"""Batched serving example: greedy decode with a KV cache, MoE decode path
(all-reduce fallback for tiny token counts) and SSM O(1)-state decode.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import make_serve_step


def serve(name, gen=24, batch=4):
    cfg = get_config(name).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(batch, gen + 1)
    step = jax.jit(make_serve_step(model, mesh, dims))
    tok = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.perf_counter()
    toks = []
    for t in range(gen):
        tok, cache = step(params, cache, {"tokens": tok,
                                          "step": jnp.int32(t)})
        toks.append(int(tok[0, 0]))
    dt = time.perf_counter() - t0
    print(f"{name:24s} {batch * gen / dt:7.1f} tok/s   first tokens: "
          f"{toks[:8]}")


def main():
    for name in ["qwen1.5-0.5b", "qwen3-moe-30b-a3b", "xlstm-350m",
                 "hymba-1.5b"]:
        serve(name)


if __name__ == "__main__":
    main()
