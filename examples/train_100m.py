"""End-to-end driver: train a ~100M-parameter MoE language model for a few
hundred steps on the synthetic corpus (the deliverable-(b) e2e example).

    PYTHONPATH=src python examples/train_100m.py --steps 300

The config is a scaled GPT-2-MoE: 6 layers, d_model 384, 8 experts top-2
(~100M params with embeddings), Parm auto-scheduling on whatever devices
are available.  On an 8-fake-device CPU mesh this exercises the real
EP/ESP collective path.
"""

import argparse
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.core.moe import MoEConfig
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import Trainer


def config_100m():
    base = get_config("gpt2-moe")
    moe = MoEConfig(d_model=512, d_ff=2048, n_experts=8, top_k=2,
                    capacity_factor=1.5, glu=False, schedule="auto")
    return replace(base, name="gpt2-moe-100m", n_layers=8, d_model=512,
                   n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=50257,
                   moe=moe, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = config_100m()
    model = build_model(cfg)
    n_dev = jax.device_count()
    d = max(1, n_dev // 2) if n_dev > 1 else 1
    mesh = make_mesh((d, max(n_dev // d, 1)), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))

    tr = Trainer(model, mesh, dims,
                 AdamWConfig(lr=6e-4, warmup_steps=20,
                             total_steps=args.steps))
    params, opt = tr.setup(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params: {n_params / 1e6:.1f}M  "
          f"devices: {n_dev}")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  n_heavy=8, heavy_prob=0.85))
    params, opt, hist = tr.run(params, opt, data, args.steps,
                               log_every=max(args.steps // 15, 1))
    print(f"CE: {hist[0]['ce']:.3f} -> {hist[-1]['ce']:.3f} over "
          f"{args.steps} steps "
          f"({hist[-1]['wall_s']:.0f}s)")
    assert hist[-1]["ce"] < hist[0]["ce"], "training must make progress"


if __name__ == "__main__":
    main()
