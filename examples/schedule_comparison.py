"""Compare Parm's three schedules on one MoE layer: numerical equivalence,
communication volume (from compiled HLO), and measured wall time.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/schedule_comparison.py

This is the paper's Fig. 3 in executable form: same math, different
collective placements, 2-3x less traffic for S1/S2.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import parse_collectives
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    cfg0 = MoEConfig(d_model=256, d_ff=512, n_experts=8, top_k=2,
                     capacity_factor=2.0)
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512, 256))

    ref = None
    print(f"{'schedule':12s} {'coll bytes':>12s} {'collectives':>42s} "
          f"{'ms/call':>8s} {'max|y-y_base|':>14s}")
    for label, sched, chunks in [
            ("baseline", "baseline", 1), ("s1", "s1", 1), ("s2", "s2", 1),
            ("s1_seqpar", "s1_seqpar", 1), ("s1 x4", "s1", 4),
            ("s2 x4", "s2", 4), ("auto", "auto", 1)]:
        cfg = replace(cfg0, pipeline_chunks=chunks)
        fn = jax.jit(lambda x, p, c=cfg, s=sched: apply_moe(
            x, p, mesh=mesh, dims=dims, cfg=c, schedule=s)[0])
        compiled = fn.lower(x, params).compile()
        stats = parse_collectives(compiled.as_text())
        y = fn(x, params)
        y.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn(x, params).block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        if ref is None:
            ref = np.asarray(y)
            err = 0.0
        else:
            err = float(np.max(np.abs(np.asarray(y) - ref)))
        print(f"{label:12s} {stats.total_bytes:12d} "
              f"{str(stats.counts):>42s} {dt * 1e3:8.1f} {err:14.2e}")


if __name__ == "__main__":
    main()
