"""Quickstart: train a tiny MoE transformer with Parm's schedules.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced Qwen3-MoE, trains 60 steps on the synthetic corpus with
the Algorithm-1 auto-selected schedule, and prints which schedule Parm
chose and the loss trajectory.
"""

import jax

from repro.configs import get_config
from repro.core.moe import select_schedule
from repro.core.perfmodel import MoELayerShape
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import Trainer


def main():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    n_dev = jax.device_count()
    d = max(1, n_dev // 2) if n_dev > 1 else 1
    mesh = make_mesh((d, max(n_dev // d, 1)), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    sizes = dims.sizes(mesh)

    pick = select_schedule(cfg.moe, MoELayerShape(
        B=8, L=64, M=cfg.d_model, H=cfg.moe.d_ff, E=cfg.moe.n_experts,
        k=cfg.moe.top_k, f=cfg.moe.capacity_factor, n_mp=sizes["mp"],
        n_esp=sizes["esp"], n_ep=sizes["ep"]))
    print(f"mesh {dict(mesh.shape)} -> Algorithm 1 picks: {pick}")

    model = build_model(cfg)
    tr = Trainer(model, mesh, dims,
                 AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60),
                 schedule="auto")
    params, opt = tr.setup(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, n_heavy=4,
                                  heavy_prob=0.9))
    params, opt, hist = tr.run(params, opt, data, 60, log_every=15)
    print(f"done: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
