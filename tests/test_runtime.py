"""Fault-tolerance layer tests (PR 8): fault-plan parsing, the guarded
train step's bitwise clean-path parity + skip semantics, the GuardState
policy machine, checkpoint rollback through the retained store, the fp8
wire-overflow fallback, and serve-side allocator starvation.

The back-compat contract locked down here: with guards ON and no fault
firing, every output is BITWISE identical to the unguarded step — the
guard rails may never perturb a healthy run.
"""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.moe import MoEConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.runtime import (OK, ROLLBACK, SKIP, FaultPlan, GuardConfig,
                           GuardState, RollbackManager, StarveState)
from repro.train import make_train_step
from repro.train.loop import Trainer, make_guarded_train_step

OPT = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)


def _setup(dtype="float32"):
    cfg = ModelConfig(
        name="rt-test", arch_type="moe", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, rope_theta=1e4,
        moe=MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                      capacity_factor=2.0, schedule="s1"),
        moe_period=1, remat=False, dtype=dtype)
    model = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                     cfg.vocab_size)}
    return model, mesh, dims, params, opt, batch


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(la, lb))


# --- fault plan ---------------------------------------------------------------

class TestFaultPlan:
    def test_parse_atoms(self):
        plan = FaultPlan.parse(
            "nan_grad@step=5-8;fp8_sat@factor=64;ckpt_bitflip@save=2;"
            "req_delay@rid=1,rounds=6;req_timeout@rid=2,ticks=4;"
            "alloc_starve@tick=1,hold=8,rounds=5", seed=7)
        assert len(plan.specs) == 6 and bool(plan) and plan.seed == 7
        assert math.isnan(plan.grad_fault(5))
        assert math.isnan(plan.grad_fault(8))
        assert plan.grad_fault(4) == 0.0 and plan.grad_fault(9) == 0.0
        assert plan.fp8_sat_factor() == 64.0
        assert plan.ckpt_corrupts(2) and not plan.ckpt_corrupts(1)
        assert plan.req_delay_rounds(1) == 6 and plan.req_delay_rounds(0) == 0
        assert plan.req_timeout_ticks(2) == 4 and plan.req_timeout_ticks(1) == 0
        assert plan.alloc_starve() == (1, 8, 5)

    def test_empty_and_single_step(self):
        empty = FaultPlan.parse("")
        assert not empty and empty.grad_fault(0) == 0.0
        assert empty.fp8_sat_factor() == 0.0 and empty.alloc_starve() is None
        one = FaultPlan.parse("nan_grad@step=3")
        assert math.isnan(one.grad_fault(3)) and one.grad_fault(2) == 0.0

    def test_inf_value(self):
        plan = FaultPlan.parse("nan_grad@step=1,value=inf")
        assert math.isinf(plan.grad_fault(1))

    def test_parse_errors(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultPlan.parse("cosmic_ray@step=1")
        with pytest.raises(ValueError, match="key=val"):
            FaultPlan.parse("nan_grad@5")

    def test_summary_roundtrips(self):
        text = "nan_grad@step=5;fp8_sat@factor=64"
        plan = FaultPlan.parse(text)
        assert FaultPlan.parse(plan.summary()).specs == plan.specs

    def test_flip_bit_deterministic(self, tmp_path):
        p = os.path.join(tmp_path, "blob.bin")
        data = bytes(range(256)) * 64
        for _ in range(2):
            with open(p, "wb") as f:
                f.write(data)
            off = FaultPlan.parse("ckpt_bitflip@save=1", seed=3).flip_bit(p)
        assert 0 < off < len(data)
        with open(p, "rb") as f:
            flipped = f.read()
        diff = [i for i in range(len(data)) if data[i] != flipped[i]]
        assert diff == [off]


# --- guard state machine ------------------------------------------------------

class TestGuardState:
    def test_skip_backoff_then_rollback(self):
        st = GuardState(cfg=GuardConfig(max_skips=3, lr_backoff=0.5))
        assert st.observe(0, 1.0, False) == OK
        assert st.observe(1, float("nan"), True) == SKIP
        assert st.observe(2, float("nan"), True) == SKIP
        assert st.lr_scale == 0.25
        assert st.observe(3, float("nan"), True) == ROLLBACK
        assert st.counters["skipped"] == 3
        st.record_rollback(3, restored_step=0)
        assert st.streak == 0 and st.counters["rollbacks"] == 1

    def test_lr_recovers_on_clean_steps(self):
        st = GuardState(cfg=GuardConfig(max_skips=5, lr_backoff=0.5,
                                        lr_recover=2.0))
        st.observe(0, float("nan"), True)
        st.observe(1, float("nan"), True)
        assert st.lr_scale == 0.25
        st.observe(2, 1.0, False)
        st.observe(3, 1.0, False)
        assert st.lr_scale == 1.0          # capped at 1.0

    def test_rollback_unavailable_counted(self):
        st = GuardState()
        st.record_rollback(4, restored_step=None)
        assert st.counters["rollback_unavailable"] == 1
        assert st.counters["rollbacks"] == 0

    def test_spike_detector(self):
        st = GuardState(cfg=GuardConfig(spike_min=8, spike_z=10.0))
        for i in range(10):
            assert st.observe(i, 5.0 + 0.01 * (i % 3), False) == OK
        assert st.observe(10, 50.0, False) == ROLLBACK
        assert st.counters["loss_spikes"] == 1
        # the spike is never folded into the window: the next spike at
        # the same level still fires
        st.record_rollback(10, restored_step=5)
        for i in range(11, 20):
            st.observe(i, 5.0, False)
        assert st.observe(20, 50.0, False) == ROLLBACK

    def test_spike_needs_history(self):
        st = GuardState(cfg=GuardConfig(spike_min=8))
        for i in range(5):
            st.observe(i, 1.0, False)
        assert st.observe(5, 1000.0, False) == OK    # < spike_min history

    def test_config_validation(self):
        with pytest.raises(ValueError):
            GuardConfig(max_skips=0)
        with pytest.raises(ValueError):
            GuardConfig(lr_backoff=0.0)


# --- guarded step: bitwise parity + skip semantics ----------------------------

class TestGuardedStep:
    @pytest.fixture(scope="class")
    def ctx(self):
        model, mesh, dims, params, opt, batch = _setup()
        plain = jax.jit(make_train_step(model, mesh, dims, OPT, "s1"))
        guarded = jax.jit(make_guarded_train_step(model, mesh, dims, OPT,
                                                  "s1"))
        return plain, guarded, params, opt, batch

    def test_clean_path_bitwise_parity(self, ctx):
        """Guards on, nothing firing: params, opt state (incl. the step
        counter), and loss are bit-identical to the unguarded step."""
        plain, guarded, params, opt, batch = ctx
        p1, o1, m1 = plain(params, opt, batch)
        p2, o2, m2 = guarded(params, opt, batch, jnp.float32(1.0),
                             jnp.float32(0.0))
        assert _bitwise_equal(p1, p2)
        assert _bitwise_equal(o1, o2)
        assert np.asarray(m1["loss"]).tobytes() == \
            np.asarray(m2["loss"]).tobytes()
        assert not bool(m2["nonfinite"])

    def test_nan_fault_skips_bit_identically(self, ctx):
        """A poisoned step returns the INPUT params/opt state untouched —
        including the optimizer step counter — and raises the flag."""
        _, guarded, params, opt, batch = ctx
        p, o, m = guarded(params, opt, batch, jnp.float32(1.0),
                          jnp.float32(float("nan")))
        assert bool(m["nonfinite"])
        assert _bitwise_equal(p, params)
        assert _bitwise_equal(o, opt)
        assert int(o["step"]) == int(opt["step"])

    def test_inf_fault_also_skips(self, ctx):
        _, guarded, params, opt, batch = ctx
        p, o, m = guarded(params, opt, batch, jnp.float32(1.0),
                          jnp.float32(float("inf")))
        assert bool(m["nonfinite"]) and _bitwise_equal(p, params)

    def test_lr_scale_shrinks_update(self, ctx):
        plain, guarded, params, opt, batch = ctx
        p_full, _, _ = plain(params, opt, batch)
        p_half, _, m = guarded(params, opt, batch, jnp.float32(0.5),
                               jnp.float32(0.0))
        assert not bool(m["nonfinite"])
        d_full = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
                     for a, b in zip(jax.tree.leaves(p_full),
                                     jax.tree.leaves(params)))
        d_half = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
                     for a, b in zip(jax.tree.leaves(p_half),
                                     jax.tree.leaves(params)))
        assert 0 < d_half < d_full


def test_adamw_finite_mask_unit():
    """The fused select in adamw_update, in isolation: finite=True is
    bit-identical to no mask; finite=False is bit-identical to no-op."""
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) * 0.1}
    grads = {"w": jnp.full((2, 3), 0.5, jnp.float32)}
    state = adamw_init(params)
    p_ref, s_ref, _ = adamw_update(params, grads, state, OPT)
    p_on, s_on, om = adamw_update(params, grads, state, OPT,
                                  finite=jnp.bool_(True))
    assert bool(om["finite"])
    assert _bitwise_equal(p_ref, p_on) and _bitwise_equal(s_ref, s_on)
    bad = {"w": grads["w"].at[0, 0].set(jnp.nan)}
    p_off, s_off, om2 = adamw_update(params, bad, state, OPT,
                                     finite=jnp.bool_(True))
    assert not bool(om2["finite"])        # gnorm went NaN -> masked out
    assert _bitwise_equal(p_off, params) and _bitwise_equal(s_off, state)


# --- checkpoint store + rollback ----------------------------------------------

class TestRollback:
    def _tree(self, v):
        return {"params": {"w": np.full((3,), v, np.float32)},
                "opt_state": {"step": np.int32(int(v))}}

    def test_retain_prunes_oldest(self, tmp_path):
        from repro.checkpoint import CheckpointStore
        store = CheckpointStore(os.path.join(tmp_path, "run.npz"), retain=2)
        for s in (1, 2, 3):
            store.save(self._tree(s), s)
        assert store.steps() == [2, 3]

    def test_rollback_falls_back_over_corrupt(self, tmp_path):
        from repro.checkpoint import CheckpointStore
        faults = FaultPlan.parse("ckpt_bitflip@save=3", seed=1)
        store = CheckpointStore(os.path.join(tmp_path, "run.npz"),
                                retain=3, faults=faults)
        mgr = RollbackManager(store)
        for s in (1, 2, 3):                 # 3rd save is bit-flipped
            mgr.snapshot(self._tree(s)["params"],
                         self._tree(s)["opt_state"], s)
        params, opt_state, restored = mgr.rollback(5)
        assert restored == 2                # newest intact checkpoint
        np.testing.assert_array_equal(params["w"],
                                      np.full((3,), 2, np.float32))

    def test_rollback_none_when_empty(self, tmp_path):
        from repro.checkpoint import CheckpointStore
        mgr = RollbackManager(CheckpointStore(str(tmp_path)))
        assert mgr.rollback(1) is None


# --- Trainer end-to-end -------------------------------------------------------

class TestTrainerGuarded:
    def test_nan_injection_recovers(self, tmp_path):
        """The acceptance run: NaN grads at steps 5-7 with max_skips=2 ->
        skips, one rollback re-anchoring to a retained checkpoint, and a
        finite final loss; retained files pruned to k."""
        from repro.data import DataConfig, SyntheticLM
        model, mesh, dims, params, opt, _ = _setup()
        tr = Trainer(model, mesh, dims, OPT, schedule="s1",
                     ckpt_path=os.path.join(tmp_path, "run.npz"),
                     guards=GuardConfig(max_skips=2),
                     faults=FaultPlan.parse("nan_grad@step=5-7"),
                     ckpt_retain=2)
        params, opt = tr.setup(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16,
                                      global_batch=2))
        params, opt, hist = tr.run(params, opt, data, 12, log_every=4,
                                   ckpt_every=3)
        gs = tr.guard_state
        assert gs.counters["skipped"] == 3
        assert gs.counters["rollbacks"] >= 1
        assert math.isfinite(hist[-1]["loss"])
        ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
        assert 1 <= len(ckpts) <= 2

    def test_guarded_clean_run_matches_plain(self):
        """Guards on, no faults: the whole training run is bitwise the
        run that never had guards."""
        from repro.data import DataConfig, SyntheticLM
        model, mesh, dims, *_ = _setup()
        data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16,
                                      global_batch=2))
        finals = []
        for guards in (None, GuardConfig()):
            tr = Trainer(model, mesh, dims, OPT, schedule="s1",
                         guards=guards)
            p, o = tr.setup(jax.random.PRNGKey(0))
            p, o, hist = tr.run(p, o, data, 4, log_every=4)
            finals.append((p, hist[-1]["loss"]))
        assert _bitwise_equal(finals[0][0], finals[1][0])
        assert finals[0][1] == finals[1][1]


# --- fp8 overflow fallback ----------------------------------------------------

@pytest.fixture
def fp8_clean():
    """Reset every process-wide fp8/wire-ceiling global around the test."""
    from repro.core import autosched, collectives
    from repro.runtime import disable_fp8_monitor, reset_fp8_counter
    yield
    collectives.set_fp8_sat_injection(0.0)
    autosched.set_wire_ceiling(None)
    disable_fp8_monitor()
    reset_fp8_counter()


class TestFp8Fallback:
    def test_monitor_counts_injected_saturation(self, fp8_clean):
        from repro.core.collectives import (CommConfig, set_fp8_sat_injection,
                                            wire_encode)
        from repro.runtime import (enable_fp8_monitor, fp8_sat_counts,
                                   fp8_sat_rate, reset_fp8_counter)
        comm = CommConfig(wire_dtype="fp8_e4m3")
        x = jnp.linspace(-3.0, 3.0, 64).reshape(4, 16)
        reset_fp8_counter()
        enable_fp8_monitor()
        # fresh lambdas: the injection factor is read at TRACE time, so
        # each phase needs its own trace (jit caches per function object)
        jax.block_until_ready(jax.jit(lambda a: wire_encode(a, comm))(x))
        sat0, tot0 = fp8_sat_counts()
        assert tot0 == 64 and sat0 == 0      # absmax scaling: none saturate
        set_fp8_sat_injection(64.0)
        reset_fp8_counter()
        jax.block_until_ready(jax.jit(lambda a: wire_encode(a, comm))(x))
        sat1, tot1 = fp8_sat_counts()
        assert tot1 == 64 and sat1 > 32      # scales shrunk 64x: most clip
        assert fp8_sat_rate() > 0.5

    def test_check_fp8_fires_once_and_sets_ceiling(self, fp8_clean):
        from repro.core import autosched
        from repro.runtime.guards import _SAT
        st = GuardState(cfg=GuardConfig(fp8_sat_threshold=1e-3))
        _SAT["sat"], _SAT["total"] = 500, 1000
        assert st.check_fp8()
        assert not st.check_fp8()            # one-shot
        assert st.counters["fp8_fallbacks"] == 1
        # what the trainer does with the signal:
        autosched.set_wire_ceiling(st.cfg.fp8_fallback)
        assert autosched.clamp_wire("fp8_e4m3") == "bf16"
        assert autosched.clamp_wire("f32") == "f32"   # never narrows

    def test_wire_ceiling_validation(self, fp8_clean):
        from repro.core import autosched
        with pytest.raises(ValueError):
            autosched.set_wire_ceiling("int4")
        autosched.set_wire_ceiling(None)
        assert autosched.clamp_wire("fp8_e4m3") == "fp8_e4m3"


# --- serve-side starvation ----------------------------------------------------

def test_starve_state_reserve_release():
    from repro.serve.kvcache import BlockAllocator
    alloc = BlockAllocator(n_blocks=16, block_size=8)
    st = StarveState(start=1, hold=10, rounds=3)
    st.tick(alloc, 0)
    assert alloc.available == 16            # not started yet
    st.tick(alloc, 1)
    assert st.active and alloc.available == 6
    for t in (2, 3, 4):
        st.tick(alloc, t)
    assert st.done and alloc.available == 16
    st.tick(alloc, 5)                        # done: never re-fires
    assert alloc.available == 16
    alloc.check()
