"""Docs health: the documented modules stay doctest-clean and every
relative link in README/docs resolves (mirrors the CI docs job so
breakage is caught locally by tier-1)."""

import doctest
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


class TestDoctests:
    def _run(self, module):
        results = doctest.testmod(module, verbose=False)
        assert results.failed == 0, f"{module.__name__}: {results}"
        assert results.attempted > 0, f"{module.__name__} has no doctests"

    def test_perfmodel_doctests(self):
        from repro.core import perfmodel
        self._run(perfmodel)

    def test_collectives_doctests(self):
        from repro.core import collectives
        self._run(collectives)


class TestDocsPresent:
    def test_docs_exist_and_crosslinked(self):
        readme = open(os.path.join(REPO, "README.md")).read()
        for page in ("docs/architecture.md", "docs/schedules.md"):
            assert os.path.exists(os.path.join(REPO, page)), page
            assert page in readme, f"README does not link {page}"
        sched = open(os.path.join(REPO, "docs", "schedules.md")).read()
        for body in ("baseline", "s1", "s2", "_pipe", "algorithm1"):
            assert body in sched, body

    def test_readme_names_every_bench(self):
        readme = open(os.path.join(REPO, "README.md")).read()
        benches = [f for f in os.listdir(os.path.join(REPO, "benchmarks"))
                   if f.startswith("bench_") and f.endswith(".py")]
        missing = [b for b in benches if b not in readme]
        assert not missing, f"README missing benches: {missing}"


class TestLinkCheck:
    def test_all_relative_links_resolve(self):
        r = subprocess.run(
            [sys.executable, os.path.join("tools", "check_links.py")],
            capture_output=True, text=True, cwd=REPO, timeout=60)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 broken" in r.stdout
