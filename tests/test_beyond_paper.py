"""Beyond-paper feature tests: sequence-parallel S1 contract and
context-parallel decode cache sharding (the §Perf levers)."""

import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def test_cache_seq_shard_decode_exact():
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, "run_cache_seqshard.py")],
        env=subprocess_env(8), capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "CACHE SEQSHARD OK" in r.stdout


def test_s1_seqpar_equivalent_and_minimal():
    """covered numerically by run_schedule_equiv (merged includes
    s1_seqpar) and volume-wise by run_comm_volume; this asserts both
    helpers agree end-to-end in one process."""
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, "run_comm_volume.py")],
        env=subprocess_env(8), capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    lines = dict(l.split()[:2] for l in r.stdout.splitlines()
                 if l and l.split()[0] in ("baseline", "s1", "s2",
                                           "s1_seqpar"))
    assert int(lines["s1_seqpar"]) < int(lines["s1"]) \
        < int(lines["baseline"])
