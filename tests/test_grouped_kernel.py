"""Dropless ragged grouped-GEMM megakernel: pallas-vs-oracle unit tests
(interpret mode) plus the s1g-vs-s1 schedule parity matrix (subprocess,
8 fake devices).

The unit tests exercise the ragged contract directly — rows at index >=
counts[e, g] are exact zeros, empty groups are skipped, tail groups
smaller than a tile are masked — and the fused single-device form
(dispatch gather prologue + combine scatter epilogue).  The parity
matrix drives the same kernels through the plan executor against the
capacity-pool path they fuse.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gating import GateConfig, capacity, topk_gate
from repro.kernels import ref
from repro.kernels.expert_ffn_grouped import (expert_ffn_grouped,
                                              expert_ffn_ragged,
                                              slot_metadata)
from repro.kernels.registry import get_op

from conftest import subprocess_env

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def _run(script, *args, n_devices=8, timeout=900):
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = HELPERS + os.pathsep + env["PYTHONPATH"]
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _pool(E, G, c, M, F, seed=0, glu=True, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xb = jax.random.normal(ks[0], (E, G, c, M), dtype)
    w1 = (jax.random.normal(ks[1], (E, M, F)) * 0.1).astype(dtype)
    w3 = (jax.random.normal(ks[2], (E, M, F)) * 0.1).astype(dtype) \
        if glu else None
    w2 = (jax.random.normal(ks[3], (E, F, M)) * 0.1).astype(dtype)
    return xb, w1, w3, w2


class TestRaggedFFN:
    @pytest.mark.parametrize("E,G,c,M,F", [
        (4, 2, 64, 64, 128), (8, 1, 32, 96, 160), (2, 4, 128, 64, 64),
    ])
    @pytest.mark.parametrize("glu", [True, False])
    def test_vs_ref(self, E, G, c, M, F, glu):
        xb, w1, w3, w2 = _pool(E, G, c, M, F, glu=glu)
        counts = jax.random.randint(jax.random.PRNGKey(9), (E, G), 0,
                                    c + 1).astype(jnp.int32)
        act = "silu" if glu else "gelu"
        out = expert_ffn_ragged(xb, counts, w1, w3, w2, act=act)
        exp = ref.expert_ffn_ragged_ref(xb, counts, w1, w3, w2, act=act)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-4, rtol=5e-4)

    def test_rows_beyond_count_are_exact_zero(self):
        xb, w1, w3, w2 = _pool(4, 2, 64, 64, 128, seed=3)
        counts = jnp.array([[64, 0], [17, 5], [0, 0], [1, 63]],
                           jnp.int32)
        out = np.asarray(expert_ffn_ragged(xb, counts, w1, w3, w2))
        for e in range(4):
            for g in range(2):
                n = int(counts[e, g])
                assert (out[e, g, n:] == 0.0).all(), (e, g)
                if n:
                    assert np.abs(out[e, g, :n]).sum() > 0.0, (e, g)

    def test_zero_token_experts_and_skew(self):
        # the ragged point: an all-but-one-empty pool must behave like
        # the dense pool masked, tail group smaller than one tile
        xb, w1, w3, w2 = _pool(8, 1, 96, 64, 128, seed=5)
        counts = jnp.zeros((8, 1), jnp.int32).at[3, 0].set(96) \
            .at[6, 0].set(7)
        out = expert_ffn_ragged(xb, counts, w1, w3, w2)
        exp = ref.expert_ffn_ragged_ref(xb, counts, w1, w3, w2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-4, rtol=5e-4)

    def test_bf16_io_f32_compute(self):
        xb, w1, w3, w2 = _pool(2, 2, 64, 64, 128, dtype=jnp.bfloat16)
        counts = jnp.array([[64, 10], [0, 33]], jnp.int32)
        out = expert_ffn_ragged(xb, counts, w1, w3, w2)
        exp = ref.expert_ffn_ragged_ref(xb, counts, w1, w3, w2)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=3e-2, rtol=3e-2)

    def test_registry_grad_matches_ref_grad(self):
        xb, w1, w3, w2 = _pool(4, 1, 64, 64, 128, seed=7)
        counts = jnp.array([[64], [11], [0], [40]], jnp.int32)
        op = get_op("expert_ffn_ragged", backend="pallas")
        op_ref = get_op("expert_ffn_ragged", backend="ref")

        def s(f):
            return jax.grad(lambda a: jnp.sum(
                f(a, counts, w1, w3, w2) ** 2))(xb)
        np.testing.assert_allclose(np.asarray(s(op)),
                                   np.asarray(s(op_ref)),
                                   atol=5e-4, rtol=5e-4)


class TestFusedGrouped:
    def _routed(self, S, M, E, k, f=2.0, seed=0):
        x = jax.random.normal(jax.random.PRNGKey(seed), (S, M))
        wg = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (M, E)) * 0.3
        cfg = GateConfig(n_experts=E, top_k=k, capacity_factor=f)
        cap = capacity(S, cfg)
        eidx, slot, w, _ = topk_gate(x, wg, cfg, cap)
        flat = jnp.where(slot < cap, eidx * cap + slot,
                         E * cap).astype(jnp.int32)
        return x, flat, w, cap

    @pytest.mark.parametrize("wire", ["f32", "bf16"])
    @pytest.mark.parametrize("S,M,E,k", [(128, 64, 4, 2), (96, 96, 8, 1)])
    def test_vs_ref(self, wire, S, M, E, k):
        x, flat, w, cap = self._routed(S, M, E, k)
        _, w1, w3, w2 = _pool(E, 1, cap, M, 2 * M)
        out = expert_ffn_grouped(x, flat, w, w1, w3, w2, cap=cap,
                                 wire=wire)
        exp = ref.expert_ffn_grouped_ref(x, flat, w, w1, w3, w2,
                                         cap=cap, wire=wire)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-4, rtol=5e-4)

    def test_dropped_tokens_contribute_zero(self):
        # tight capacity: sentinel slots must gather zeros and scatter
        # nothing back — dropped rows come out exactly zero
        x, flat, w, cap = self._routed(256, 64, 4, 2, f=0.25, seed=2)
        assert bool((flat == 4 * cap).any())
        _, w1, w3, w2 = _pool(4, 1, cap, 64, 128)
        out = np.asarray(expert_ffn_grouped(x, flat, w, w1, w3, w2,
                                            cap=cap))
        exp = np.asarray(ref.expert_ffn_grouped_ref(x, flat, w, w1, w3,
                                                    w2, cap=cap))
        np.testing.assert_allclose(out, exp, atol=5e-4, rtol=5e-4)
        dropped = np.asarray((flat == 4 * cap).all(axis=-1))
        assert (np.abs(out[dropped]) == 0.0).all()

    def test_slot_metadata_counts_match_gate_load(self):
        x, flat, w, cap = self._routed(128, 64, 4, 2, seed=4)
        rid, ws, counts = slot_metadata(flat, w, 128, 4, cap)
        onehot = np.zeros((4,), np.int64)
        fl = np.asarray(flat).reshape(-1)
        for v in fl[fl < 4 * cap]:
            onehot[v // cap] += 1
        np.testing.assert_array_equal(np.asarray(counts), onehot)
        # slots are contiguous per expert: rid sentinel iff idx>=count
        rid = np.asarray(rid)
        for e in range(4):
            assert (rid[e, :onehot[e]] < 128).all()
            assert (rid[e, onehot[e]:] == 128).all()


class TestGroupedScheduleParity:
    """s1g (fuse_grouped(s1)) vs the s1 capacity-pool golden through
    apply_moe: forward/grad envelopes, bit-identical aux scalars,
    routed-load vectors and drop masks — per mesh mapping x n_chunks x
    wire dtype (see tests/helpers/run_grouped_parity.py)."""

    def test_merged_mesh(self):
        assert "OK merged" in _run("run_grouped_parity.py", "merged")

    def test_distinct_axes(self):
        assert "OK distinct" in _run("run_grouped_parity.py", "distinct")

    def test_skewed_load_and_empty_experts(self):
        assert "OK skew" in _run("run_grouped_parity.py", "skew")

    def test_local_fused_megakernel(self):
        assert "OK local" in _run("run_grouped_parity.py", "local",
                                  n_devices=1)
