"""Checkpoint round-trip tests: save -> restore -> one-more-step parity
(the contract serving needs to load trained params), plus the
bfloat16/ml_dtypes bit-exactness fix the parity test surfaced."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core.moe import MoEConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import make_train_step


def _setup(dtype="float32"):
    cfg = ModelConfig(
        name="ckpt-test", arch_type="moe", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, rope_theta=1e4,
        moe=MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                      capacity_factor=2.0, schedule="s1"),
        moe_period=1, remat=False, dtype=dtype)
    model = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, mesh, dims,
                                   AdamWConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=10)))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                     cfg.vocab_size),
    }
    return model, step, params, opt, batch


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype != y.dtype or not np.array_equal(x, y):
            return False
    return True


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_save_restore_one_more_step_parity(tmp_path, dtype):
    """The serving contract: restoring a checkpoint must continue
    training (and therefore serve) EXACTLY as if never interrupted —
    same leaves, same dtypes, bit-equal next step.  bfloat16 exercises
    the ml_dtypes round-trip (np.savez used to demote bf16 to raw void
    arrays jax then rejected)."""
    model, step, params, opt, batch = _setup(dtype)
    p1, o1, _ = step(params, opt, batch)

    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"params": p1, "opt": o1}, step=1)
    tree, at_step = load_checkpoint(path)
    assert at_step == 1
    assert _trees_equal(tree["params"], p1)
    assert _trees_equal(tree["opt"], o1)

    p2a, o2a, ma = step(p1, o1, batch)
    p2b, o2b, mb = step(tree["params"], tree["opt"], batch)
    assert _trees_equal(p2a, p2b)
    assert _trees_equal(o2a, o2b)
    assert float(ma["loss"]) == float(mb["loss"])


def test_restored_params_serve(tmp_path):
    """End-to-end serving contract: the engine decodes identically from
    restored params as from the in-memory originals."""
    from repro.parallel.mesh import ParallelDims, make_mesh
    from repro.serve import Engine

    model, step, params, opt, batch = _setup()
    p1, o1, _ = step(params, opt, batch)
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"params": p1, "opt": o1}, step=1)
    tree, _ = load_checkpoint(path)

    mesh = make_mesh((1, 1), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    prompt = list(range(1, 8))
    outs = []
    for p in (p1, tree["params"]):
        eng = Engine(model, mesh, dims, max_batch=2, max_len=32)
        eng.submit(prompt, 6)
        (c,) = eng.run(p)
        outs.append(c.tokens)
    assert outs[0] == outs[1]


def test_shardings_and_step_roundtrip(tmp_path):
    """Restore with explicit shardings device_puts the leaves; nested
    list/tuple structure survives."""
    path = os.path.join(tmp_path, "t.npz")
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": [np.int32(3), (np.float32(1.5), np.float32(2.5))]}
    save_checkpoint(path, tree, step=7)
    out, at = load_checkpoint(path)
    assert at == 7
    assert isinstance(out["b"], list) and isinstance(out["b"][1], tuple)
    np.testing.assert_array_equal(out["a"], tree["a"])
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out2, _ = load_checkpoint(path, shardings={
        "a": sh, "b": [None, (None, None)]})
    assert isinstance(out2["a"], jax.Array)


# --- corruption handling (PR 8) ----------------------------------------------

def test_truncated_file_clean_diagnostic(tmp_path):
    """A partially-written checkpoint raises CheckpointCorruptError with
    the path named — never a raw zipfile/np.load traceback three
    subsystems later."""
    from repro.checkpoint import CheckpointCorruptError

    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"w": np.arange(64, dtype=np.float32)}, step=3)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CheckpointCorruptError, match="unreadable"):
        load_checkpoint(path)


def test_bitflipped_leaf_named_in_diagnostic(tmp_path):
    """A flipped bit in leaf data fails the crc manifest and the error
    names the corrupt key."""
    from repro.checkpoint import CheckpointCorruptError

    path = os.path.join(tmp_path, "ck.npz")
    big = np.arange(4096, dtype=np.float32)
    save_checkpoint(path, {"params": {"embed": big}}, step=1)
    from repro.runtime import FaultPlan
    FaultPlan.parse("ckpt_bitflip@save=1", seed=0).flip_bit(path)
    with pytest.raises(CheckpointCorruptError):
        load_checkpoint(path)


def test_store_falls_back_to_previous_retained(tmp_path):
    """CheckpointStore.restore walks newest -> oldest past a corrupt
    newest file: one retained step of progress lost, never the run."""
    from repro.checkpoint import CheckpointStore

    store = CheckpointStore(os.path.join(tmp_path, "run.npz"), retain=3)
    for s in (2, 4, 6):
        store.save({"w": np.full((8,), s, np.float32)}, s)
    from repro.runtime import FaultPlan
    FaultPlan.parse("ckpt_bitflip@save=1", seed=5).flip_bit(store.path_of(6))
    tree, step, path = store.restore()
    assert step == 4 and path.endswith(".step00000004.npz")
    np.testing.assert_array_equal(tree["w"], np.full((8,), 4, np.float32))


def test_store_save_is_atomic(tmp_path):
    """No *.tmp litter after saves; the newest file always verifies."""
    from repro.checkpoint import CheckpointStore

    store = CheckpointStore(str(tmp_path), retain=2)
    for s in (1, 2, 3):
        store.save({"w": np.arange(16, dtype=np.float32) * s}, s)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert store.steps() == [2, 3]
    tree, step, _ = store.restore()
    assert step == 3
