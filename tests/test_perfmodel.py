"""Alpha-beta performance model + Algorithm 1 (paper §IV/§V) tests."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.perfmodel import (AlphaBeta, MoELayerShape, PerfModel,
                                  fit_alpha_beta, speedup_table,
                                  tpu_v5e_model)


def toy_model(beta=1e-9, alpha=1e-5):
    ab = AlphaBeta(alpha, beta)
    return PerfModel(a2a_ep_esp=ab, a2a_ep=ab, ag_esp=ab, ar_esp=ab,
                     ag_mp=AlphaBeta(alpha, beta / 4), overlap=ab)


class TestClosedForms:
    def test_eq1_baseline(self):
        m = toy_model()
        s = MoELayerShape(B=4, L=1024, M=1024, H=4096, E=8, k=2, f=1.2,
                          n_mp=2, n_esp=2, n_ep=4)
        t = m.t_baseline(s)
        expect = (m.ag_esp(s.blm * 2) + m.ar_esp(s.etm * 2)
                  + 2 * m.a2a_ep(s.etm * 2))
        assert t == pytest.approx(expect)

    def test_s1_s2_beat_baseline(self):
        """Paper §IV-B: S1 and S2 always beat the baseline (Eq. 6/10)."""
        for n_mp in (1, 2, 4):
            for n_esp in (1, 2, 4):
                m = tpu_v5e_model(n_ep=4, n_esp=n_esp, n_mp=n_mp)
                s = MoELayerShape(B=8, L=1024, M=2048, H=8192, E=16, k=2,
                                  f=1.2, n_mp=n_mp, n_esp=n_esp, n_ep=4)
                assert m.t_s1(s) < m.t_baseline(s)
                assert m.t_s2(s) < m.t_baseline(s)

    def test_regimes_t_small_s2_t_large_s1(self):
        """§IV-B: T->0 favours S2, T->inf favours S1."""
        m = toy_model()
        small = MoELayerShape(B=1, L=64, M=1024, H=1, E=64, k=1, f=0.1,
                              n_mp=4, n_esp=1, n_ep=4)
        big = MoELayerShape(B=64, L=4096, M=1024, H=1, E=4, k=4, f=8.0,
                            n_mp=4, n_esp=1, n_ep=4)
        assert m.algorithm1(small) == "s2"
        assert m.algorithm1(big) == "s1"

    @settings(max_examples=50, deadline=None)
    @given(B=st.sampled_from([1, 4, 8]), L=st.sampled_from([256, 2048]),
           M=st.sampled_from([512, 4096]), E=st.sampled_from([8, 64]),
           k=st.integers(1, 4), n_mp=st.sampled_from([1, 2, 4, 16]),
           n_esp=st.sampled_from([1, 2, 4, 16]))
    def test_algorithm1_is_argmin(self, B, L, M, E, k, n_mp, n_esp):
        """The selector must pick argmin(t_D1, t_D2) of its own line-4/5
        cost expressions."""
        m = tpu_v5e_model(n_ep=4, n_esp=n_esp, n_mp=n_mp)
        s = MoELayerShape(B=B, L=L, M=M, H=4 * M, E=E, k=k, f=1.2,
                          n_mp=n_mp, n_esp=n_esp, n_ep=4)
        y = s.E * s.T * s.M * n_esp
        x = s.B * s.L * s.M
        t1 = 2 * m.a2a_ep_esp(y / n_mp) + m.ag_mp(x)
        t2 = (m.a2a_ep_esp(y / n_mp) + m.overlap(y / n_mp)
              + m.ag_mp(s.E * s.T * s.M))
        pick = m.algorithm1(s)
        assert pick == ("s1" if t1 <= t2 else "s2")

    def test_speedup_table_fields(self):
        m = tpu_v5e_model(4, 4, 4)
        s = MoELayerShape(B=8, L=1024, M=2048, H=2048, E=16, k=2, f=1.2,
                          n_mp=4, n_esp=4, n_ep=4)
        row = speedup_table(s, m)
        assert row["speedup_parm"] >= max(row["speedup_s1"],
                                          row["speedup_s2"]) - 1e-9
        assert row["speedup_parm"] > 1.0


class TestFitting:
    @settings(max_examples=20, deadline=None)
    @given(alpha=st.floats(1e-6, 1e-3), beta=st.floats(1e-12, 1e-8))
    def test_lsq_recovers_parameters(self, alpha, beta):
        sizes = [2 ** i for i in range(10, 24, 2)]
        times = [alpha + beta * x for x in sizes]
        fit = fit_alpha_beta(sizes, times)
        assert fit.beta == pytest.approx(beta, rel=1e-6)
        assert fit.alpha == pytest.approx(alpha, rel=1e-3, abs=1e-9)

    def test_fit_with_noise(self):
        rng = np.random.default_rng(0)
        alpha, beta = 5e-5, 2e-10
        sizes = [2 ** i for i in range(12, 26)]
        times = [alpha + beta * x * (1 + rng.normal(0, 0.02))
                 for x in sizes]
        fit = fit_alpha_beta(sizes, times)
        assert fit.beta == pytest.approx(beta, rel=0.1)
