"""Optimizer / data pipeline / checkpoint / HLO-parser unit tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.analysis.hlo import parse_collectives
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule)


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_frac=1.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw_update(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.ones((4,))}
        state = adamw_init(params)
        _, _, m = adamw_update(params, {"w": jnp.full((4,), 100.0)}, state,
                               cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_no_weight_decay_on_1d(self):
        cfg = AdamWConfig(lr=1.0, weight_decay=1.0, warmup_steps=0,
                          min_lr_frac=1.0)
        params = {"scale": jnp.ones((8,)), "w": jnp.ones((8, 8))}
        state = adamw_init(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        p2, _, _ = adamw_update(params, zero_g, state, cfg)
        np.testing.assert_array_equal(np.asarray(p2["scale"]),
                                      np.ones((8,)))   # no decay
        assert (np.asarray(p2["w"]) < 1.0).all()        # decayed

    def test_schedule_warmup_and_floor(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(cosine_schedule(cfg, 0)) == 0.0
        assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0)
        assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1)


class TestData:
    def test_deterministic(self):
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=4))
        b1, b2 = d.batch(3), d.batch(3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = d.batch(4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=4))
        b = d.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """bigram successors must be over-represented."""
        d = SyntheticLM(DataConfig(vocab_size=50, seq_len=256,
                                   global_batch=16, heavy_prob=0.8))
        b = d.batch(0)
        hits = 0
        total = 0
        for row_t, row_l in zip(b["tokens"], b["labels"]):
            for t, l in zip(row_t, row_l):
                total += 1
                if l in d.bigram[t]:
                    hits += 1
        assert hits / total > 0.5

    def test_vocab_bounds(self):
        d = SyntheticLM(DataConfig(vocab_size=37, seq_len=8,
                                   global_batch=2))
        b = d.batch(0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 37


class TestCheckpoint:
    def test_roundtrip_nested(self):
        tree = {"a": {"b": jnp.arange(6).reshape(2, 3),
                      "c": [jnp.ones(2), jnp.zeros(3)]},
                "d": (jnp.float32(3.5), jnp.int32(7))}
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "x.npz")
            save_checkpoint(p, tree, step=42)
            got, step = load_checkpoint(p)
        assert step == 42
        assert isinstance(got["d"], tuple)
        assert isinstance(got["a"]["c"], list)
        np.testing.assert_array_equal(got["a"]["b"],
                                      np.arange(6).reshape(2, 3))
        assert got["d"][0] == np.float32(3.5)

    def test_atomic_overwrite(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "x.npz")
            save_checkpoint(p, {"w": jnp.zeros(3)}, 1)
            save_checkpoint(p, {"w": jnp.ones(3)}, 2)
            got, step = load_checkpoint(p)
        assert step == 2
        np.testing.assert_array_equal(got["w"], np.ones(3))


class TestHLOParser:
    def test_counts_and_bytes(self):
        hlo = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar = bf16[4,256]{1,0} all-reduce(%y), to_apply=%add
  %a2a = f32[8,8]{1,0} all-to-all(%z), dimensions={0}
  %cp = u8[100]{0} collective-permute(%w), source_target_pairs={}
  %rs = f32[64]{0} reduce-scatter(%v), dimensions={0}
"""
        st_ = parse_collectives(hlo)
        assert st_.counts == {"all-gather": 1, "all-reduce": 1,
                              "all-to-all": 1, "collective-permute": 1,
                              "reduce-scatter": 1}
        assert st_.bytes_by_kind["all-gather"] == 16 * 128 * 4
        assert st_.bytes_by_kind["all-reduce"] == 4 * 256 * 2
        assert st_.bytes_by_kind["collective-permute"] == 100

    def test_async_pairs_counted_once(self):
        hlo = """
  %s = (f32[8]{0}, f32[16]{0}) all-gather-start(%x), dimensions={0}
  %d = f32[16]{0} all-gather-done(%s)
"""
        st_ = parse_collectives(hlo)
        assert st_.counts["all-gather"] == 1
        assert st_.bytes_by_kind["all-gather"] == (8 + 16) * 4 // 2

    def test_ignores_non_collectives(self):
        st_ = parse_collectives("%m = f32[8,8]{1,0} dot(%a, %b)")
        assert st_.total_bytes == 0
