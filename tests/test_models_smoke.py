"""Per-architecture smoke tests: reduced variant (<=2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU, asserting output shapes
and the absence of NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.configs.registry import ASSIGNED
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import make_train_step

ARCHS = list(all_configs())


def _setup(name):
    cfg = get_config(name).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, L = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                                     cfg.vocab_size),
    }
    if cfg.arch_type == "vlm":
        batch["ctx_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_ctx_tokens, cfg.d_model)) * 0.1
    if cfg.arch_type == "audio":
        batch["ctx_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return cfg, mesh, dims, model, params, batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name):
    cfg, mesh, dims, model, params, batch = _setup(name)
    logits, aux = jax.jit(
        lambda p, b: model.forward(p, b, mesh=mesh, dims=dims))(params,
                                                                batch)
    B, L = batch["tokens"].shape
    assert logits.shape == (B, L, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert np.isfinite(float(aux["aux_loss"]))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg, mesh, dims, model, params, batch = _setup(name)
    step = jax.jit(make_train_step(model, mesh, dims,
                                   AdamWConfig(lr=1e-3, warmup_steps=1,
                                               total_steps=10)))
    opt = adamw_init(params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(p2)[0]
    assert not np.array_equal(np.asarray(before), np.asarray(after))
    # no NaNs anywhere in updated params
    for leaf in jax.tree.leaves(p2):
        assert not np.isnan(np.asarray(leaf, np.float32)).any()


def test_assigned_list_complete():
    assert len(ASSIGNED) == 10
    expected = {"yi-9b", "mistral-nemo-12b", "llama4-scout-17b-a16e",
                "hymba-1.5b", "llama-3.2-vision-11b", "whisper-tiny",
                "xlstm-350m", "command-r-35b", "qwen3-moe-30b-a3b",
                "qwen1.5-0.5b"}
    assert set(ASSIGNED) == expected


@pytest.mark.parametrize("name", ASSIGNED)
def test_full_config_matches_assignment(name):
    """Exact assigned hyperparameters (spot: layer/width/head/vocab)."""
    spec = {
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    }[name]
    cfg = get_config(name)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec
    if name == "llama4-scout-17b-a16e":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 1
    if name == "qwen3-moe-30b-a3b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if name == "hymba-1.5b":
        assert cfg.ssm_state == 16
