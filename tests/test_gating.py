"""Property + unit tests for the top-k gate, dispatch and combine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.gating import (GateConfig, capacity, combine, dispatch,
                               topk_gate)


def _gate(S=64, M=16, E=8, k=2, f=2.0, seed=0, cap=None):
    cfg = GateConfig(n_experts=E, top_k=k, capacity_factor=f)
    rng = jax.random.PRNGKey(seed)
    x = jax.random.normal(rng, (S, M))
    wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, E)) * 0.5
    cap = cap or capacity(S, cfg)
    return cfg, x, wg, cap, topk_gate(x, wg, cfg, cap)


class TestGateInvariants:
    @settings(max_examples=25, deadline=None)
    @given(S=st.sampled_from([8, 32, 64, 128]),
           E=st.sampled_from([2, 4, 8, 16]),
           k=st.integers(1, 4),
           seed=st.integers(0, 10_000))
    def test_invariants(self, S, E, k, seed):
        k = min(k, E)
        cfg, x, wg, cap, (eidx, slot, w, aux) = _gate(
            S=S, E=E, k=k, seed=seed)
        eidx, slot, w = map(np.asarray, (eidx, slot, w))
        # every chosen expert id is valid
        assert ((eidx >= 0) & (eidx < E)).all()
        # per-(token) choices are distinct experts
        for s in range(S):
            assert len(set(eidx[s])) == k
        # per-expert slot occupancy: kept slots are unique and < cap
        kept = slot < cap
        pairs = set()
        for s in range(S):
            for j in range(k):
                if kept[s, j]:
                    assert 0 <= slot[s, j] < cap
                    pair = (int(eidx[s, j]), int(slot[s, j]))
                    assert pair not in pairs, "slot collision"
                    pairs.add(pair)
        # dropped choices have zero combine weight
        assert (np.asarray(w)[~kept] == 0).all()
        # weights are softmax probs: within [0, 1]
        assert (w >= 0).all() and (w <= 1.0 + 1e-6).all()

    @settings(max_examples=20, deadline=None)
    @given(S=st.sampled_from([32, 64, 256]), E=st.sampled_from([4, 16, 128]),
           k=st.integers(1, 8), seed=st.integers(0, 1000))
    def test_sort_impl_equals_cumsum_reference(self, S, E, k, seed):
        """the O(S*k log) sort-based slot assignment (§Perf A1) must be
        bit-identical to the GShard one-hot-cumsum reference."""
        from dataclasses import replace as drep
        k = min(k, E)
        cfg = GateConfig(n_experts=E, top_k=k, capacity_factor=1.2,
                         impl="sort")
        rng = jax.random.PRNGKey(seed)
        x = jax.random.normal(rng, (S, 16))
        wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, E))
        cap = capacity(S, cfg)
        rs = topk_gate(x, wg, cfg, cap)
        rc = topk_gate(x, wg, drep(cfg, impl="cumsum"), cap)
        np.testing.assert_array_equal(np.asarray(rs[0]), np.asarray(rc[0]))
        np.testing.assert_array_equal(np.asarray(rs[1]), np.asarray(rc[1]))
        np.testing.assert_array_equal(np.asarray(rs[2]), np.asarray(rc[2]))

    def test_capacity_formula(self):
        cfg = GateConfig(n_experts=8, top_k=2, capacity_factor=1.5)
        # T = k*f*tokens/E, 8-aligned
        assert capacity(64, cfg) == 24
        assert capacity(8, cfg) >= 8

    def test_priority_first_choice_wins(self):
        # with capacity 8-aligned minimum, first choices of early tokens
        # must never be dropped while a 2nd choice of the same expert kept
        cfg, x, wg, cap, (eidx, slot, w, aux) = _gate(S=256, E=2, k=2, f=0.5)
        eidx, slot = np.asarray(eidx), np.asarray(slot)
        kept = slot < cap
        # choice-major priority: if any first choice dropped for expert e,
        # no second choice for e may be kept
        for e in range(2):
            first_dropped = ((eidx[:, 0] == e) & ~kept[:, 0]).any()
            second_kept = ((eidx[:, 1] == e) & kept[:, 1]).any()
            assert not (first_dropped and second_kept)

    def test_normalize_topk(self):
        cfg = GateConfig(n_experts=8, top_k=4, capacity_factor=4.0,
                         normalize_topk=True)
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        wg = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        _, slot, w, _ = topk_gate(x, wg, cfg, capacity(32, cfg))
        keep = np.asarray(slot) < capacity(32, cfg)
        sums = np.asarray(w).sum(1)
        np.testing.assert_allclose(sums[keep.all(1)], 1.0, rtol=1e-5)


class TestDispatchCombine:
    def test_roundtrip_identity(self):
        """dispatch then combine with weight 1 reproduces kept tokens."""
        cfg, x, wg, cap, (eidx, slot, w, aux) = _gate(S=64, E=8, k=1, f=4.0)
        buf = dispatch(x, eidx, slot, cap, 8)
        ones = jnp.ones_like(w)
        y = combine(buf, eidx, slot, ones, cap)
        kept = np.asarray(slot)[:, 0] < cap
        np.testing.assert_allclose(np.asarray(y)[kept],
                                   np.asarray(x)[kept], rtol=1e-6)

    def test_dropped_tokens_zero(self):
        cfg, x, wg, cap, (eidx, slot, w, aux) = _gate(S=512, E=2, k=1,
                                                      f=0.1)
        buf = dispatch(x, eidx, slot, cap, 2)
        y = combine(buf, eidx, slot, w, cap)
        dropped = np.asarray(slot)[:, 0] >= cap
        assert dropped.any()
        np.testing.assert_allclose(np.asarray(y)[dropped], 0.0, atol=1e-7)

    def test_gradients_flow(self):
        cfg, x, wg, cap, _ = _gate(S=32, E=4, k=2, f=4.0)

        def loss(x, wg):
            eidx, slot, w, aux = topk_gate(x, wg, cfg, cap)
            buf = dispatch(x, eidx, slot, cap, 4)
            y = combine(buf * 2.0, eidx, slot, w, cap)
            return jnp.sum(y ** 2) + aux["aux_loss"]

        gx, gw = jax.grad(loss, argnums=(0, 1))(x, wg)
        assert np.isfinite(np.asarray(gx)).all()
        assert np.isfinite(np.asarray(gw)).all()
        assert float(jnp.abs(gx).sum()) > 0
        assert float(jnp.abs(gw).sum()) > 0

    def test_aux_loss_balanced_lower(self):
        """uniform routing must give lower aux loss than collapsed."""
        cfg = GateConfig(n_experts=4, top_k=1)
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
        wg_uniform = jnp.zeros((16, 4))
        wg_collapse = jnp.zeros((16, 4)).at[:, 0].set(5.0)
        cap = capacity(256, cfg)
        _, _, _, aux_u = topk_gate(x, wg_uniform, cfg, cap)
        _, _, _, aux_c = topk_gate(x, wg_collapse, cfg, cap)
        assert float(aux_u["aux_loss"]) < float(aux_c["aux_loss"])
