"""Serving-engine tests: continuous-batching parity against the
single-request loop (bitwise, greedy), KV-slot lifecycle, one-call
prefill regression, decode-vs-training autosched cache separation, the
sampler contract, and the multi-device smoke (subprocess)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_env
from repro.configs.base import ModelConfig
from repro.core import autosched
from repro.core import plan as planlib
from repro.core.moe import MoEConfig, shard_pool_capacity
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.serve import Engine, KVCachePool, SamplerConfig, sample
from repro.serve.engine import latency_stats, suggest_max_batch


def tiny_moe_cfg():
    return ModelConfig(
        name="serve-test-moe", arch_type="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=128, rope_theta=1e4,
        moe=MoEConfig(d_model=64, d_ff=96, n_experts=4, top_k=2,
                      capacity_factor=2.0, schedule="auto"),
        moe_period=1, remat=False)


def tiny_dense_cfg():
    return ModelConfig(
        name="serve-test-dense", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128, rope_theta=1e4,
        qkv_bias=True, tie_embeddings=True, remat=False)


def _mesh_dims(cfg):
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))
    return mesh, dims


@pytest.fixture(autouse=True)
def fresh_sched_cache():
    autosched.clear_cache()
    yield
    autosched.clear_cache()


@pytest.fixture(scope="module")
def moe_setup():
    cfg = tiny_moe_cfg()
    model = build_model(cfg)
    mesh, dims = _mesh_dims(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, mesh, dims, params


@pytest.fixture(scope="module")
def dense_setup():
    cfg = tiny_dense_cfg()
    model = build_model(cfg)
    mesh, dims = _mesh_dims(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, mesh, dims, params


def _prompts(cfg, spec, seed=0):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, plen), gen)
            for plen, gen in spec]


class TestKVCachePool:
    """The paged pool through the slab pool's old admission surface:
    rows still hand out lowest-free-first, release still recycles, and
    the arena is sized slab-equivalent by default.  (Deep allocator /
    prefix-cache properties live in tests/test_kvcache.py.)"""

    def _pool(self, dense_setup, n=3):
        _, model, _, _, _ = dense_setup
        return KVCachePool(model, n, 16, prefix_cache=False)

    def test_alloc_is_lowest_free_row_first(self, dense_setup):
        pool = self._pool(dense_setup)
        assert [pool.alloc(i)[0] for i in range(3)] == [0, 1, 2]

    def test_release_recycles_row_and_blocks(self, dense_setup):
        pool = self._pool(dense_setup)
        for i in range(3):
            pool.alloc(i, (1, 2, 3), max_new=4)
            pool.ensure(i, 2)
        assert not pool.can_admit()
        held = pool.table_of(1)
        assert pool.release(1) == 1
        assert pool.can_admit() and pool.n_free == 1
        assert set(held) <= set(pool.drain_freed())    # pages recycled
        row, shared = pool.alloc("new")
        assert (row, shared) == (1, 0)                 # evicted row reused

    def test_exhaustion_and_double_alloc_raise(self, dense_setup):
        pool = self._pool(dense_setup)
        for i in range(3):
            pool.alloc(i)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.alloc("overflow")
        pool.release(0)
        with pytest.raises(KeyError, match="already holds"):
            pool.alloc(1)
        with pytest.raises(KeyError, match="no row"):
            pool.release("never-seen")

    def test_cache_layout_is_paged(self, dense_setup):
        _, model, _, _, _ = dense_setup
        pool = KVCachePool(model, 4, 16, block_size=8)
        assert pool.n_blocks == 4 * 2                  # slab-equivalent
        for leaf in jax.tree.leaves(pool.cache):
            assert leaf.shape[1] == pool.n_blocks + 1  # +1 null block
            if leaf.ndim >= 3:
                assert leaf.shape[2] == 8

    def test_max_len_must_divide_into_blocks(self, dense_setup):
        _, model, _, _, _ = dense_setup
        with pytest.raises(ValueError, match="not divisible"):
            KVCachePool(model, 2, 24, block_size=16)


class TestSampler:
    def test_greedy_is_argmax(self):
        logits = jnp.array(np.random.RandomState(0).randn(3, 50),
                           jnp.float32)
        keys = np.zeros((3, 2), np.uint32)
        out = sample(logits, keys, jnp.zeros(3), jnp.zeros(3, jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.argmax(np.asarray(logits), -1))

    def test_topk_never_escapes_the_top_k(self):
        rng = np.random.RandomState(1)
        logits = jnp.array(rng.randn(8, 64), jnp.float32)
        top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
        for trial in range(5):
            keys = rng.randint(0, 2**31, (8, 2)).astype(np.uint32)
            out = np.asarray(sample(
                logits, keys, jnp.full(8, 0.8), jnp.full(8, 4, jnp.int32)))
            for b in range(8):
                assert out[b] in top4[b]

    def test_same_key_same_draw(self):
        logits = jnp.array(np.random.RandomState(2).randn(4, 32),
                           jnp.float32)
        keys = np.arange(8, dtype=np.uint32).reshape(4, 2)
        a = sample(logits, keys, jnp.full(4, 1.0), jnp.zeros(4, jnp.int32))
        b = sample(logits, keys, jnp.full(4, 1.0), jnp.zeros(4, jnp.int32))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_config_bounds(self):
        with pytest.raises(ValueError):
            SamplerConfig(top_k=4096)
        assert SamplerConfig().greedy
        assert not SamplerConfig(temperature=0.7).greedy


class TestEngineParity:
    """The acceptance criterion: engine decode output is bitwise the
    single-request greedy loop's, with concurrent requests of different
    lengths joining and leaving the batch mid-run."""

    SPEC = [(9, 12), (5, 6), (13, 4)]

    def test_concurrent_bitwise_matches_solo(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        reqs = _prompts(cfg, self.SPEC)

        eng = Engine(model, mesh, dims, max_batch=4, max_len=64)
        for prompt, gen in reqs:
            eng.submit(prompt, gen)
        conc = {c.rid: c.tokens for c in eng.run(params)}
        # different lengths, joining AND leaving mid-run
        assert eng.stats["max_active"] >= 2
        assert eng.stats["decode_calls"] > 0

        for rid, (prompt, gen) in enumerate(reqs):
            solo = Engine(model, mesh, dims, max_batch=4, max_len=64)
            solo.submit(prompt, gen)
            (c,) = solo.run(params)
            assert c.tokens == conc[rid], \
                f"request {rid} diverged under batching"

    def test_dense_arch_parity(self, dense_setup):
        cfg, model, mesh, dims, params = dense_setup
        reqs = _prompts(cfg, [(7, 8), (11, 5)])
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        for prompt, gen in reqs:
            eng.submit(prompt, gen)
        conc = {c.rid: c.tokens for c in eng.run(params)}
        assert eng.stats["max_active"] == 2
        for rid, (prompt, gen) in enumerate(reqs):
            solo = Engine(model, mesh, dims, max_batch=2, max_len=64)
            solo.submit(prompt, gen)
            (c,) = solo.run(params)
            assert c.tokens == conc[rid]


class TestEngineLifecycle:
    def test_prefill_is_one_call_not_prompt_len(self, moe_setup):
        """Regression for the seed serve loop, which stepped the prompt
        one token at a time: prefill must be ONE jitted call."""
        cfg, model, mesh, dims, params = moe_setup
        (prompt, gen), = _prompts(cfg, [(17, 5)])
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        eng.submit(prompt, gen)
        (c,) = eng.run(params)
        assert eng.stats["prefill_calls"] == 1
        assert eng.stats["prefill_tokens"] == 17
        assert eng.stats["decode_calls"] == gen - 1
        assert len(c.tokens) == gen

    def test_more_requests_than_slots(self, moe_setup):
        """Queueing + slot eviction: 5 requests over 2 slots."""
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        reqs = _prompts(cfg, [(6, 4), (9, 3), (5, 5), (8, 2), (7, 4)])
        for prompt, gen in reqs:
            eng.submit(prompt, gen)
        done = eng.run(params)
        assert len(done) == 5
        assert eng.stats["max_active"] == 2     # never over capacity
        assert eng.pool.n_live == 0             # all slots evicted
        assert eng.pool.n_free == 2
        assert [len(c.tokens) for c in done] == [g for _, g in reqs]

    def test_eos_finishes_early_and_frees_slot(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        (prompt, _), = _prompts(cfg, [(9, 8)])
        ref = Engine(model, mesh, dims, max_batch=2, max_len=64)
        ref.submit(prompt, 8)
        (c,) = ref.run(params)
        eos = c.tokens[2]
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64,
                     eos_token=eos)
        eng.submit(prompt, 8)
        (c2,) = eng.run(params)
        stop = c.tokens.index(eos) + 1          # first occurrence wins
        assert c2.tokens == c.tokens[:stop]     # stops AT the eos token
        assert c2.tokens[-1] == eos and len(c2.tokens) < 8
        assert eng.pool.n_live == 0

    def test_admission_control_rejects_oversized(self, moe_setup):
        cfg, model, mesh, dims, _ = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=32)
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.submit(list(range(20)), 16)
        with pytest.raises(ValueError, match="empty"):
            eng.submit([], 4)

    def test_unsupported_arch_rejected(self):
        from repro.configs import get_config
        cfg = get_config("xlstm-350m").reduced()
        model = build_model(cfg)
        mesh, dims = _mesh_dims(cfg)
        with pytest.raises(NotImplementedError, match="dense/moe"):
            Engine(model, mesh, dims)

    def test_temperature_sampling_serves(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        (prompt, gen), = _prompts(cfg, [(8, 6)])
        eng.submit(prompt, gen,
                   sampler=SamplerConfig(temperature=0.9, top_k=8, seed=7))
        (c,) = eng.run(params)
        assert len(c.tokens) == gen
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)

    def test_latency_stats_shape(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        for prompt, gen in _prompts(cfg, [(6, 3), (7, 3)]):
            eng.submit(prompt, gen)
        stats = latency_stats(eng.run(params))
        assert stats["n_requests"] == 2 and stats["n_tokens"] == 6
        for k in ("tok_per_s", "p50_ms", "p95_ms", "p99_ms",
                  "ttft_p50_ms"):
            assert stats[k] > 0


class TestPrefixAndChunk:
    """PR 7 satellites: shared-prefix hits and chunked prefill must not
    move a single bit of the served streams, while the stats must show
    the work actually being saved / split."""

    def _serve(self, moe_setup, spec_prompts, **kw):
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64,
                     schedule="s1", **kw)
        for prompt, gen in spec_prompts:
            eng.submit(prompt, gen)
        return {c.rid: c.tokens for c in eng.run(params)}, eng

    def _shared_prompts(self, cfg, n_shared=37, tails=(3, 5, 2), seed=3):
        rng = np.random.RandomState(seed)
        sysp = list(rng.randint(1, cfg.vocab_size, n_shared))
        return [(sysp + list(rng.randint(1, cfg.vocab_size, t)), 6)
                for t in tails]

    def test_prefix_hit_is_bitwise_cold(self, moe_setup):
        cfg = moe_setup[0]
        reqs = self._shared_prompts(cfg)
        cold, cold_eng = self._serve(moe_setup, reqs, prefix_cache=False)
        hot, hot_eng = self._serve(moe_setup, reqs, prefix_cache=True)
        assert cold == hot
        assert hot_eng.stats["prefix_hits"] == 2       # 2nd + 3rd request
        assert hot_eng.stats["prefix_tokens"] == 2 * 32  # 2 full blocks
        # the shared prefix is computed ONCE: later admissions prefill
        # only their suffix
        assert (hot_eng.stats["prefill_tokens"]
                < cold_eng.stats["prefill_tokens"])
        assert cold_eng.stats["prefix_hits"] == 0

    def test_chunked_prefill_is_bitwise_one_shot(self, moe_setup):
        cfg = moe_setup[0]
        reqs = self._shared_prompts(cfg)
        one, one_eng = self._serve(moe_setup, reqs, prefix_cache=False)
        chk, chk_eng = self._serve(moe_setup, reqs, prefix_cache=False,
                                   prefill_chunk=8)
        assert one == chk
        assert chk_eng.stats["prefill_calls"] \
            > one_eng.stats["prefill_calls"]
        assert chk_eng.stats["prefill_tokens"] \
            == one_eng.stats["prefill_tokens"]

    def test_engine_refuses_eviction_of_held_prefix(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64,
                     schedule="s1")
        (prompt, gen), = self._shared_prompts(cfg, tails=(3,))
        eng.submit(prompt, gen)
        while not eng.active:                  # prefill + first sample
            eng.step(params)
        key = max(eng.pool.prefix.keys(), key=len)   # deepest entry
        with pytest.raises(RuntimeError, match="refused"):
            eng.pool.prefix.evict(key)
        while eng.active:
            eng.step(params)
        eng.pool.prefix.evict(key)             # released -> evictable

    def test_stats_regressions(self, moe_setup):
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        for prompt, gen in _prompts(cfg, [(6, 4), (9, 3), (5, 5)]):
            eng.submit(prompt, gen)
        eng.run(params)
        s = eng.stats
        assert set(s) >= {"prefix_hits", "prefix_tokens", "peak_blocks"}
        assert 0 < s["peak_blocks"] <= eng.pool.n_blocks
        assert eng.pool.occupancy() == 0.0     # drained after the run
        assert eng.pool.n_free_blocks == eng.pool.n_blocks


class TestPagedParity:
    """Tentpole oracle: the paged engine vs PR 5's frozen slab engine
    (tests/helpers/legacy_kvcache.py), token-for-token, via the
    subprocess harness (controlled device counts)."""

    def test_paged_parity_trace(self, helpers_dir):
        r = subprocess.run(
            [sys.executable, os.path.join(helpers_dir,
                                          "run_paged_parity.py"), "trace"],
            env=subprocess_env(1), capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "PAGED PARITY OK" in r.stdout

    def test_paged_parity_multidev(self, helpers_dir):
        r = subprocess.run(
            [sys.executable, os.path.join(helpers_dir,
                                          "run_paged_parity.py"),
             "multidev"],
            env=subprocess_env(8), capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "PAGED PARITY OK" in r.stdout


class TestDecodeAutosched:
    """Satellite: decode decisions must never evict/overwrite training
    decisions, and the decode grid must carry the decode-only plans."""

    def _shape(self, **kw):
        from repro.core.perfmodel import MoELayerShape
        base = dict(B=8, L=1, M=256, H=512, E=8, k=2, f=1.25,
                    n_mp=2, n_esp=2, n_ep=2)
        base.update(kw)
        return MoELayerShape(**base)

    def test_decode_and_train_cache_lines_are_distinct(self):
        from repro.core.perfmodel import AlphaBeta, PerfModel
        ab = AlphaBeta(1e-5, 1e-9)
        pm = PerfModel(ab, ab, ab, ab, ab, ab, flops_per_s=1e12)
        train = autosched.decide(self._shape(), perf_model=pm)
        decode = autosched.decide(self._shape(infer=True), perf_model=pm)
        assert len(autosched.cache_info()) == 2
        # the training entry survives the decode decision untouched
        assert autosched.decide(self._shape(), perf_model=pm) is train
        assert autosched.decide(self._shape(infer=True),
                                perf_model=pm) is decode
        # only the decode grid scored the decode-dedicated plan
        assert not any(c[0] == "s1d" for c, _ in train.times)
        assert any(c[0] == "s1d" for c, _ in decode.times)
        # the summary tags the decode class
        assert "decode" in autosched.cache_summary()

    def test_registry_flags(self):
        assert "s1d" not in planlib.analytic_schedules()
        assert "s1d" in planlib.analytic_schedules(infer=True)
        assert "s1d" not in planlib.measured_schedules()
        assert "s1d" in planlib.measured_schedules(infer=True)
        assert planlib.PLANS["s1d"].decode_only

    def test_decode_grid_pins_one_chunk(self, moe_setup):
        """apply_moe's decode decisions must never ask for capacity
        chunking (the per-chunk alphas dominate at decode sizes)."""
        cfg, model, mesh, dims, params = moe_setup
        eng = Engine(model, mesh, dims, max_batch=4, max_len=64)
        (prompt, gen), = _prompts(cfg, [(9, 3)])
        eng.submit(prompt, gen)
        eng.run(params)
        decode_entries = [d for key, d in autosched.cache_info().items()
                          if getattr(key[0], "infer", False)]
        assert decode_entries, "decode decision never cached"
        assert all(d.n_chunks == 1 for d in decode_entries)

    def test_decode_capacity_is_drop_free(self):
        from repro.core.gating import GateConfig
        g = GateConfig(n_experts=16, top_k=1, capacity_factor=0.5)
        s, cap_train = shard_pool_capacity(64, 1, 1, g)
        _, cap_decode = shard_pool_capacity(64, 1, 1, g, infer=True)
        assert cap_train < 64           # training capacity really drops
        assert cap_decode >= 64         # decode never drops a token

    def test_t_decode_and_bucket_sizing(self):
        from repro.core.perfmodel import tpu_v5e_model
        pm = tpu_v5e_model(2, 2, 2)
        t1 = pm.t_decode(self._shape(B=1, infer=True))
        t8 = pm.t_decode(self._shape(B=8, infer=True))
        assert 0 < t1 <= t8             # more tokens never get cheaper
        cfg = tiny_moe_cfg()
        b = suggest_max_batch(cfg, n_ep=2, n_esp=2, n_mp=2)
        assert b in (1, 2, 4, 8, 16, 32)
        # alpha-dominated decode: batching always beats B=1 throughput
        assert b > 1


class TestMultiDevice:
    def test_serve_multidev_smoke(self, helpers_dir):
        r = subprocess.run(
            [sys.executable, os.path.join(helpers_dir,
                                          "run_serve_multidev.py")],
            env=subprocess_env(8), capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "SERVE MULTIDEV OK" in r.stdout


class TestServeRobustness:
    """PR 8: deadlines, shedding, the decode watchdog, fault injection,
    and the chaos contract — injected faults only ever touch their
    target request; everything else finishes bitwise identical to a
    fault-free run."""

    def _engine(self, moe_setup, **kw):
        _, model, mesh, dims, _ = moe_setup
        kw.setdefault("max_batch", 4)
        kw.setdefault("max_len", 64)
        kw.setdefault("prefix_cache", False)
        return Engine(model, mesh, dims, **kw)

    def _run(self, moe_setup, n=4, gen=6, **kw):
        cfg, _, _, _, params = moe_setup
        eng = self._engine(moe_setup, **kw)
        for prompt, g in _prompts(cfg, [(6, gen)] * n, seed=11):
            eng.submit(prompt, g)
        done = sorted(eng.run(params), key=lambda c: c.rid)
        return eng, done

    def test_chaos_unaffected_requests_bitwise(self, moe_setup):
        """req 1 force-expired, req 2 stalled into the watchdog, the
        arena starved for 4 ticks — reqs 0 and 3 must still produce the
        exact fault-free token streams, and the allocator must balance."""
        from repro.runtime import FaultPlan
        _, ref = self._run(moe_setup)
        faults = FaultPlan.parse(
            "req_timeout@rid=1,ticks=3;req_delay@rid=2,rounds=999;"
            "alloc_starve@tick=1,hold=9999,rounds=4")
        eng, done = self._run(moe_setup, faults=faults, watchdog_rounds=5)
        by = {c.rid: c for c in done}
        assert by[1].status == "expired" and "tick" in by[1].reason
        assert by[2].status == "evicted" and "watchdog" in by[2].reason
        for rid in (0, 3):
            assert by[rid].status == "ok"
            assert by[rid].tokens == ref[rid].tokens
        assert eng.stats["expired"] == 1 and eng.stats["evicted"] == 1
        eng.pool.alloc_blocks.check()
        assert eng.pool.n_live == 0          # cancelled pages all freed

    def test_deadline_expiry_frees_pages(self, moe_setup):
        """A wall-clock deadline of ~0 expires every request mid-flight;
        their pages return to the arena."""
        cfg, _, _, _, params = moe_setup
        eng = self._engine(moe_setup)
        for prompt, g in _prompts(cfg, [(6, 8)] * 3, seed=2):
            eng.submit(prompt, g, deadline=1e-6)
        done = eng.run(params)
        assert len(done) == 3
        assert all(c.status == "expired" for c in done)
        assert all("deadline" in c.reason for c in done)
        eng.pool.alloc_blocks.check()
        assert eng.pool.n_live == 0

    def test_infeasible_request_shed_at_admission(self, moe_setup):
        """A request whose worst-case page need exceeds the whole arena
        is shed immediately (it could never be admitted) — with a reason,
        not a hang."""
        cfg, _, _, _, params = moe_setup
        eng = self._engine(moe_setup, max_batch=2, max_len=64,
                           n_blocks=2, block_size=16)
        # passes the max_len check but could never fit the 2-page arena
        rid_big = eng.submit(list(range(1, 7)), 40)
        rid_ok = eng.submit(list(range(1, 7)), 4)
        done = {c.rid: c for c in eng.run(params)}
        assert done[rid_big].status == "shed"
        assert done[rid_big].reason.startswith("blocks")
        assert done[rid_ok].status == "ok" and done[rid_ok].tokens
        assert eng.stats["shed_blocks"] == 1

    def test_queue_slo_sheds_waiting_request(self, moe_setup):
        """With the pool pinned full and a ~0 queue SLO, a waiting
        request is shed instead of backpressuring forever."""
        cfg, _, _, _, params = moe_setup
        eng = self._engine(moe_setup, max_batch=1, max_len=64,
                           queue_slo=1e-6)
        prompts = _prompts(cfg, [(6, 8), (6, 8)], seed=4)
        for prompt, g in prompts:
            eng.submit(prompt, g)
        done = sorted(eng.run(params), key=lambda c: c.rid)
        statuses = sorted(c.status for c in done)
        assert statuses == ["ok", "shed"]
        shed = next(c for c in done if c.status == "shed")
        assert shed.reason.startswith("queue")
        assert eng.stats["shed_queue"] == 1

    def test_starvation_recovers(self, moe_setup):
        """Allocator starvation (blocks held hostage for a few ticks)
        delays admission but loses nothing: every request completes ok
        once the blocks come back."""
        from repro.runtime import FaultPlan
        faults = FaultPlan.parse("alloc_starve@tick=1,hold=9999,rounds=3")
        eng, done = self._run(moe_setup, n=3, faults=faults)
        assert [c.status for c in done] == ["ok"] * 3
        assert all(c.tokens for c in done)
        eng.pool.alloc_blocks.check()

    def test_latency_stats_total_function(self, moe_setup):
        """Hardened latency_stats: empty, all-shed, and single-sample
        inputs all yield the full key set without dividing by zero."""
        from repro.serve.engine import Completion

        keys = {"n_requests", "n_tokens", "tok_per_s", "p50_ms", "p95_ms",
                "p99_ms", "ttft_p50_ms", "ttft_p99_ms", "n_shed",
                "n_cancelled"}
        empty = latency_stats([])
        assert set(empty) == keys and empty["n_requests"] == 0
        assert empty["tok_per_s"] == 0.0

        shed = Completion(rid=0, prompt=(), tokens=[], text="",
                          timing={"queued": 0.1}, status="shed",
                          reason="blocks")
        s = latency_stats([shed])
        assert s["n_shed"] == 1 and s["n_requests"] == 0

        one = Completion(rid=1, prompt=(1,), tokens=[5, 6], text="",
                         timing={"latency": 0.2, "ttft": 0.05,
                                 "queued": 0.0})
        s1 = latency_stats([one, shed])
        assert s1["n_requests"] == 1 and s1["n_tokens"] == 2
        assert s1["p50_ms"] == s1["p99_ms"] == pytest.approx(200.0)
        assert s1["ttft_p50_ms"] == pytest.approx(50.0)

        evicted = Completion(rid=2, prompt=(1,), tokens=[7], text="",
                             timing={"latency": 0.3, "queued": 0.0},
                             status="evicted", reason="watchdog")
        s2 = latency_stats([one, shed, evicted])
        assert s2["n_cancelled"] == 1
        assert s2["n_requests"] == 1          # evicted never pollutes p50
