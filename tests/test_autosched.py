"""Autoscheduler runtime tests: decision caching, determinism under a
fixed perf model, measured-mode plumbing, and the body-name mapping."""

import pytest

from repro.core import autosched
from repro.core.autosched import ScheduleDecision, decide
from repro.core.perfmodel import AlphaBeta, MoELayerShape, PerfModel


def toy_model(beta=1e-9, alpha=1e-5, flops=1e12):
    ab = AlphaBeta(alpha, beta)
    return PerfModel(a2a_ep_esp=ab, a2a_ep=ab, ag_esp=ab, ar_esp=ab,
                     ag_mp=AlphaBeta(alpha, beta / 4), overlap=ab,
                     flops_per_s=flops)


def shape(**kw):
    base = dict(B=4, L=1024, M=1024, H=4096, E=8, k=2, f=1.2,
                n_mp=2, n_esp=2, n_ep=2)
    base.update(kw)
    return MoELayerShape(**base)


@pytest.fixture(autouse=True)
def fresh_cache():
    autosched.clear_cache()
    yield
    autosched.clear_cache()


class TestAnalytic:
    def test_decision_is_argmin_of_perf_model(self):
        """The analytic grid is the plan registry x chunk candidates,
        scored by walking each candidate's plan graph (t_plan)."""
        from repro.core import plan as planlib
        pm = toy_model()
        s = shape()
        d = decide(s, perf_model=pm)
        cands = {(sc, n): pm.t_plan(planlib.plan_for_shape(sc, s, n), s)
                 for sc in planlib.analytic_schedules()
                 for n in (1, 2, 4, 8)}
        best = min(cands, key=cands.get)
        assert cands[(d.schedule, d.n_chunks)] == cands[best]
        assert d.source == "analytic"
        # times are ranked fastest-first and cover every candidate
        assert len(d.times) == len(cands)
        assert [t for _, t in d.times] == sorted(t for _, t in d.times)

    def test_registered_schedule_joins_the_grid(self):
        """Satellite acceptance: registering a plan makes it a candidate
        without touching autosched."""
        from repro.core import plan as planlib
        assert "s2h" in planlib.analytic_schedules()
        d = decide(shape(), perf_model=toy_model())
        assert any(c[0] == "s2h" for c, _ in d.times)
        d2 = decide(shape(L=512), perf_model=toy_model(), mode="measured",
                    measure=lambda cands: {c: 1.0 for c in cands})
        assert any(c[0] == "s2h" for c, _ in d2.times)

    def test_late_registration_invalidates_default_grid(self):
        """The cache key carries the resolved schedule grid: a plan
        registered AFTER a cached decision must still be scored on the
        next decide() for the same shape."""
        from repro.core import plan as planlib
        pm = toy_model()
        d1 = decide(shape(), perf_model=pm)
        assert not any(c[0] == "s1_late" for c, _ in d1.times)
        planlib.register_plan(
            "s1_late", lambda i: planlib.PLANS["s1"].builder(i),
            analytic=True, measured=False)
        try:
            d2 = decide(shape(), perf_model=pm)
            assert any(c[0] == "s1_late" for c, _ in d2.times)
        finally:
            planlib.PLANS.pop("s1_late", None)

    def test_cached_and_deterministic(self):
        pm = toy_model()
        d1 = decide(shape(), perf_model=pm)
        assert len(autosched.cache_info()) == 1
        d2 = decide(shape(), perf_model=pm)
        assert d2 is d1                     # cache hit, not a recompute
        assert decide(shape(), perf_model=toy_model()) == d1  # equal model

    def test_distinct_shapes_get_distinct_entries(self):
        pm = toy_model()
        decide(shape(), perf_model=pm)
        decide(shape(L=2048), perf_model=pm)
        assert len(autosched.cache_info()) == 2

    def test_compute_bound_layer_prefers_chunks(self):
        """Slow chips + cheap startup: overlap wins, n_chunks > 1."""
        pm = toy_model(alpha=1e-9, flops=1e11)
        d = decide(shape(), perf_model=pm)
        assert d.n_chunks > 1

    def test_latency_bound_layer_stays_unchunked(self):
        """Huge per-collective startup: chunking only adds alphas."""
        pm = toy_model(alpha=1.0, flops=1e18)
        d = decide(shape(), perf_model=pm)
        assert d.n_chunks == 1

    def test_clear_cache(self):
        decide(shape(), perf_model=toy_model())
        autosched.clear_cache()
        assert autosched.cache_info() == {}

    def test_cache_summary_mentions_pick(self):
        d = decide(shape(), perf_model=toy_model())
        s = autosched.cache_summary()
        assert d.schedule in s and "analytic" in s
        # exclude filters pre-existing keys (multi-model processes)
        assert autosched.cache_summary(
            exclude=set(autosched.cache_info())) == ""

    def test_pick_chunks_is_t_pipelined_argmin(self):
        """The per-schedule chunk picker must agree with the scores
        decide() ranks (keeps the two argmins from drifting apart)."""
        pm = toy_model(alpha=1e-9, flops=1e11)
        s = shape()
        for sched in ("baseline", "s1", "s2"):
            n = pm.pick_chunks(s, sched, (1, 2, 4, 8))
            assert n == min((1, 2, 4, 8),
                            key=lambda c: pm.t_pipelined(s, sched, c))


class TestMeasured:
    def test_measured_uses_injected_times_and_caches(self):
        calls = []

        def fake_measure(cands):
            calls.append(list(cands))
            # make (s2, 4) the clear winner
            return {c: (0.001 if c == ("s2", 4) else 1.0) for c in cands}

        d = decide(shape(), perf_model=toy_model(), mode="measured",
                   measure=fake_measure)
        assert (d.schedule, d.n_chunks) == ("s2", 4)
        assert d.source == "measured"
        # second call hits the cache: measure not re-invoked
        d2 = decide(shape(), perf_model=toy_model(), mode="measured",
                    measure=fake_measure)
        assert d2 is d and len(calls) == 1
        # baseline is a measured-mode candidate (it can win on-box)
        assert any(s == "baseline" for s, _ in calls[0])

    def test_measured_requires_measure(self):
        with pytest.raises(ValueError):
            decide(shape(), mode="measured")

    def test_measured_calibration_runs_inside_jit_trace(self):
        """The real regression: apply_moe usually hits decide() while
        train_step is being TRACED; the calibration must still execute
        eagerly (worker thread) and record finite candidate times."""
        import jax

        from repro.core.moe import MoEConfig, apply_moe, init_moe_params
        from repro.parallel.mesh import ParallelDims, make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                        capacity_factor=2.0, schedule="auto",
                        autosched="measured")
        params = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
        y, _ = jax.jit(lambda x, p: apply_moe(
            x, p, mesh=mesh, dims=dims, cfg=cfg))(x, params)
        assert y.shape == x.shape
        (d,) = autosched.cache_info().values()
        assert d.source == "measured"
        best_time = d.times[0][1]
        assert best_time < float("inf")    # candidates actually ran

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            decide(shape(), mode="vibes")


class TestBodyName:
    def test_body_name_maps_to_pipe(self):
        assert ScheduleDecision("s1", 4).body_name == "s1_pipe"
        assert ScheduleDecision("s1", 1).body_name == "s1"
        assert ScheduleDecision("baseline", 2).body_name == "baseline_pipe"

    def test_select_schedule_matches_decide(self):
        from repro.core.moe import MoEConfig, select_schedule
        pm = toy_model()
        s = shape()
        cfg = MoEConfig(d_model=s.M, d_ff=s.H, n_experts=s.E,
                        top_k=s.k, schedule="auto")
        assert select_schedule(cfg, s, pm) == decide(s, perf_model=pm).schedule
        assert select_schedule(
            MoEConfig(d_model=8, d_ff=8, n_experts=2, schedule="s2"),
            s, pm) == "s2"
