"""End-to-end behaviour tests: training convergence, serve loop, and the
Parm auto-schedule integration in a full model."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_env
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import Trainer, make_serve_step


def _mesh_dims(cfg):
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))
    return mesh, dims


class TestTrainingIntegration:
    def test_loss_decreases_moe(self):
        """~120 steps on the synthetic bigram corpus must reduce CE."""
        cfg = get_config("gpt2-moe").reduced()
        mesh, dims = _mesh_dims(cfg)
        model = build_model(cfg)
        tr = Trainer(model, mesh, dims,
                     AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150),
                     schedule="auto")
        params, opt = tr.setup(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=8, n_heavy=4,
                                      heavy_prob=0.9))
        params, opt, hist = tr.run(params, opt, data, 150, log_every=30)
        assert hist[-1]["ce"] < hist[0]["ce"] - 0.25, hist
        assert all(np.isfinite(h["loss"]) for h in hist)

    def test_loss_decreases_dense(self):
        cfg = get_config("qwen1.5-0.5b").reduced()
        mesh, dims = _mesh_dims(cfg)
        model = build_model(cfg)
        tr = Trainer(model, mesh, dims,
                     AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=100))
        params, opt = tr.setup(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=8, n_heavy=4,
                                      heavy_prob=0.9))
        params, opt, hist = tr.run(params, opt, data, 100, log_every=20)
        assert hist[-1]["ce"] < hist[0]["ce"] - 0.3, hist


class TestServeLoop:
    @pytest.mark.parametrize("name", ["qwen1.5-0.5b", "xlstm-350m",
                                      "qwen3-moe-30b-a3b"])
    def test_greedy_decode_runs(self, name):
        cfg = get_config(name).reduced()
        mesh, dims = _mesh_dims(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, T = 2, 12
        cache = model.init_cache(B, T)
        serve = jax.jit(make_serve_step(model, mesh, dims))
        tok = jnp.zeros((B, 1), jnp.int32)
        for t in range(T - 1):
            tok, cache = serve(params, cache,
                               {"tokens": tok, "step": jnp.int32(t)})
            assert tok.shape == (B, 1)
            assert int(tok.max()) < cfg.vocab_size

    def test_decode_matches_prefill_dense(self):
        """Greedy decode over a teacher-forced prompt must match the
        full-sequence forward logits (KV-cache correctness)."""
        cfg = get_config("mistral-nemo-12b").reduced()
        mesh, dims = _mesh_dims(cfg)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, L = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                  cfg.vocab_size)
        logits, _ = jax.jit(lambda p, b: model.forward(
            p, b, mesh=mesh, dims=dims))(params, {"tokens": toks})
        cache = model.init_cache(B, L)
        errs = []
        step_fn = jax.jit(lambda p, c, b: model.decode_step(
            p, c, b, mesh=mesh, dims=dims))
        for t in range(L):
            lg, cache = step_fn(params, cache,
                                {"tokens": toks[:, t:t + 1],
                                 "step": jnp.int32(t)})
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits[:, t]))))
        assert max(errs) < 1e-3, errs


class TestMultiDeviceTraining:
    def test_sharded_training_runs(self, helpers_dir):
        r = subprocess.run(
            [sys.executable, os.path.join(helpers_dir,
                                          "run_sharded_train.py")],
            env=subprocess_env(8), capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "SHARDED TRAIN OK" in r.stdout
