"""Test config. NOTE: no global XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses (tests/helpers/)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


def subprocess_env(n_devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return env


@pytest.fixture
def helpers_dir():
    return os.path.join(os.path.dirname(__file__), "helpers")
