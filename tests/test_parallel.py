"""ParallelDims / sharding-rule / mesh unit tests (1 device, spec-level)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.moe import moe_param_specs
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, axis_size, make_mesh, \
    production_dims


class TestParallelDims:
    def test_merged_detection(self):
        d = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        assert d.merged
        d2 = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        assert not d2.merged

    def test_batch_axes(self):
        merged = ParallelDims(dp=("pod",), ep=("data",), esp=("model",),
                              mp=("model",))
        assert merged.batch_axes == ("pod", "data")
        distinct = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        assert distinct.batch_axes == ("ep", "esp")

    def test_string_coercion(self):
        d = ParallelDims(ep="data", mp="model")
        assert d.ep == ("data",) and d.mp == ("model",)

    def test_production_dims(self):
        moe = production_dims(multi_pod=True, moe=True)
        assert moe.dp == ("pod",) and moe.ep == ("data",)
        assert moe.merged
        dense = production_dims(multi_pod=False, moe=False)
        assert dense.dp == ("data",) and dense.mp == ("model",)

    def test_validate_rejects_bad_axes(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        d = ParallelDims(ep=("nope",))
        with pytest.raises(ValueError):
            d.validate(mesh, 8)


class TestSpecs:
    def test_moe_param_specs_shard_correctly(self):
        cfg = get_config("qwen3-moe-30b-a3b").moe
        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        s = moe_param_specs(cfg, mesh, dims)
        assert s["w1"] == P(("data",), None, ("model",))
        assert s["w2"] == P(("data",), ("model",), None)
        assert s["wg"] == P(None, None)

    def test_model_specs_cover_all_params(self):
        """every param leaf must have a matching spec leaf."""
        for name in ["qwen3-moe-30b-a3b", "hymba-1.5b", "whisper-tiny",
                     "llama-3.2-vision-11b", "xlstm-350m", "command-r-35b"]:
            cfg = get_config(name).reduced()
            mesh = make_mesh((1, 1), ("data", "model"))
            dims = (ParallelDims(ep=("data",), esp=("model",),
                                 mp=("model",)) if cfg.moe
                    else ParallelDims(dp=("data",), mp=("model",)))
            model = build_model(cfg)
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            specs = model.specs(mesh, dims)
            jax.tree.map(lambda a, b: None, shapes, specs,
                         is_leaf=lambda x: isinstance(x, P))  # structure eq

    def test_spec_ranks_match_param_ranks(self):
        cfg = get_config("yi-9b").reduced()
        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(dp=("data",), mp=("model",))
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = model.specs(mesh, dims)

        def check(leaf, spec):
            assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        jax.tree.map(check, shapes, specs,
                     is_leaf=lambda x: isinstance(x, P))


class TestAxisSize:
    def test_axis_size(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        assert axis_size(mesh, ()) == 1
        assert axis_size(mesh, ("data",)) == 1
        assert axis_size(mesh, "model") == 1
