"""Optional-``hypothesis`` shim for the property-based tests.

``from hypothesis_compat import given, settings, st`` behaves exactly like
the real imports when hypothesis is installed; when it is not, property
tests become zero-arg stubs that ``pytest.skip`` at call time (the rest of
the module's plain unit tests still collect and run).  Install the real
thing with ``pip install -r requirements-dev.txt`` (or the ``dev`` extra).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy constructor
        returns None (the values are never drawn — the test is skipped)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            # a fresh zero-fixture stub: pytest must not try to resolve the
            # property-test parameters (S, E, ...) as fixtures
            def stub(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco
