"""Optional-``hypothesis`` shim for the property-based tests.

``from hypothesis_compat import given, settings, st`` behaves exactly like
the real imports when hypothesis is installed; when it is not, property
tests become zero-arg stubs that ``pytest.skip`` at call time (the rest of
the module's plain unit tests still collect and run).  Install the real
thing with ``pip install -r requirements-dev.txt`` (or the ``dev`` extra).

Stateful testing gets the same treatment: ``RuleBasedStateMachine`` /
``rule`` / ``invariant`` / ``precondition`` / ``initialize`` re-export
from ``hypothesis.stateful`` when available, and degrade to inert stand-ins
otherwise — the machine class still DEFINES cleanly either way (so a
seeded stdlib-``random`` fuzz walk can drive the same rule methods by
hand; see ``tests/test_kvcache.py``), while ``run_state_machine_as_test``
skips.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, precondition, rule,
                                     run_state_machine_as_test)
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy constructor
        returns None (the values are never drawn — the test is skipped)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            # a fresh zero-fixture stub: pytest must not try to resolve the
            # property-test parameters (S, E, ...) as fixtures
            def stub(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco

    class RuleBasedStateMachine:
        """Inert stand-in: subclasses still define + instantiate, and the
        rule methods stay plain callables a hand-rolled fuzz loop can
        drive.  Only ``run_state_machine_as_test`` (hypothesis's own
        driver) skips."""

    def _passthrough_deco(*args, **kwargs):
        if len(args) == 1 and callable(args[0]) and not kwargs:
            return args[0]              # bare @invariant()-style use
        return lambda f: f

    rule = _passthrough_deco
    invariant = _passthrough_deco
    precondition = _passthrough_deco
    initialize = _passthrough_deco

    def run_state_machine_as_test(factory, settings=None):
        pytest.skip("hypothesis not installed "
                    "(pip install -r requirements-dev.txt)")
