"""Plan-IR tests: validation (cycles, dangling deps, bad axes), the
split_capacity / apply_wire graph transforms, the plan registry, the
t_plan cost walker vs the legacy closed forms, and the executor parity
matrix — every (schedule x n_chunks x wire_dtype) against the golden
legacy bodies (subprocess, 8 fake devices)."""

import os
import subprocess
import sys

import pytest

from conftest import subprocess_env
from repro.core import plan as planlib
from repro.core.collectives import CommConfig
from repro.core.gating import GateConfig
from repro.core.perfmodel import AlphaBeta, MoELayerShape, PerfModel
from repro.core.plan import (Plan, PlanError, apply_wire, build_plan,
                             plan_for_shape, plan_summary, split_capacity,
                             stage, validate)
from repro.core.schedules import BODY, SCHEDULES, MoEShardInfo

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def _run(script, *args, n_devices=8, timeout=900):
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = HELPERS + os.pathsep + env["PYTHONPATH"]
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def info(**kw):
    base = dict(ep_axes=("ep",), esp_axes=("esp",), mp_axes=("mp",),
                n_ep=2, n_esp=2, n_mp=2, tokens=128, cap=32,
                gate=GateConfig(n_experts=8, top_k=2),
                pipeline_chunks=1)
    base.update(kw)
    return MoEShardInfo(**base)


class TestValidation:
    def _plan(self, stages, output="b", **kw):
        return Plan("t", tuple(stages), output=output, **kw)

    def test_valid_plan_topo_order(self):
        p = self._plan([stage("a", "gate", deps=("x",)),
                        stage("b", "dispatch", deps=("x", "a"))])
        assert [s.name for s in validate(p)] == ["a", "b"]

    def test_cycle_detected(self):
        p = self._plan([stage("a", "gate", deps=("b",)),
                        stage("b", "dispatch", deps=("a",))])
        with pytest.raises(PlanError, match="cycle"):
            validate(p)

    def test_dangling_dep_rejected(self):
        p = self._plan([stage("a", "gate", deps=("x",)),
                        stage("b", "dispatch", deps=("nope", "a"))])
        with pytest.raises(PlanError, match="undefined stage 'nope'"):
            validate(p)

    def test_bad_axis_name_rejected(self):
        p = self._plan([stage("a", "gate", deps=("x",)),
                        stage("b", "ag_mp", deps=("a",), axes=("pp",))])
        with pytest.raises(PlanError, match="bad axis 'pp'"):
            validate(p)

    def test_unknown_kind_rejected(self):
        p = self._plan([stage("a", "gate", deps=("x",)),
                        stage("b", "warp_drive", deps=("a",))])
        with pytest.raises(PlanError, match="unknown kind"):
            validate(p)

    def test_unknown_size_symbol_rejected(self):
        """A typo'd size symbol would silently price the collective at
        zero bandwidth in t_plan — validate must catch it."""
        p = self._plan([stage("a", "gate", deps=("x",)),
                        stage("b", "ag_mp", deps=("a",), axes=("mp",),
                              size="elm")])
        with pytest.raises(PlanError, match="unknown size symbol"):
            validate(p)

    def test_duplicate_names_rejected(self):
        p = self._plan([stage("b", "gate", deps=("x",)),
                        stage("b", "dispatch", deps=("x",))])
        with pytest.raises(PlanError, match="duplicate"):
            validate(p)

    def test_missing_output_rejected(self):
        p = self._plan([stage("a", "gate", deps=("x",))], output="zz")
        with pytest.raises(PlanError, match="output stage"):
            validate(p)

    def test_reserved_input_name_rejected(self):
        p = self._plan([stage("x", "gate", deps=())], output="x")
        with pytest.raises(PlanError, match="reserved"):
            validate(p)

    def test_every_registered_plan_validates(self):
        for name in planlib.PLANS:
            for nc in (1, 2, 4):
                p = build_plan(name, info(pipeline_chunks=nc))
                validate(p)
                assert p.find(p.output) is not None


class TestSplitCapacity:
    def test_noop_at_one_chunk(self):
        import dataclasses
        base = planlib.PLANS["s1"].builder(info())
        assert split_capacity(base, 1) == dataclasses.replace(
            base, n_chunks=1)

    def test_replicates_region_and_remaps_deps(self):
        p = split_capacity(planlib.PLANS["s1"].builder(info()), 2)
        names = p.stage_names()
        assert "chunk0/slice" in names and "chunk1/slice" in names
        assert "a2a_d@0" in names and "ffn@1" in names
        assert p.find("merge").deps == ("a2a_c@0", "a2a_c@1")
        # the post-region combine reads the merge, not a chunk clone
        assert "merge" in p.find("comb").deps
        # per-chunk ffn depends on its own chunk's dispatch a2a
        assert p.find("ffn@1").deps == ("a2a_d@1",)

    def test_clamps_to_divisor(self):
        base = planlib.PLANS["s1"].builder(info(cap=28, n_mp=2))  # dim 14
        assert split_capacity(base, 4).n_chunks == 2
        assert split_capacity(base, 4, clamp=False).n_chunks == 4

    def test_s2h_alternates_hier_order(self):
        p = split_capacity(planlib.PLANS["s2h"].builder(info()), 4,
                           clamp=False)
        orders = [p.find(f"a2a_d@{i}").p("hier") for i in range(4)]
        assert orders == ["esp_first", "ep_first"] * 2

    def test_s2_saa_collapses_inside_chunks(self):
        p = split_capacity(planlib.PLANS["s2"].builder(info()), 2)
        assert p.find("a2a_c@0").p("saa_chunks") == 1
        assert p.merge == "stack_mp"

    def test_chunk_count_recorded(self):
        p = split_capacity(planlib.PLANS["baseline"].builder(info()), 4)
        assert p.n_chunks == 4
        assert sum(s.kind == "slice" for s in p.stages) == 4


class TestApplyWire:
    def test_stamps_comm(self):
        base = planlib.PLANS["s1"].builder(info())
        c = CommConfig(wire_dtype="bf16")
        assert apply_wire(base, c).comm == c

    def test_rejects_unresolved_auto(self):
        with pytest.raises(PlanError, match="auto"):
            apply_wire(planlib.PLANS["s1"].builder(info()),
                       CommConfig(wire_dtype="auto"))

    def test_build_plan_threads_info(self):
        i = info(pipeline_chunks=2, comm=CommConfig(wire_dtype="bf16"))
        p = build_plan("s2", i)
        assert p.n_chunks == 2 and p.comm.wire_dtype == "bf16"
        # the unchunked alias pins n_chunks=1 regardless of info
        assert build_plan("s2", i, n_chunks=1).n_chunks == 1


class TestRegistry:
    def test_paper_schedules_registered(self):
        assert {"baseline", "s1", "s2", "s1_seqpar", "s2h"} <= set(
            planlib.PLANS)

    def test_grid_flags(self):
        assert "baseline" not in planlib.analytic_schedules()
        assert "baseline" in planlib.measured_schedules()
        assert "s1_seqpar" not in planlib.analytic_schedules()
        assert "s1_seqpar" not in planlib.measured_schedules()
        assert "s2h" in planlib.analytic_schedules()
        assert "s2h" in planlib.measured_schedules()

    def test_body_registry_covers_schedules(self):
        assert set(SCHEDULES) - {"auto"} == set(BODY)

    def test_unknown_plan_rejected(self):
        with pytest.raises(KeyError, match="no plan registered"):
            build_plan("s99", info())

    def test_registered_plan_runs_without_body_alias(self):
        """Registration alone makes a schedule executable: apply_moe
        falls back to execute(build_plan(...)) for registry-only names
        (the docs' 'add a schedule' path needs no BODY edit)."""
        import jax
        import numpy as np

        from repro.core.moe import MoEConfig, apply_moe, init_moe_params
        from repro.parallel.mesh import ParallelDims, make_mesh

        planlib.register_plan(
            "s1_docsvariant",
            lambda i: planlib.PLANS["s1"].builder(i),
            analytic=False, measured=False)
        try:
            mesh = make_mesh((1, 1), ("data", "model"))
            dims = ParallelDims(ep=("data",), esp=("model",),
                                mp=("model",))
            cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                            capacity_factor=2.0, schedule="s1")
            params = init_moe_params(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
            y_ref, _ = apply_moe(x, params, mesh=mesh, dims=dims, cfg=cfg)
            y, _ = apply_moe(x, params, mesh=mesh, dims=dims, cfg=cfg,
                             schedule="s1_docsvariant")
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-6, atol=1e-6)
            with pytest.raises(KeyError, match="unknown schedule"):
                apply_moe(x, params, mesh=mesh, dims=dims, cfg=cfg,
                          schedule="never_registered")
        finally:
            planlib.PLANS.pop("s1_docsvariant", None)

    def test_plan_summary_is_json_ready(self):
        import json
        p = build_plan("s2h", info(pipeline_chunks=2,
                                   comm=CommConfig(wire_dtype="bf16")))
        d = plan_summary(p)
        json.dumps(d)
        assert d["n_chunks"] == 2 and d["wire_dtype"] == "bf16"
        kinds = {s["kind"] for s in d["stages"]}
        assert {"gate", "dispatch_a2a", "expert_ffn", "combine_a2a",
                "slice", "merge"} <= kinds
        assert any(s.get("hier") == "ep_first" for s in d["stages"])


def toy_model(beta=1e-9, alpha=1e-5, flops=1e12):
    ab = AlphaBeta(alpha, beta)
    return PerfModel(a2a_ep_esp=ab, a2a_ep=ab, ag_esp=ab, ar_esp=ab,
                     ag_mp=AlphaBeta(alpha, beta / 4), overlap=ab,
                     flops_per_s=flops)


class TestTPlan:
    """One cost-model source of truth: walking a legacy schedule's plan
    must reproduce the hand-derived t_pipelined closed forms."""

    def shape(self, **kw):
        base = dict(B=4, L=1024, M=1024, H=4096, E=8, k=2, f=1.2,
                    n_mp=2, n_esp=2, n_ep=2)
        base.update(kw)
        return MoELayerShape(**base)

    @pytest.mark.parametrize("sched", ["baseline", "s1", "s2",
                                       "s1_seqpar"])
    @pytest.mark.parametrize("n", [1, 2, 4, 8])
    @pytest.mark.parametrize("wire", [None, "bf16", "fp8_e4m3"])
    def test_matches_t_pipelined(self, sched, n, wire):
        pm, s = toy_model(), self.shape()
        tp = pm.t_pipelined(s, sched, n, wire_dtype=wire)
        tq = pm.t_plan(plan_for_shape(sched, s, n), s, wire_dtype=wire)
        assert tq == pytest.approx(tp, rel=1e-12)

    def test_s2h_scored_and_finite(self):
        pm, s = toy_model(), self.shape()
        for n in (1, 2, 4):
            t = pm.t_plan(plan_for_shape("s2h", s, n), s)
            assert 0.0 < t < float("inf")

    def test_s2h_wins_on_inter_pod_fabric(self):
        """The hierarchical decomposition only pays off where intra- and
        inter-group links differ — exactly the MegaScale regime the
        analytic v5e model encodes with inter_pod=True."""
        from repro.core import autosched
        from repro.core.perfmodel import tpu_v5e_model
        s = self.shape(B=8, L=2048, M=2048, H=8192, E=32,
                       n_mp=4, n_esp=4, n_ep=8)
        autosched.clear_cache()
        d = autosched.decide(s, perf_model=tpu_v5e_model(
            8, 4, 4, inter_pod=True))
        assert d.schedule == "s2h" and d.n_chunks > 1
        autosched.clear_cache()
        d1 = autosched.decide(s, perf_model=tpu_v5e_model(8, 4, 4))
        assert d1.schedule != "s2h"     # all-ICI: nothing to hide behind
        autosched.clear_cache()


class TestExecutorParityMatrix:
    """Plan executor vs golden legacy bodies (subprocess, 8 fake
    devices): forward + grad envelopes, bit-identical aux scalars and
    drop masks, per (schedule x n_chunks in {1,2,4} x wire in
    {f32, bf16}).  The full matrix runs on the merged production
    mapping; distinct/drops cover the same code paths on a reduced
    grid."""

    def test_full_matrix_merged(self):
        assert "OK merged" in _run("run_plan_parity.py", "merged")

    def test_distinct_axes(self):
        assert "OK distinct" in _run("run_plan_parity.py", "distinct")

    def test_dropped_tokens(self):
        assert "OK drops" in _run("run_plan_parity.py", "drops")


class TestNoLegacyBodiesInSrc:
    def test_schedule_modules_hold_no_hand_written_bodies(self):
        """The acceptance criterion: no hand-written schedule bodies
        remain under src/repro/core — every BODY entry is a thin
        plan-build-and-execute alias."""
        import inspect

        import repro.core.pipeline as P
        import repro.core.schedules as S
        for name, fn in BODY.items():
            src = inspect.getsource(fn)
            assert "execute(build_plan(" in src, name
        for mod in (S, P):
            text = inspect.getsource(mod)
            for marker in ("topk_gate(", "wire_ep_all_to_all(",
                           "saa_combine_allgather(", "lax.psum("):
                assert marker not in text, (mod.__name__, marker)
