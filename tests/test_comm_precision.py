"""Wire-precision tests: codec invariants, schedule parity across wire
dtypes (subprocess, 8 fake devices), the extended perf model, the joint
(schedule, n_chunks, wire_dtype) autosched decision, and the analytic
dispatch/combine transposes that replaced the ref-recompute VJPs."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import subprocess_env
from repro.core import autosched
from repro.core.collectives import (CommConfig, wire_decode, wire_encode)
from repro.core.perfmodel import (AlphaBeta, MoELayerShape, PerfModel,
                                  WIRE_BYTES)

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def _run(script, *args, n_devices=8, timeout=900):
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=subprocess_env(n_devices), capture_output=True, text=True,
        timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestWireCodec:
    def test_f32_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (6, 16))
        w = wire_encode(x, CommConfig())
        assert w is x
        np.testing.assert_array_equal(
            np.asarray(wire_decode(w, CommConfig(), x.dtype)),
            np.asarray(x))

    def test_none_comm_is_identity(self):
        x = jnp.ones((2, 4))
        assert wire_encode(x, None) is x

    def test_bf16_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
        c = CommConfig(wire_dtype="bf16")
        w = wire_encode(x, c)
        assert w.dtype == jnp.bfloat16 and w.shape == x.shape
        r = np.asarray(wire_decode(w, c, x.dtype))
        # bf16 has an 8-bit mantissa: relative error <= 2^-8
        assert np.max(np.abs(r - np.asarray(x))) <= \
            np.max(np.abs(np.asarray(x))) * 2.0 ** -8

    def test_fp8_scale_tail_and_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 64)) * 100.0
        c = CommConfig(wire_dtype="fp8_e4m3")
        w = wire_encode(x, c)
        # per-row f32 absmax scale piggybacks as 4 extra fp8 elements
        assert w.shape == (32, 64 + 4)
        assert w.dtype == jnp.float8_e4m3fn
        r = np.asarray(wire_decode(w, c, x.dtype))
        xa = np.asarray(x)
        # e4m3 mantissa: 3 bits -> per-row relative error <= 2^-3 of the
        # row absmax (absmax scaling puts the largest entry at 448)
        row_max = np.max(np.abs(xa), axis=-1, keepdims=True)
        assert np.all(np.abs(r - xa) <= row_max * 2.0 ** -3 + 1e-6)

    def test_fp8_zero_rows_stay_zero(self):
        c = CommConfig(wire_dtype="fp8_e4m3")
        x = jnp.zeros((4, 8))
        r = np.asarray(wire_decode(wire_encode(x, c), c, x.dtype))
        np.testing.assert_array_equal(r, 0.0)

    def test_fp8_scaling_none_saturates(self):
        c = CommConfig(wire_dtype="fp8_e4m3", scaling="none")
        x = jnp.array([[1e6, 1.0]])
        w = wire_encode(x, c)
        assert w.shape == x.shape  # no scale tail
        r = np.asarray(wire_decode(w, c, x.dtype))
        assert r[0, 0] <= 448.0 and abs(r[0, 1] - 1.0) < 0.1

    def test_auto_must_be_resolved(self):
        with pytest.raises(ValueError):
            wire_encode(jnp.ones((2, 2)), CommConfig(wire_dtype="auto"))

    def test_bad_configs_rejected(self):
        with pytest.raises(ValueError):
            CommConfig(wire_dtype="fp4")
        with pytest.raises(ValueError):
            CommConfig(scaling="per_tensor")


class TestWireParity:
    """All schedules x {f32, bf16, fp8} forward + grad within tolerance,
    routing/drops exactly invariant (subprocess, 8 fake devices)."""

    def test_merged_production_mapping(self):
        assert "OK merged" in _run("run_wire_equiv.py", "merged")

    def test_distinct_axes(self):
        assert "OK distinct" in _run("run_wire_equiv.py", "distinct")

    def test_dropped_tokens_invariant(self):
        assert "OK drops" in _run("run_wire_equiv.py", "drops")

    def test_pipelined_bodies(self):
        assert "OK pipe" in _run("run_wire_equiv.py", "pipe")


def toy_model(beta=1e-9, alpha=1e-5, flops=1e12):
    ab = AlphaBeta(alpha, beta)
    return PerfModel(a2a_ep_esp=ab, a2a_ep=ab, ag_esp=ab, ar_esp=ab,
                     ag_mp=AlphaBeta(alpha, beta / 4), overlap=ab,
                     flops_per_s=flops, wire_bytes_ref=4.0)


def shape(**kw):
    base = dict(B=4, L=1024, M=1024, H=4096, E=8, k=2, f=1.2,
                n_mp=2, n_esp=2, n_ep=2)
    base.update(kw)
    return MoELayerShape(**base)


@pytest.fixture(autouse=True)
def fresh_cache():
    autosched.clear_cache()
    yield
    autosched.clear_cache()


class TestPerfModelWire:
    def test_wire_factor_relative_to_ref(self):
        pm = toy_model()
        assert pm.wire_factor() == 1.0
        assert pm.wire_factor("f32") == 1.0        # ref is 4 bytes here
        assert pm.wire_factor("bf16") == 0.5
        assert pm.wire_factor("fp8_e4m3") == 0.25

    def test_narrow_wire_never_slower(self):
        pm = toy_model()
        s = shape()
        for sched in ("baseline", "s1", "s2"):
            for n in (1, 4):
                t32 = pm.t_pipelined(s, sched, n, wire_dtype="f32")
                t16 = pm.t_pipelined(s, sched, n, wire_dtype="bf16")
                t8 = pm.t_pipelined(s, sched, n, wire_dtype="fp8_e4m3")
                assert t8 <= t16 <= t32

    def test_closed_forms_scale_only_comm(self):
        """Halving wire bytes must cut the s1 comm term exactly in half
        (alphas unscaled), and leave the baseline's pre-gate AllGather
        and AllReduce untouched."""
        pm = toy_model(alpha=0.0)
        s = shape()
        assert pm.t_s1(s, "bf16") == pytest.approx(pm.t_s1(s, "f32") / 2)
        # baseline: AG + AR terms are wire-invariant by design
        d32 = pm.t_baseline(s, "f32") - 2 * pm.a2a_ep(s.etm * s.n_esp)
        d16 = pm.t_baseline(s, "bf16") - 2 * pm.a2a_ep(
            s.etm * s.n_esp * 0.5)
        assert d32 == pytest.approx(d16)

    def test_alpha_not_scaled(self):
        pm = toy_model(beta=0.0, alpha=1e-3)
        s = shape()
        assert pm.t_s2(s, "fp8_e4m3") == pytest.approx(pm.t_s2(s, "f32"))


class TestJointDecision:
    def test_argmin_over_triple_grid(self):
        from repro.core import plan as planlib
        pm = toy_model()
        s = shape()
        d = autosched.decide(s, perf_model=pm,
                             wire_candidates=("f32", "bf16"))
        cands = {(sc, n, w): pm.t_plan(planlib.plan_for_shape(sc, s, n),
                                       s, wire_dtype=w)
                 for sc in planlib.analytic_schedules()
                 for n in (1, 2, 4, 8) for w in ("f32", "bf16")}
        best = min(cands.values())
        assert cands[(d.schedule, d.n_chunks, d.wire_dtype)] == best
        assert len(d.times) == len(cands)

    def test_comm_dominant_layer_picks_bf16(self):
        """Acceptance: wherever the analytic comm term dominates, the
        joint decision selects the narrower wire."""
        pm = toy_model(beta=1e-8, flops=1e18)   # comm >> compute
        d = autosched.decide(shape(), perf_model=pm,
                             wire_candidates=autosched.AUTO_WIRE)
        assert d.wire_dtype == "bf16"

    def test_zero_comm_tie_prefers_f32(self):
        """With no bandwidth term the times tie exactly; the tie must
        break toward the wider dtype (no silent compression)."""
        pm = toy_model(beta=0.0)
        d = autosched.decide(shape(), perf_model=pm,
                             wire_candidates=autosched.AUTO_WIRE)
        assert d.wire_dtype == "f32"

    def test_deterministic_and_cached(self):
        pm = toy_model()
        d1 = autosched.decide(shape(), perf_model=pm,
                              wire_candidates=autosched.AUTO_WIRE)
        d2 = autosched.decide(shape(), perf_model=pm,
                              wire_candidates=autosched.AUTO_WIRE)
        assert d2 is d1
        autosched.clear_cache()
        d3 = autosched.decide(shape(), perf_model=toy_model(),
                              wire_candidates=autosched.AUTO_WIRE)
        assert (d3.schedule, d3.n_chunks, d3.wire_dtype) == \
            (d1.schedule, d1.n_chunks, d1.wire_dtype)
        assert d3.times == d1.times

    def test_wire_grid_distinct_cache_entries(self):
        pm = toy_model()
        autosched.decide(shape(), perf_model=pm)
        autosched.decide(shape(), perf_model=pm,
                         wire_candidates=autosched.AUTO_WIRE)
        assert len(autosched.cache_info()) == 2

    def test_default_grid_keeps_legacy_pair_candidates(self):
        d = autosched.decide(shape(), perf_model=toy_model())
        assert all(len(c) == 2 for c, _ in d.times)
        assert d.wire_dtype == "f32"

    def test_forced_schedule_wire_only_decision(self):
        pm = toy_model(beta=1e-8, flops=1e18)
        d = autosched.decide(shape(), perf_model=pm, schedules=("s2",),
                             chunk_candidates=(1,),
                             wire_candidates=autosched.AUTO_WIRE)
        assert d.schedule == "s2" and d.n_chunks == 1
        assert d.wire_dtype == "bf16"

    def test_measured_joint_candidates_are_triples(self):
        seen = []

        def fake_measure(cands):
            seen.extend(cands)
            return {c: (0.001 if c == ("s1", 2, "bf16") else 1.0)
                    for c in cands}

        d = autosched.decide(shape(), perf_model=toy_model(),
                             mode="measured", measure=fake_measure,
                             wire_candidates=autosched.AUTO_WIRE)
        assert all(len(c) == 3 for c in seen)
        assert (d.schedule, d.n_chunks, d.wire_dtype) == ("s1", 2, "bf16")

    def test_summary_mentions_wire(self):
        pm = toy_model(beta=1e-8, flops=1e18)
        autosched.decide(shape(), perf_model=pm,
                         wire_candidates=autosched.AUTO_WIRE)
        assert "wire=bf16" in autosched.cache_summary()

    def test_auto_wire_excludes_fp8(self):
        """fp8 is opt-in only: the auto grid must never select it."""
        assert "fp8_e4m3" not in autosched.AUTO_WIRE
        assert set(autosched.AUTO_WIRE) <= set(WIRE_BYTES)


class TestAnalyticDispatchCombineVjp:
    """The pallas moe_dispatch/moe_combine backends now differentiate via
    their closed-form transposes; they must agree with the ref oracles'
    autodiff on routed data, including drops and duplicate slots."""

    def _routed(self, S=48, M=16, E=4, k=2, f=0.5, seed=0):
        from repro.core.gating import GateConfig, capacity, topk_gate
        x = jax.random.normal(jax.random.PRNGKey(seed), (S, M))
        wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, E)) * 0.3
        gcfg = GateConfig(n_experts=E, top_k=k, capacity_factor=f)
        cap = capacity(S, gcfg)
        gate = topk_gate(x, wg, gcfg, cap)
        return x, gate.flat(cap, E), gate.weights, E * cap

    @pytest.mark.parametrize("f", [4.0, 0.5])
    def test_dispatch_grad_matches_ref(self, f):
        from repro.kernels.registry import get_op
        x, flat, _, n_slots = self._routed(f=f)

        def loss(x, backend):
            op = get_op("moe_dispatch", backend=backend, n_slots=n_slots)
            return jnp.sum(op(x, flat) ** 2)

        g_ref = jax.grad(lambda x: loss(x, "ref"))(x)
        g_pal = jax.grad(lambda x: loss(x, "pallas"))(x)
        np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                                   atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("f", [4.0, 0.5])
    def test_combine_grads_match_ref(self, f):
        from repro.kernels.registry import get_op
        x, flat, w, n_slots = self._routed(f=f)
        buf = jax.random.normal(jax.random.PRNGKey(7),
                                (n_slots, x.shape[1]))

        def loss(buf, w, backend):
            op = get_op("moe_combine", backend=backend)
            return jnp.sum(op(buf, flat, w) ** 2)

        for argnums in (0, 1):
            g_ref = jax.grad(lambda b, ww: loss(b, ww, "ref"),
                             argnums=argnums)(buf, w)
            g_pal = jax.grad(lambda b, ww: loss(b, ww, "pallas"),
                             argnums=argnums)(buf, w)
            np.testing.assert_allclose(
                np.asarray(g_pal), np.asarray(g_ref), atol=1e-5,
                rtol=1e-5, err_msg=f"argnums={argnums}")

    def test_duplicate_slots_grad(self):
        """Adversarial scatter-ADD collisions: analytic transpose must
        sum both contributions exactly like the ref autodiff."""
        from repro.kernels.registry import get_op
        S, M, n_slots = 8, 16, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (S, M))
        flat = jnp.array([[0, 1]] * 4 + [[1, 1]] * 2 + [[n_slots, 0]] * 2,
                         jnp.int32)

        def loss(x, backend):
            op = get_op("moe_dispatch", backend=backend, n_slots=n_slots)
            return jnp.sum(op(x, flat) ** 3)

        g_ref = jax.grad(lambda x: loss(x, "ref"))(x)
        g_pal = jax.grad(lambda x: loss(x, "pallas"))(x)
        np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                                   atol=1e-5, rtol=1e-5)


class TestGateResultFlatCache:
    def test_flat_cached_per_key(self):
        from repro.core.gating import GateConfig, capacity, topk_gate
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
        wg = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
        gcfg = GateConfig(n_experts=4, top_k=2, capacity_factor=2.0)
        cap = capacity(32, gcfg)
        gate = topk_gate(x, wg, gcfg, cap)
        f1 = gate.flat(cap, 4)
        assert gate.flat(cap, 4) is f1          # memoized
        assert gate.flat(cap * 2, 4) is not f1  # distinct key
        # unpacks as the classic 4-tuple
        eidx, slot, w, aux = gate
        assert eidx.shape == slot.shape == w.shape == (32, 2)
        from repro.core.gating import flat_slots
        np.testing.assert_array_equal(
            np.asarray(f1), np.asarray(flat_slots(eidx, slot, cap, 4)))
