"""Load-adaptive expert placement: table math, the plan-IR transform,
skew-aware cost-model pricing, the autosched rebalance lifecycle, and
executor numerical parity.

Pure table/plan/pricing tests run in-process on 1 device; the executor
parity matrix runs in subprocesses with 8 fake CPU devices
(tests/helpers/run_placement_parity.py)."""

import math
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_env

from repro.core import autosched
from repro.core import plan as planlib
from repro.core.perfmodel import MoELayerShape, _rank_imbalance, \
    tpu_v5e_model
from repro.core.placement import ExpertPlacement, LoadEMA, \
    identity_placement, placement_from_loads

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")

HOT = [4.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]   # one ~4x-hot expert
EVEN = [1.0] * 8


@pytest.fixture(autouse=True)
def _clean_registry():
    """Placement + decision cache are process-global; isolate each test."""
    autosched.clear_cache()
    yield
    autosched.clear_cache()


def shape8(**kw):
    d = dict(B=8, L=128, M=512, H=2048, E=8, k=2, f=1.2,
             n_mp=2, n_esp=2, n_ep=4)
    d.update(kw)
    return MoELayerShape(**d)


# ---------------------------------------------------------------- tables


class TestExpertPlacement:
    def test_identity(self):
        pl = identity_placement(8, 4)
        assert pl.is_identity and pl.n_phys == 8
        assert list(pl.rep_count) == [1] * 8
        assert pl.imbalance(EVEN) == pytest.approx(1.0)
        # identity at full capacity only pays the 8-alignment
        assert pl.scaled_cap(64) == 64
        assert pl.pool_scale(64) == pytest.approx(1.0)

    def test_replica_tables(self):
        # E=4 experts on n_ep=2 ranks, expert 0 replicated 3x
        pl = ExpertPlacement(n_experts=4, n_ep=2,
                             assignments=(0, 1, 0, 2, 0, 3), cap_frac=0.5)
        assert pl.n_phys == 6 and not pl.is_identity
        assert list(pl.rep_count) == [3, 1, 1, 1]
        table = pl.rep_table
        assert table.shape == (4, 3)
        assert list(table[0]) == [0, 2, 4]          # expert 0's slots
        assert list(table[1]) == [1, 1, 1]          # padded with replica 0
        assert list(pl.replica_index) == [0, 0, 1, 0, 2, 0]

    def test_scaled_cap_alignment(self):
        pl = ExpertPlacement(n_experts=4, n_ep=2,
                             assignments=(0, 1, 0, 2, 0, 3), cap_frac=0.25)
        assert pl.scaled_cap(64) == 16               # ceil(16) -> 16
        assert pl.scaled_cap(10) == 8                # floor at align
        assert pl.scaled_cap(64, align=24) == 24     # lcm(8, n_mp=3) style

    def test_replication_reduces_imbalance(self):
        loads = [4.0, 1.0, 1.0, 1.0]
        uni = identity_placement(4, 2)
        # identity: rank0 carries (4+1)/7 of the traffic
        assert uni.imbalance(loads) == pytest.approx((5 / 7) / 0.5)
        rep = ExpertPlacement(n_experts=4, n_ep=2,
                              assignments=(0, 1, 2, 0, 0, 3), cap_frac=0.5)
        assert rep.imbalance(loads) < uni.imbalance(loads)

    def test_validation(self):
        with pytest.raises(ValueError, match="not divisible"):
            ExpertPlacement(n_experts=4, n_ep=2,
                            assignments=(0, 1, 2, 3, 0))
        with pytest.raises(ValueError, match="no replica"):
            ExpertPlacement(n_experts=4, n_ep=2,
                            assignments=(0, 1, 2, 2))
        with pytest.raises(ValueError, match="cap_frac"):
            ExpertPlacement(n_experts=4, n_ep=2,
                            assignments=(0, 1, 2, 3), cap_frac=0.0)
        with pytest.raises(ValueError, match="cap_frac"):
            ExpertPlacement(n_experts=4, n_ep=2,
                            assignments=(0, 1, 2, 3), cap_frac=1.5)

    def test_summary_roundtrip(self):
        pl = ExpertPlacement(n_experts=4, n_ep=2,
                             assignments=(0, 1, 0, 2, 0, 3),
                             cap_frac=0.5, epoch=3)
        s = pl.summary()
        assert s["epoch"] == 3 and s["n_phys"] == 6
        assert s["replicated"] == {0: 3}
        assert ExpertPlacement(
            n_experts=s["n_experts"], n_ep=s["n_ep"],
            assignments=tuple(s["assignments"]), cap_frac=s["cap_frac"],
            epoch=s["epoch"]) == pl


class TestPlacementFromLoads:
    def test_hot_expert_replicated(self):
        pl = placement_from_loads(HOT, 4, capacity_factor=1.2, top_k=2)
        assert not pl.is_identity
        assert pl.rep_count[0] > 1                   # the hot expert
        assert pl.n_phys % 4 == 0
        assert set(pl.assignments) == set(range(8))  # full coverage
        assert 0.0 < pl.cap_frac <= 1.0
        # replicas of the hot expert land on distinct ranks
        per_rank = np.asarray(pl.assignments).reshape(4, -1)
        assert max(int((per_rank == 0).sum(axis=1).max()), 1) == 1

    def test_uniform_is_identity(self):
        assert placement_from_loads(EVEN, 4).is_identity

    def test_degenerate_inputs(self):
        assert placement_from_loads([0.0] * 8, 4).is_identity
        assert placement_from_loads(HOT, 1).is_identity
        assert placement_from_loads([1.0, 9.0], 4).is_identity  # E < n_ep

    def test_max_replicas(self):
        pl = placement_from_loads([100.0, 1, 1, 1, 1, 1, 1, 1], 4,
                                  max_replicas=2)
        assert int(pl.rep_count.max()) <= 2

    def test_epoch_stamped(self):
        pl = placement_from_loads(HOT, 4, epoch=7)
        assert pl.epoch == 7


class TestLoadEMA:
    def test_lifecycle(self):
        ema = LoadEMA(decay=0.5)
        assert not ema.ready and ema.value().size == 0
        assert ema.imbalance() == 1.0
        ema.update(HOT)
        assert ema.ready
        ema.update(EVEN)
        np.testing.assert_allclose(
            ema.value(), 0.5 * np.asarray(HOT) + 0.5 * np.asarray(EVEN))
        assert ema.imbalance() > 1.0

    def test_rejects_bad_updates(self):
        ema = LoadEMA()
        ema.update([])                               # empty: ignored
        ema.update([np.nan, 1.0])                    # non-finite: ignored
        assert not ema.ready
        ema.update([1.0, 2.0])
        ema.update([1.0, 2.0, 3.0])                  # shape change: reset
        assert ema.value().shape == (3,)


# ------------------------------------------------------ plan-IR transform


class TestApplyPlacement:
    def test_stamps_plan(self):
        # f=5.0 is the drop-free uniform capacity for 4x-hot traffic; the
        # replicated placement shrinks the per-slot capacity (bench regime)
        pl = placement_from_loads(HOT, 4, capacity_factor=5.0, top_k=2)
        assert pl.cap_frac < 1.0
        s = shape8(f=5.0)
        p = planlib.plan_for_shape("s1", s, 1, placement=pl)
        assert p.placement is pl
        gate = next(st for st in p.stages if st.kind == "gate")
        placed_cap = gate.p("placed_cap")
        assert placed_cap and placed_cap % 8 == 0
        # identity placement keeps the full (aligned) capacity; the
        # replicated one must come in under it
        p_uni = planlib.plan_for_shape("s1", s, 1,
                                       placement=identity_placement(8, 4))
        uni_cap = next(st for st in p_uni.stages
                       if st.kind == "gate").p("placed_cap")
        assert placed_cap < uni_cap
        stamped = [st for st in p.stages
                   if st.kind in ("dispatch", "combine", "dispatch_a2a",
                                  "combine_a2a", "expert_ffn_grouped")]
        assert stamped and all(st.p("placed") is True for st in stamped)

    def test_identity_is_noop_graph(self):
        s = shape8()
        base = planlib.plan_for_shape("s1", s, 1)
        placed = planlib.plan_for_shape("s1", s, 1,
                                        placement=identity_placement(8, 4))
        # same stage graph shape; only the stamps differ
        assert placed.stage_names() == base.stage_names()
        assert base.placement is None

    def test_pool_split_chunk_alignment(self):
        # s2-family plans mp_split the capacity dim: placed_cap must stay
        # divisible by n_mp so the 1/N_MP slices are exact
        pl = placement_from_loads(HOT, 4, capacity_factor=5.0, top_k=2)
        s = shape8(n_mp=2, f=5.0)
        p = planlib.plan_for_shape("s2", s, 2, placement=pl)
        gate = next(st for st in p.stages if st.kind == "gate")
        assert gate.p("placed_cap") % (2 * s.n_mp) == 0
        if p.chunk_size:
            assert p.chunk_size == gate.p("placed_cap") // s.n_mp

    def test_none_placement_unchanged(self):
        s = shape8()
        p = planlib.plan_for_shape("s1", s, 1)
        assert planlib.apply_placement(p, None) is p

    def test_rejects_planless_gate(self):
        bad = planlib.Plan(
            "t", (planlib.stage("d", "dispatch", deps=()),), output="d")
        with pytest.raises(planlib.PlanError, match="needs a"):
            planlib.apply_placement(bad, identity_placement(8, 4))


# ------------------------------------------------------ skew-aware pricing


class TestSkewPricing:
    def test_rank_imbalance(self):
        assert _rank_imbalance(EVEN, 4) == pytest.approx(1.0)
        assert _rank_imbalance(HOT, 4) > 1.4
        pl = placement_from_loads(HOT, 4, capacity_factor=5.0, top_k=2)
        assert _rank_imbalance(HOT, 4, pl) < _rank_imbalance(HOT, 4)

    def test_t_plan_prices_skew(self):
        s = shape8()
        pm = tpu_v5e_model(s.n_ep, s.n_esp, s.n_mp)
        p = planlib.plan_for_shape("s1", s, 1)
        t_even = pm.t_plan(p, s, loads=EVEN)
        t_hot = pm.t_plan(p, s, loads=HOT)
        assert t_hot > t_even                        # max-rank load paces

    def test_placed_plan_wins_under_skew(self):
        s = shape8()
        pm = tpu_v5e_model(s.n_ep, s.n_esp, s.n_mp)
        pl = placement_from_loads(HOT, 4, capacity_factor=5.0, top_k=2)
        t_uni = pm.t_plan(planlib.plan_for_shape("s1", s, 1), s, loads=HOT)
        t_pl = pm.t_plan(planlib.plan_for_shape("s1", s, 1, placement=pl),
                         s, loads=HOT)
        assert t_pl < t_uni


# --------------------------------------------------- autosched lifecycle


class TestAutoschedPlacement:
    def test_epoch_and_registry(self):
        assert autosched.current_placement() is None
        assert autosched.placement_epoch() == 0
        pl = placement_from_loads(HOT, 4, capacity_factor=1.2, top_k=2)
        e1 = autosched.set_placement(pl)
        assert e1 == 1 and autosched.current_placement() is pl
        e2 = autosched.set_placement(None)
        assert e2 == 2 and autosched.current_placement() is None
        autosched.clear_cache()
        assert autosched.placement_epoch() == 0

    def test_decisions_keyed_by_epoch(self):
        s = shape8()
        d0 = autosched.decide(s)
        assert d0.placement_epoch == 0
        assert len(autosched.cache_info()) == 1
        pl = placement_from_loads(HOT, 4, capacity_factor=1.2, top_k=2)
        autosched.set_placement(pl)
        # the stale line survives (running jits still trace against it);
        # a fresh decide under the new epoch adds a second line
        assert len(autosched.cache_info()) == 1
        d1 = autosched.decide(s)
        assert d1.placement_epoch == 1
        assert len(autosched.cache_info()) == 2
        summary = autosched.cache_summary()
        assert "placement-epoch=1" in summary
        assert "STALE" in summary                    # the epoch-0 line

    def test_invalidate_by_shape(self):
        sa, sb = shape8(), shape8(B=16)
        autosched.decide(sa)
        autosched.decide(sb)
        assert len(autosched.cache_info()) == 2
        assert autosched.invalidate("test", shape=sa) == 1
        assert len(autosched.cache_info()) == 1
        assert autosched.invalidate("test") == 1     # no shape: flush all
        assert len(autosched.cache_info()) == 0

    def test_decide_placement(self):
        s = shape8()
        pl, t_pl, t_uni = autosched.decide_placement(
            s, HOT, schedule="s1", capacity_factor=1.2, top_k=2)
        assert pl is not None and t_pl < t_uni
        none, t1, t2 = autosched.decide_placement(
            s, EVEN, schedule="s1", capacity_factor=1.2, top_k=2)
        assert none is None and t1 == t2

    def test_rebalance_lifecycle(self):
        s = shape8()
        # nothing cached yet: no shapes to score, no-op
        assert autosched.maybe_rebalance(HOT) is None
        autosched.decide(s)
        epoch = autosched.maybe_rebalance(HOT, capacity_factor=1.2,
                                          top_k=2)
        assert epoch == 1
        installed = autosched.current_placement()
        assert installed is not None and not installed.is_identity
        # steady state: same loads, same placement -> no re-jit
        assert autosched.maybe_rebalance(HOT, capacity_factor=1.2,
                                         top_k=2) is None
        # loads even out: placement cleared (a new epoch, so retraces
        # decide fresh), then further even loads are a no-op
        assert autosched.maybe_rebalance(EVEN, capacity_factor=1.2,
                                         top_k=2) == 2
        assert autosched.current_placement() is None
        assert autosched.maybe_rebalance(EVEN, capacity_factor=1.2,
                                         top_k=2) is None

    def test_rebalance_infer_keeps_full_capacity(self):
        s = shape8(infer=True)
        autosched.decide(s)
        epoch = autosched.maybe_rebalance(HOT, capacity_factor=1.2,
                                          top_k=2, infer=True)
        pl = autosched.current_placement()
        # decode runs drop-free: any installed placement must be full-cap
        if epoch is not None and pl is not None:
            assert pl.cap_frac == 1.0

    def test_rebalance_ignores_foreign_shapes(self):
        # only decisions matching the load vector's E participate
        autosched.decide(shape8(E=16, k=2))
        assert autosched.maybe_rebalance(HOT, capacity_factor=1.2,
                                         top_k=2) is None


# ------------------------------------------------------- executor parity


def _run(script, *args, n_devices=8, timeout=900):
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = HELPERS + os.pathsep + env["PYTHONPATH"]
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_parity_merged_mesh():
    out = _run("run_placement_parity.py", "merged")
    assert "OK merged" in out


def test_parity_distinct_mesh():
    out = _run("run_placement_parity.py", "distinct")
    assert "OK distinct" in out
