"""Parm schedule tests: numerical equivalence (subprocess, 8 fake devices)
and communication-volume claims vs the paper's closed forms (Eq. 1/11/14)."""

import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def _run(script, *args, n_devices=8, timeout=600):
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=subprocess_env(n_devices), capture_output=True, text=True,
        timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestScheduleEquivalence:
    def test_merged_production_mapping(self):
        """baseline == S1 == S2 == s1_seqpar (outputs + grads), MP==ESP."""
        out = _run("run_schedule_equiv.py", "merged")
        assert "OK merged" in out

    def test_distinct_axes_nmp_neq_nesp(self):
        """Same, on a dedicated (ep, esp, mp) mesh (N_MP != N_ESP space)."""
        out = _run("run_schedule_equiv.py", "distinct")
        assert "OK distinct" in out


class TestCommVolumes:
    def test_volumes_match_paper_closed_forms(self):
        """Collective bytes parsed from compiled HLO must match Eq. (1),
        (11) and (14) exactly, per schedule."""
        out = _run("run_comm_volume.py")
        assert "VOLUMES OK" in out

    def test_s1_seqpar_strictly_less(self):
        out = _run("run_comm_volume.py")
        # helper prints the per-schedule totals; seqpar must be minimal
        lines = {l.split()[0]: int(l.split()[1])
                 for l in out.splitlines() if l.startswith(("baseline",
                                                            "s1 ", "s1_"))
                 or l.startswith("s2 ")}
        assert lines["s1_seqpar"] <= lines["s1"]
        assert lines["s1"] < lines["baseline"]
