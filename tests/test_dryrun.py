"""Dry-run machinery tests on a scaled (8 fake device) mesh: the same
lower+compile path as the production 512-chip run, per arch family."""

import json
import os
import subprocess
import sys

import pytest

from conftest import subprocess_env

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _dryrun(arch, shape, mesh="single", schedule=None, timeout=900):
    env = subprocess_env(8)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh]
    if schedule:
        cmd += ["--schedule", schedule]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout, cwd=os.path.dirname(SRC))
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "train_4k"),          # dense
    ("qwen3-moe-30b-a3b", "train_4k"),     # fine-grained MoE
    ("xlstm-350m", "decode_32k"),          # recurrent decode
    ("whisper-tiny", "decode_32k"),        # enc-dec cross-attn decode
    ("hymba-1.5b", "long_500k"),           # hybrid long-context decode
])
def test_scaled_dryrun_compiles(arch, shape):
    out = _dryrun(arch, shape)
    assert "dry-run complete" in out


def test_multi_pod_axis_shards():
    out = _dryrun("qwen3-moe-30b-a3b", "train_4k", mesh="multi")
    assert "dry-run complete" in out


def test_schedule_override_changes_collectives():
    """baseline must emit an all-reduce (ESP-AllReduce); s1 must not."""
    _dryrun("qwen3-moe-30b-a3b", "prefill_32k", schedule="baseline")
    _dryrun("qwen3-moe-30b-a3b", "prefill_32k", schedule="s1")
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
    with open(os.path.join(
            art, "qwen3-moe-30b-a3b__prefill_32k__single__baseline.json")) \
            as f:
        base = json.load(f)
    with open(os.path.join(
            art, "qwen3-moe-30b-a3b__prefill_32k__single__s1.json")) as f:
        s1 = json.load(f)
    assert base["collectives"]["counts"].get("all-reduce", 0) > 0
    base_a2a = base["collectives"]["bytes"]["all-to-all"]
    s1_a2a = s1["collectives"]["bytes"]["all-to-all"]
    assert s1_a2a < base_a2a  # PauseMP divides dispatch volume by N_MP
    assert (s1["collectives"]["total_bytes"]
            < base["collectives"]["total_bytes"])


def test_long500k_skips_whisper():
    env = subprocess_env(8)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "long_500k"],
        env=env, capture_output=True, text=True, timeout=300,
        cwd=os.path.dirname(SRC))
    assert r.returncode == 0
    assert "[skip]" in r.stdout
