"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype swept."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.gating import GateConfig, capacity, topk_gate
from repro.kernels import ops, ref


class TestFlashAttention:
    @pytest.mark.parametrize("B,L,H,K,hd", [
        (2, 256, 4, 2, 64), (1, 512, 8, 1, 32), (2, 128, 4, 4, 128),
        (1, 384, 6, 6, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_gqa(self, B, L, H, K, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, L, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, L, K, hd), dtype)
        v = jax.random.normal(ks[2], (B, L, K, hd), dtype)
        out = ops.flash_attention(q, k, v, causal=True)
        kk, vv = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
        exp = ref.flash_attention_ref(qq := q, kk, vv, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 64))
        k = jax.random.normal(ks[1], (1, 256, 4, 64))
        v = jax.random.normal(ks[2], (1, 256, 4, 64))
        out = ops.flash_attention(q, k, v, causal=True, window=window)
        exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 128, 2, 32))
        k = jax.random.normal(ks[1], (2, 128, 2, 32))
        v = jax.random.normal(ks[2], (2, 128, 2, 32))
        out = ops.flash_attention(q, k, v, causal=False)
        exp = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(L=st.sampled_from([64, 192, 320]),
           hd=st.sampled_from([32, 64]),
           seed=st.integers(0, 100))
    def test_property_sweep(self, L, hd, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, L, 2, hd))
        k = jax.random.normal(ks[1], (1, L, 2, hd))
        v = jax.random.normal(ks[2], (1, L, 2, hd))
        out = ops.flash_attention(q, k, v)
        exp = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=3e-5, rtol=3e-5)


class TestExpertFFN:
    @pytest.mark.parametrize("E,T,M,F", [
        (4, 64, 96, 160), (8, 128, 64, 256), (2, 256, 128, 128),
    ])
    @pytest.mark.parametrize("glu", [True, False])
    def test_vs_ref(self, E, T, M, F, glu):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (E, T, M))
        w1 = jax.random.normal(ks[1], (E, M, F)) * 0.1
        w3 = jax.random.normal(ks[2], (E, M, F)) * 0.1 if glu else None
        w2 = jax.random.normal(ks[3], (E, F, M)) * 0.1
        act = "silu" if glu else "gelu"
        out = ops.expert_ffn(x, w1, w3, w2, act=act)
        exp = ref.expert_ffn_ref(x, w1, w3, w2, act=act)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=5e-4, rtol=5e-4)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (2, 64, 64), jnp.bfloat16)
        w1 = (jax.random.normal(ks[1], (2, 64, 128)) * 0.1).astype(
            jnp.bfloat16)
        w3 = (jax.random.normal(ks[2], (2, 64, 128)) * 0.1).astype(
            jnp.bfloat16)
        w2 = (jax.random.normal(ks[3], (2, 128, 64)) * 0.1).astype(
            jnp.bfloat16)
        out = ops.expert_ffn(x, w1, w3, w2)
        exp = ref.expert_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=3e-2, rtol=3e-2)


class TestMoEDispatchCombine:
    def _routing(self, S, M, E, k, cap, seed=0):
        rng = jax.random.PRNGKey(seed)
        x = jax.random.normal(rng, (S, M))
        wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, E)) * 0.3
        eidx, slot, w, _ = topk_gate(
            x, wg, GateConfig(n_experts=E, top_k=k, capacity_factor=4.0),
            cap)
        flat = jnp.where(slot < cap, eidx * cap + slot, E * cap)
        return x, flat.astype(jnp.int32), w

    @pytest.mark.parametrize("S,M,E,k,cap", [
        (128, 64, 8, 2, 48), (256, 128, 4, 1, 96), (64, 32, 16, 4, 24),
    ])
    def test_dispatch_combine_vs_ref(self, S, M, E, k, cap):
        x, flat, w = self._routing(S, M, E, k, cap)
        n_slots = E * cap
        buf = ops.moe_dispatch(x, flat, n_slots)
        bref = ref.moe_dispatch_ref(x, flat, n_slots)
        np.testing.assert_allclose(np.asarray(buf), np.asarray(bref),
                                   atol=1e-6)
        y = ops.moe_combine(bref, flat, w)
        yref = ref.moe_combine_ref(bref, flat, w)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   atol=1e-5, rtol=1e-5)

    def test_dispatch_drops(self):
        S, M, E, cap = 128, 32, 2, 8   # force drops
        x, flat, w = self._routing(S, M, E, 1, cap)
        assert (np.asarray(flat) == E * cap).any()
        buf = ops.moe_dispatch(x, flat, E * cap)
        bref = ref.moe_dispatch_ref(x, flat, E * cap)
        np.testing.assert_allclose(np.asarray(buf), np.asarray(bref),
                                   atol=1e-6)


class TestRMSNorm:
    @settings(max_examples=10, deadline=None)
    @given(R=st.sampled_from([32, 128]), D=st.sampled_from([64, 96, 256]),
           seed=st.integers(0, 50))
    def test_vs_ref(self, R, D, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (R, D))
        s = jax.random.uniform(jax.random.PRNGKey(seed + 1), (D,))
        np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, s)),
                                   np.asarray(ref.rmsnorm_ref(x, s)),
                                   atol=2e-6, rtol=2e-6)
