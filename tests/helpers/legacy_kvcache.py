"""PR 5's slot-per-request slab KV cache + engine, kept as the ORACLE.

The serving engine migrated to a paged block arena (PR 7:
``repro.serve.kvcache``); this module preserves the previous memory
model verbatim — one ``max_len`` slab row per request, whole-prompt
one-shot prefill via ``model.prefill_step``, whole-pool decode via
``model.decode_step`` — so the paged engine can be checked against it
bit-for-bit (``tests/helpers/run_paged_parity.py``): the same request
trace must produce identical greedy token streams through both.

Do not "fix" or modernize this file: its value is that it is the old
code path, frozen.
"""

from __future__ import annotations

import heapq
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampler import SamplerConfig
from repro.serve.engine import Completion, Request, _pow2, _State


class LegacyKVCachePool:
    """PR 5's slab pool: a ``max_batch``-row KV cache + slot free list."""

    def __init__(self, model, max_batch: int, max_len: int, dtype=None):
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.cache = model.init_cache(self.max_batch, self.max_len, dtype)
        for leaf in jax.tree.leaves(self.cache):
            if leaf.ndim < 2 or leaf.shape[1] != self.max_batch:
                raise ValueError(
                    "LegacyKVCachePool needs every cache leaf shaped "
                    f"(layers, max_batch, ...); got {leaf.shape}")
        self._free = list(range(self.max_batch))   # min-heap of free slots
        heapq.heapify(self._free)
        self._slot_of: dict = {}                   # request id -> slot

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._slot_of)

    def can_admit(self, n: int = 1) -> bool:
        return len(self._free) >= n

    def alloc(self, rid) -> int:
        if rid in self._slot_of:
            raise KeyError(f"request {rid!r} already holds slot "
                           f"{self._slot_of[rid]}")
        if not self._free:
            raise RuntimeError("KV-cache pool exhausted "
                               f"({self.max_batch} slots live)")
        slot = heapq.heappop(self._free)
        self._slot_of[rid] = slot
        return slot

    def release(self, rid) -> int:
        if rid not in self._slot_of:
            raise KeyError(f"request {rid!r} holds no slot")
        slot = self._slot_of.pop(rid)
        heapq.heappush(self._free, slot)
        return slot

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]


def make_legacy_prefill_step(model, mesh, dims, schedule=None):
    """PR 5's engine prefill: gather pool rows by slot, one-shot
    ``model.prefill_step`` over the padded prompts, scatter back."""
    def prefill_step(params, pool, tokens, lengths, slots, keys, temps,
                     topks):
        from repro.serve.sampler import sample
        rows = jax.tree.map(lambda a: jnp.take(a, slots, axis=1), pool)
        logits, rows2 = model.prefill_step(
            params, rows, {"tokens": tokens}, lengths=lengths,
            mesh=mesh, dims=dims, schedule=schedule)
        pool2 = jax.tree.map(lambda a, r: a.at[:, slots].set(r), pool,
                             rows2)
        return sample(logits, keys, temps, topks), pool2

    return prefill_step


def make_legacy_decode_step(model, mesh, dims, schedule=None):
    """PR 5's engine decode: whole-pool ``model.decode_step`` at per-row
    positions + per-row sampling."""
    def decode_step(params, pool, tokens, steps, keys, temps, topks):
        from repro.serve.sampler import sample
        logits, pool2 = model.decode_step(
            params, pool, {"tokens": tokens, "step": steps},
            mesh=mesh, dims=dims, schedule=schedule)
        return sample(logits[:, -1], keys, temps, topks), pool2

    return decode_step


class LegacyEngine:
    """PR 5's continuous-batching engine over the slab pool (oracle)."""

    def __init__(self, model, mesh, dims, *, max_batch: int = 8,
                 max_len: int = 256, schedule=None, prefill_batch: int = 1,
                 eos_token=None):
        self.model, self.mesh, self.dims = model, mesh, dims
        self.max_batch, self.max_len = int(max_batch), int(max_len)
        self.prefill_batch = max(int(prefill_batch), 1)
        self.eos_token = eos_token
        self.pool = LegacyKVCachePool(model, self.max_batch, self.max_len)
        self._prefill = jax.jit(make_legacy_prefill_step(
            model, mesh, dims, schedule), donate_argnums=(1,))
        self._decode = jax.jit(make_legacy_decode_step(
            model, mesh, dims, schedule), donate_argnums=(1,))
        self.queue: deque = deque()
        self.active: dict = {}
        self.stats = {"prefill_calls": 0, "decode_calls": 0,
                      "prefill_tokens": 0, "decode_tokens": 0,
                      "max_active": 0, "admitted": 0}
        self._rid = 0

    def submit(self, prompt, max_new_tokens: int = 16,
               sampler: SamplerConfig = SamplerConfig(), rid=None) -> int:
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError("prompt + max_new_tokens exceeds max_len")
        if rid is None:
            rid, self._rid = self._rid, self._rid + 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens), sampler=sampler)
        self.queue.append((req, time.perf_counter()))
        return rid

    def step(self, params) -> list:
        group = []
        while (self.queue and len(group) < self.prefill_batch
               and self.pool.can_admit()):
            req, t_submit = self.queue.popleft()
            slot = self.pool.alloc(req.rid)
            group.append(_State(req, slot, 0, t_submit,
                                time.perf_counter()))
        if group:
            self._prefill_group(params, group)
        elif self.active:
            self._decode_round(params)
        self.stats["max_active"] = max(self.stats["max_active"],
                                       len(self.active))
        return self._collect_finished()

    def run(self, params) -> list:
        done = []
        while self.queue or self.active:
            done.extend(self.step(params))
        return sorted(done, key=lambda c: c.rid)

    def _keys(self, states):
        return np.array(
            [[s.req.sampler.seed & 0xFFFFFFFF,
              len(s.req.prompt) + len(s.generated)] for s in states],
            np.uint32)

    def _prefill_group(self, params, group):
        lens = [len(s.req.prompt) for s in group]
        lb = min(max(_pow2(max(lens)), 8), self.max_len)
        tokens = np.zeros((len(group), lb), np.int32)
        for i, s in enumerate(group):
            tokens[i, :lens[i]] = s.req.prompt
        temps = np.array([s.req.sampler.temperature for s in group],
                         np.float32)
        topks = np.array([s.req.sampler.top_k for s in group], np.int32)
        slots = np.array([s.slot for s in group], np.int32)
        tok, self.pool.cache = self._prefill(
            params, self.pool.cache, tokens,
            np.array(lens, np.int32), slots, self._keys(group), temps,
            topks)
        tok = np.asarray(tok)
        t = time.perf_counter()
        for i, s in enumerate(group):
            s.last_tok = int(tok[i])
            s.generated.append(s.last_tok)
            s.t_first = t
            self.active[s.slot] = s
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += sum(lens)
        self.stats["admitted"] += len(group)

    def _decode_round(self, params):
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        steps = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        topks = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        states = sorted(self.active.values(), key=lambda s: s.slot)
        for s in states:
            tokens[s.slot, 0] = s.last_tok
            steps[s.slot] = s.pos
            temps[s.slot] = s.req.sampler.temperature
            topks[s.slot] = s.req.sampler.top_k
        keys[[s.slot for s in states]] = self._keys(states)
        tok, self.pool.cache = self._decode(
            params, self.pool.cache, tokens, steps, keys, temps, topks)
        tok = np.asarray(tok)
        for s in states:
            s.last_tok = int(tok[s.slot])
            s.generated.append(s.last_tok)
            s.pos += 1
        self.stats["decode_calls"] += 1
        self.stats["decode_tokens"] += len(states)

    def _collect_finished(self) -> list:
        done = []
        for slot, s in list(self.active.items()):
            full = len(s.generated) >= s.req.max_new_tokens
            eos = (self.eos_token is not None
                   and s.generated and s.generated[-1] == self.eos_token)
            capped = s.pos >= self.max_len
            if not (full or eos or capped):
                continue
            s.t_done = time.perf_counter()
            del self.active[slot]
            self.pool.release(s.req.rid)
            done.append(Completion(
                rid=s.req.rid, prompt=s.req.prompt,
                tokens=list(s.generated), text="",
                timing={"ttft": 0.0, "latency": 0.0, "queued": 0.0}))
        return done
