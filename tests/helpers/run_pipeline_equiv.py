"""Subprocess helper: pipelined-schedule parity on 8 fake devices.

Run as:  python tests/helpers/run_pipeline_equiv.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP (production mapping)
  mode = distinct : mesh (ep=2, esp=2, mp=2), N_MP != N_ESP exercised
  mode = drops    : merged mesh, capacity_factor < 1 forces dropped tokens

For every base schedule (baseline/s1/s2[/s1_seqpar]) and n_chunks in
{1, 2, 4}: the pipelined body's outputs AND gradients must match the
unchunked schedule's bitwise-close.  Chunking happens after the gate, so
drop patterns are identical by construction — `drops` mode asserts it.
Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh


def main(mode: str):
    if mode in ("merged", "drops"):
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        scheds = ["baseline", "s1", "s2", "s1_seqpar"]
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        scheds = ["baseline", "s1", "s2"]

    f = 0.5 if mode == "drops" else 8.0
    cfg0 = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                     capacity_factor=f, schedule="baseline")
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    # drops mode needs a pool big enough that the 8-aligned capacity floor
    # doesn't absorb all overflow on the MP-split (s1_seqpar) pool.
    B = 32 if mode == "drops" else 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 32))

    def run(sched, n_chunks, grad=False):
        cfg = replace(cfg0, pipeline_chunks=n_chunks)
        if not grad:
            y, aux = jax.jit(lambda x, p, c=cfg, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=c, schedule=s))(x, params)
            return np.asarray(y), {k: float(v) for k, v in aux.items()
                                   if getattr(v, "ndim", 0) == 0}

        def loss(p, x):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                               schedule=sched)
            return jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"]
        return jax.tree.map(np.asarray, jax.jit(jax.grad(loss))(params, x))

    for sched in scheds:
        y_ref, aux_ref = run(sched, 1)
        if mode == "drops":
            assert aux_ref["drop_frac"] > 0.0, (sched, aux_ref)
        g_ref = run(sched, 1, grad=True)
        for nc in (1, 2, 4):
            y, aux = run(sched, nc)
            # bitwise-close: only f32 reassociation from XLA fusing the
            # differently-shaped chunked matmuls (same tolerances as
            # run_schedule_equiv.py)
            np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-5,
                                       err_msg=f"{sched} nc={nc}")
            assert aux["drop_frac"] == aux_ref["drop_frac"], (sched, nc)
            g = run(sched, nc, grad=True)
            jax.tree.map(
                lambda a, b: np.testing.assert_allclose(
                    a, b, rtol=5e-3, atol=5e-4,
                    err_msg=f"{sched} nc={nc} grad"),
                g, g_ref)

    # the explicit *_pipe schedule names resolve too (chunks from config)
    y_pipe, _ = run("s1_pipe", 4)
    y_s1, _ = run("s1", 1)
    np.testing.assert_allclose(y_pipe, y_s1, rtol=2e-4, atol=2e-5)
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
