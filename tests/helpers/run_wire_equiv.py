"""Subprocess helper: wire-precision parity on 8 fake devices.

Run as:  python tests/helpers/run_wire_equiv.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP (production mapping)
  mode = distinct : mesh (ep=2, esp=2, mp=2), N_MP != N_ESP exercised
  mode = drops    : merged mesh, capacity_factor < 1 forces dropped tokens
  mode = pipe     : merged mesh, pipeline_chunks=2 (the *_pipe bodies)

For every schedule and wire_dtype in {f32, bf16, fp8_e4m3}:

  * forward outputs within the dtype's error envelope of the f32 run,
  * gradients (params + input) within a looser envelope (the backward
    collective runs in the same wire dtype),
  * routing EXACTLY invariant: the gate runs before any wire encode, so
    aux_loss / z_loss / drop_frac must be bit-identical to f32, and in
    drops mode the zero-row pattern of the output (dropped tokens
    produce exact zeros) must match f32's bit-for-bit.

Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CommConfig
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh

WIRES = ["f32", "bf16", "fp8_e4m3"]
# max |y - y_f32| envelopes for O(1) activations through two wire
# collectives + a weighted combine; grads go through the transposed
# collectives in the same dtype, so they get ~4x headroom.
FWD_TOL = {"f32": 0.0, "bf16": 0.05, "fp8_e4m3": 0.5}
GRAD_RTOL = {"f32": 0.0, "bf16": 0.05, "fp8_e4m3": 0.5}


def main(mode: str):
    if mode in ("merged", "drops", "pipe"):
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        scheds = ["baseline", "s1", "s2", "s1_seqpar"]
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        scheds = ["baseline", "s1", "s2"]

    f = 0.5 if mode == "drops" else 8.0
    n_chunks = 2 if mode == "pipe" else 1
    cfg0 = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                     capacity_factor=f, schedule="baseline",
                     pipeline_chunks=n_chunks)
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    B = 32 if mode == "drops" else 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 32))

    def run(sched, wire, grad=False):
        cfg = replace(cfg0, comm=CommConfig(wire_dtype=wire))
        if not grad:
            y, aux = jax.jit(lambda x, p, c=cfg, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=c, schedule=s))(x, params)
            return np.asarray(y), {k: float(v) for k, v in aux.items()
                                   if getattr(v, "ndim", 0) == 0}

        def loss(p, x):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                               schedule=sched)
            return jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"]
        g = jax.jit(jax.grad(loss, argnums=(0, 1)))(params, x)
        return jax.tree.map(np.asarray, g)

    for sched in scheds:
        y_ref, aux_ref = run(sched, "f32")
        g_ref = run(sched, "f32", grad=True)
        gscale = max(float(np.max(np.abs(l)))
                     for l in jax.tree.leaves(g_ref))
        if mode == "drops":
            assert aux_ref["drop_frac"] > 0.0, (sched, aux_ref)
        for wire in WIRES:
            y, aux = run(sched, wire)
            err = float(np.max(np.abs(y - y_ref)))
            assert err <= FWD_TOL[wire], (sched, wire, err)
            if wire != "f32":
                # the wire path must actually engage (flag not inert)
                assert err > 0.0, (sched, wire, "wire had no effect?")
            # routing invariance: the gate runs pre-encode, so every
            # gate-derived scalar is bit-identical across wire dtypes
            for k in ("aux_loss", "z_loss", "drop_frac"):
                assert aux[k] == aux_ref[k], (sched, wire, k, aux, aux_ref)
            if mode == "drops":
                # dropped tokens are exact zeros in every schedule's
                # output; identical zero masks <=> identical drop sets
                np.testing.assert_array_equal(
                    (np.abs(y) == 0.0).all(axis=-1),
                    (np.abs(y_ref) == 0.0).all(axis=-1),
                    err_msg=f"{sched} {wire} drop pattern")
            g = run(sched, wire, grad=True)
            tol = GRAD_RTOL[wire] * max(gscale, 1.0)
            jax.tree.map(
                lambda a, b: np.testing.assert_allclose(
                    a, b, rtol=GRAD_RTOL[wire] or 1e-12, atol=tol or 1e-12,
                    err_msg=f"{sched} {wire} grad"),
                g, g_ref)
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
