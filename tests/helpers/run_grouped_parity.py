"""Subprocess helper: grouped-megakernel schedule (s1g) parity vs the
capacity-pool s1 path it fuses.

Run as:  python tests/helpers/run_grouped_parity.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP — chunks {1,2} x
                    wire {f32, bf16}, fwd + grad envelopes
  mode = distinct : mesh (ep=2, esp=2, mp=2) — same grid on the
                    three-axis mapping
  mode = skew     : merged mesh, gate weights biased so expert 0 takes
                    almost every token and several experts route ZERO
                    rows — the ragged kernel's empty-group predication —
                    with capacity_factor < 1 so drops occur; asserts
                    bit-identical drop masks on top of the fwd envelope
  mode = local    : single-device (1,1) mesh — the fully fused local
                    megakernel (dispatch gather prologue + combine
                    scatter epilogue in one kernel), wire {f32, bf16,
                    fp8_e4m3}, fwd + grad

s1g is ``fuse_grouped(s1)``: identical gate, identical a2a layout (the
wire payload just travels un-decoded for plain-cast wire dtypes), and a
ragged grouped GEMM that skips the capacity slots the pool path
multiplies as zeros.  Zero-padding is exact (FFN(0) == 0), so the two
paths compute the same function:

  * forward outputs within a tight f32 envelope,
  * gate-derived aux scalars (aux_loss / z_loss / drop_frac) and the
    per-expert routed-load vector bit-identical,
  * zero-row drop masks bit-identical (skew mode),
  * parameter gradients within the run_plan_parity envelopes.

Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CommConfig
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh

FWD_TOL = dict(rtol=2e-4, atol=2e-5)
GRAD_TOL = dict(rtol=5e-3, atol=5e-4)
# fp8 wire: the codec itself quantizes, parity only needs both paths to
# agree through the same codec — but the local fused path composes the
# roundtrip at a different point than the chunked pool path, so give the
# envelope quantization headroom
FWD_TOL_FP8 = dict(rtol=5e-2, atol=5e-3)


def grids(mode):
    if mode == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        return mesh, dims, (1, 2), ("f32", "bf16")
    if mode == "distinct":
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        return mesh, dims, (1, 2), ("f32", "bf16")
    if mode == "skew":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        return mesh, dims, (1, 2), ("f32",)
    if mode == "local":
        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        return mesh, dims, (1,), ("f32", "bf16", "fp8_e4m3")
    raise SystemExit(f"unknown mode {mode}")


def main(mode: str):
    mesh, dims, chunk_grid, wire_grid = grids(mode)

    f = 0.5 if mode == "skew" else 8.0
    cfg0 = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                     capacity_factor=f, schedule="baseline")
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    if mode == "skew":
        # bias the router hard toward expert 0 (second choice expert 1)
        # through feature 0, which the tokens below pin to 1.0: most
        # experts route zero rows — the ragged kernel must skip their
        # groups entirely — and expert 0 overflows its capacity
        bias = jnp.zeros((cfg0.n_experts,)).at[0].set(8.0).at[1].set(4.0)
        params = dict(params, wg=params["wg"] * 0.05
                      + jnp.zeros_like(params["wg"]).at[0, :].set(bias))
    B = 32 if mode == "skew" else 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 32))
    if mode == "skew":
        x = x.at[..., 0].set(1.0)

    def run_pair(nc, wire):
        """One jit: (y, aux, grads) for s1g AND the s1 pool golden."""
        cfg = replace(cfg0, pipeline_chunks=nc,
                      comm=CommConfig(wire_dtype=wire))

        def loss(p, x, s):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                               schedule=s)
            return (jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"],
                    (y, aux))

        def both(p, x):
            (_, (y1, a1)), g1 = jax.value_and_grad(
                loss, has_aux=True)(p, x, "s1g")
            (_, (y2, a2)), g2 = jax.value_and_grad(
                loss, has_aux=True)(p, x, "s1")
            return y1, a1, g1, y2, a2, g2

        out = jax.jit(both)(params, x)
        return jax.tree.map(np.asarray, out)

    for nc in chunk_grid:
        for wire in wire_grid:
            tag = f"s1g nc={nc} wire={wire} [{mode}]"
            y, aux, g, y_ref, aux_ref, g_ref = run_pair(nc, wire)
            fwd_tol = FWD_TOL_FP8 if wire == "fp8_e4m3" else FWD_TOL
            np.testing.assert_allclose(y, y_ref, err_msg=tag, **fwd_tol)
            # identical gate on both paths: every gate-derived scalar
            # and the routed-load vector must be bit-identical
            for k in ("aux_loss", "z_loss", "drop_frac"):
                assert float(aux[k]) == float(aux_ref[k]), \
                    (tag, k, aux, aux_ref)
            np.testing.assert_array_equal(aux["expert_load"],
                                          aux_ref["expert_load"],
                                          err_msg=f"{tag} expert_load")
            if mode == "skew":
                assert float(aux_ref["drop_frac"]) > 0.0, tag
                # several experts must actually be empty for this mode
                # to exercise the zero-group predication
                assert (np.asarray(aux_ref["expert_load"]) == 0).any(), tag
                np.testing.assert_array_equal(
                    (np.abs(y) == 0.0).all(axis=-1),
                    (np.abs(y_ref) == 0.0).all(axis=-1),
                    err_msg=f"{tag} drop mask")
            if wire != "fp8_e4m3":
                jax.tree.map(
                    lambda a, b: np.testing.assert_allclose(
                        a, b, err_msg=f"{tag} grad", **GRAD_TOL),
                    g, g_ref)
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
