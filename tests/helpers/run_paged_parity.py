"""Paged-vs-slab serving oracle: identical greedy token streams.

PR 7 replaced the slot-per-request KV slab with a paged block arena
(page tables + shared-prefix reuse + chunked prefill).  The refactor's
contract is BITWISE: the same request trace through the paged engine
and through PR 5's frozen slab engine (``tests/helpers/legacy_kvcache``)
must produce identical token streams — not merely close logits.

Modes (argv[1], default ``trace``):

  trace     1 device.  A mixed join/leave trace (staggered prompt and
            budget lengths over a 2-row pool, so rows join and leave the
            decode batch mid-run next to idle rows) is served by the
            legacy slab engine and by the paged engine; streams must
            match token-for-token.  Then, on a shared-system-prompt
            workload, the paged engine must be bitwise invariant to its
            own features: prefix-cache ON == OFF (with hits actually
            taken and prefill work actually saved) and chunked prefill
            == one-shot (with more prefill calls, same tokens).
  multidev  8 fake CPU devices, (4, 2) data x model mesh, MoE arch with
            sharded decode schedules.  Legacy vs paged on the same
            trace, prefix cache off (identical jitted shapes), again
            token-for-token.

Prints PAGED PARITY OK on success.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))
sys.path.insert(0, os.path.dirname(__file__))

MODE = sys.argv[1] if len(sys.argv) > 1 else "trace"
if MODE == "multidev":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ModelConfig, get_config  # noqa: E402
from repro.core.moe import MoEConfig  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.parallel.mesh import ParallelDims, make_mesh  # noqa: E402
from repro.serve import Engine, SamplerConfig  # noqa: E402
from legacy_kvcache import LegacyEngine  # noqa: E402


def tiny_moe_cfg():
    return ModelConfig(
        name="parity-moe", arch_type="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=128, rope_theta=1e4,
        moe=MoEConfig(d_model=64, d_ff=96, n_experts=4, top_k=2,
                      capacity_factor=2.0, schedule="auto"),
        moe_period=1, remat=False)


def streams(engine, params, spec, prompts):
    for (plen, gen), p in zip(spec, prompts):
        engine.submit(p, gen, sampler=SamplerConfig())
    done = engine.run(params)
    assert len(done) == len(spec), (len(done), len(spec))
    return {c.rid: list(c.tokens) for c in done}


def check_match(a, b, label):
    assert set(a) == set(b), (label, sorted(a), sorted(b))
    for rid in sorted(a):
        assert a[rid] == b[rid], (
            f"{label}: rid {rid} diverges\n legacy {a[rid]}\n paged  "
            f"{b[rid]}")
    print(f"{label}: {len(a)} streams bitwise identical")


def run_trace(model, mesh, dims, params, *, max_batch, max_len, spec,
              prompts, **paged_kw):
    legacy = streams(LegacyEngine(model, mesh, dims, max_batch=max_batch,
                                  max_len=max_len), params, spec, prompts)
    paged_eng = Engine(model, mesh, dims, max_batch=max_batch,
                       max_len=max_len, **paged_kw)
    paged = streams(paged_eng, params, spec, prompts)
    return legacy, paged, paged_eng


def main_trace():
    cfg = tiny_moe_cfg()
    model = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    # mixed join/leave: budgets chosen so requests finish at different
    # rounds and later admissions decode next to idle + mid-life rows
    spec = [(9, 12), (5, 6), (13, 4), (4, 10), (7, 3)]
    prompts = [list(rng.randint(1, cfg.vocab_size, n)) for n, _ in spec]
    legacy, paged, _ = run_trace(
        model, mesh, dims, params, max_batch=2, max_len=32, spec=spec,
        prompts=prompts, prefix_cache=False)
    check_match(legacy, paged, "trace legacy-vs-paged")

    # shared system prompt: prefix hits and chunking must not move bits
    sysp = list(rng.randint(1, cfg.vocab_size, 37))
    pspec = [(37 + n, 6) for n in (3, 5, 2)]
    pprompts = [sysp + list(rng.randint(1, cfg.vocab_size, n))
                for n in (3, 5, 2)]

    def paged_streams(**kw):
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64,
                     schedule="s1", **kw)
        return streams(eng, params, pspec, pprompts), eng

    cold, cold_eng = paged_streams(prefix_cache=False)
    hot, hot_eng = paged_streams(prefix_cache=True)
    check_match(cold, hot, "prefix hit-vs-cold")
    assert hot_eng.stats["prefix_hits"] >= 2, hot_eng.stats
    assert hot_eng.stats["prefix_tokens"] > 0
    # the shared prefix is computed once: later admissions prefill only
    # their suffix tokens
    assert (hot_eng.stats["prefill_tokens"]
            < cold_eng.stats["prefill_tokens"]), (
        hot_eng.stats, cold_eng.stats)

    chunked, chunk_eng = paged_streams(prefix_cache=False, prefill_chunk=8)
    check_match(cold, chunked, "chunked-vs-one-shot")
    assert (chunk_eng.stats["prefill_calls"]
            > cold_eng.stats["prefill_calls"])
    assert (chunk_eng.stats["prefill_tokens"]
            == cold_eng.stats["prefill_tokens"])

    both, both_eng = paged_streams(prefix_cache=True, prefill_chunk=8)
    check_match(cold, both, "chunked+prefix-vs-cold")
    assert both_eng.stats["prefix_hits"] >= 2


def main_multidev():
    assert jax.device_count() == 8, jax.device_count()
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    spec = [(9, 6), (5, 5), (11, 4), (4, 6), (7, 3), (6, 5)]
    prompts = [list(rng.randint(1, cfg.vocab_size, n)) for n, _ in spec]
    legacy, paged, eng = run_trace(
        model, mesh, dims, params, max_batch=8, max_len=64, spec=spec,
        prompts=prompts, prefix_cache=False)
    check_match(legacy, paged, "multidev legacy-vs-paged")
    assert eng.pool.n_live == 0 and eng.pool.n_free_blocks \
        == eng.pool.n_blocks, "pages leaked"


def main():
    if MODE == "multidev":
        main_multidev()
    else:
        main_trace()
    print("PAGED PARITY OK")


if __name__ == "__main__":
    main()
