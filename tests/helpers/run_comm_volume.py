"""Subprocess helper: validate per-schedule collective volumes against the
paper's closed forms (Eq. 1, 11, 14) by parsing compiled HLO.

Mesh (4, 2) = (data, model): N_EP=4, N_ESP=N_MP=2 (merged).  Element size
4 bytes (f32).  Prints per-schedule totals and "VOLUMES OK".
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.analysis.hlo import parse_collectives
from repro.core.gating import capacity
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh

EL = 4  # f32 bytes


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    Ne, Ns, Nm = 4, 2, 2
    B, L, M = 32, 64, 64
    E, k, f = 8, 2, 2.0
    cfg = MoEConfig(d_model=M, d_ff=128, n_experts=E, top_k=k,
                    capacity_factor=f, saa_chunks=4)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((B, L, M))

    S = B * L // Ne                    # tokens per device cell
    T = capacity(S, cfg.gate_config())  # aligned to max(8, Nm) in apply_moe
    T = max(T, 8)
    totals = {}
    stats_by = {}
    for sched in ["baseline", "s1", "s2", "s1_seqpar"]:
        fjit = jax.jit(lambda x, p, s=sched: apply_moe(
            x, p, mesh=mesh, dims=dims, cfg=cfg, schedule=s)[0])
        txt = fjit.lower(x, params).compile().as_text()
        st = parse_collectives(txt)
        totals[sched] = st.total_bytes
        stats_by[sched] = st
        print(f"{sched} {st.total_bytes} {st.bytes_by_kind}")

    # --- Eq. (1): baseline = AG(S*M*Ns) + AR(E*T*M*Ns) + 2*A2A(E*T*M*Ns)
    st = stats_by["baseline"]
    assert st.bytes_by_kind["all-gather"] == S * M * Ns * EL, st.bytes_by_kind
    assert st.bytes_by_kind["all-to-all"] == 2 * E * (T * Ns) * M * EL
    assert st.bytes_by_kind["all-reduce"] == E * (T * Ns) * M * EL
    assert st.counts == {"all-gather": 1, "all-to-all": 2, "all-reduce": 1}

    # --- Eq. (11): S1 = 2*A2A(E*T*M*Ns/Nm) + AG(S*M)
    st = stats_by["s1"]
    assert st.bytes_by_kind["all-to-all"] == 2 * E * T * M * Ns // Nm * EL
    assert st.bytes_by_kind["all-gather"] == S * M * EL
    assert st.counts["all-to-all"] == 2

    # --- Eq. (14): S2 = 2*A2A(E*T*M*Ns/Nm) + AG(E*T*M) (chunked via SAA)
    st = stats_by["s2"]
    assert st.bytes_by_kind["all-to-all"] == 2 * E * T * M * Ns // Nm * EL
    assert st.bytes_by_kind["all-gather"] == E * T * M * EL
    # SAA chunking: combine a2a + gather split into saa_chunks pieces
    assert st.counts["all-to-all"] == 1 + cfg.saa_chunks
    assert st.counts["all-gather"] == cfg.saa_chunks

    # --- beyond-paper: s1_seqpar has NO MP collectives at all
    st = stats_by["s1_seqpar"]
    assert "all-gather" not in st.bytes_by_kind
    assert st.bytes_by_kind["all-to-all"] == 2 * E * T * M * Ns // Nm * EL

    print("VOLUMES OK")


if __name__ == "__main__":
    main()
