"""Subprocess helper: context-parallel decode (KV cache sharded along the
length dim over MP) must produce the same logits as the replicated layout.
This validates the §Perf cache-seq-shard lever end-to-end on 8 devices."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import cache_specs, make_serve_step, named_tree


def main():
    cfg = get_config("mistral-nemo-12b").reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(dp=("data",), mp=("model",))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, L = 4, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              cfg.vocab_size)
    serve = make_serve_step(model, mesh, dims)

    outs = {}
    for seq_shard in (False, True):
        c_specs = cache_specs(model, mesh, dims, B, L,
                              seq_shard=seq_shard)
        c_sh = named_tree(mesh, c_specs)
        cache = jax.jit(lambda: model.init_cache(B, L),
                        out_shardings=c_sh)()
        step = jax.jit(serve, in_shardings=(None, c_sh, None),
                       out_shardings=(None, c_sh))
        seq = []
        for t in range(L - 1):
            tok, cache = step(params, cache,
                              {"tokens": toks[:, t:t + 1],
                               "step": jnp.int32(t)})
            seq.append(np.asarray(tok))
        outs[seq_shard] = np.concatenate(seq, 1)

    np.testing.assert_array_equal(outs[False], outs[True])
    print("CACHE SEQSHARD OK")


if __name__ == "__main__":
    main()
