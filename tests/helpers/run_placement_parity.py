"""Subprocess helper: expert-placement parity for the plan executor.

Run as:  python tests/helpers/run_placement_parity.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP
  mode = distinct : mesh (ep=2, esp=2, mp=2)

Each mode runs schedules {s1, s2} x wire {f32, bf16} x chunks {1, 2}
with three placements against the unplaced run of the same schedule:

  * identity     — ``identity_placement(E, n_ep)`` pushed through the
                   full placement machinery (vector-capacity gate,
                   placed flat indices, gathered weights): forward
                   output, every aux value and every parameter gradient
                   must be BITWISE equal to the unplaced plan.  This is
                   the acceptance criterion that placement never
                   perturbs existing schedules.
  * rep2 (drops) — every expert replicated x2 on two distinct EP ranks
                   with ``cap_frac = 0.5``: the effective per-expert
                   capacity r_e * cap_p equals the unplaced capacity
                   exactly, so with a hot-skewed router and real drops
                   the kept/dropped decisions are the same set — aux
                   (drop_frac, expert_load) and the observable zero-row
                   drop mask bitwise, outputs/grads allclose (replica
                   weight-gradient scatter-adds reorder float sums).
  * hot (free)   — expert 0 replicated across ranks (uneven round-robin
                   split), ``cap_frac = 1.0``, capacity generous enough
                   that neither run drops: outputs/grads allclose, aux
                   bitwise, drop_frac == 0 in both.  Runs on wire f32,
                   chunks {1, 2}.

Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import CommConfig
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.core.placement import ExpertPlacement, identity_placement
from repro.parallel.mesh import ParallelDims, make_mesh

FWD_TOL = dict(rtol=2e-4, atol=2e-5)
GRAD_TOL = dict(rtol=5e-3, atol=5e-4)
E = 8


def grids(mode):
    if mode == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        return mesh, dims, 4
    if mode == "distinct":
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        return mesh, dims, 2
    raise SystemExit(f"unknown mode {mode}")


def rep2_placement(n_ep):
    """Every expert x2, replicas on distinct EP ranks, half capacity:
    r_e * cap_p == cap — same effective capacities as unplaced."""
    per = 2 * E // n_ep
    assignments = tuple((r * (E // n_ep) + i) % E
                        for r in range(n_ep) for i in range(per))
    return ExpertPlacement(n_experts=E, n_ep=n_ep,
                           assignments=assignments, cap_frac=0.5)


def hot_placement(n_ep):
    """Experts 1..7 once, expert 0 on every remaining slot (uneven
    round-robin split), full capacity."""
    R = -(-(E + n_ep - 1) // n_ep) * n_ep + n_ep   # > E, multiple of n_ep
    rest = [0] * (R - E + 1)
    slots = sorted(rest + list(range(1, E)))
    return ExpertPlacement(n_experts=E, n_ep=n_ep,
                           assignments=tuple(slots), cap_frac=1.0)


def main(mode: str):
    mesh, dims, n_ep = grids(mode)

    def make_inputs(f):
        cfg0 = MoEConfig(d_model=32, d_ff=64, n_experts=E, top_k=2,
                         capacity_factor=f, schedule="baseline")
        params = init_moe_params(jax.random.PRNGKey(0), cfg0)
        # bias the router toward experts 0/1 through feature 0 (pinned
        # to 1.0 below): expert 0 runs ~4x the mean load
        bias = jnp.zeros((E,)).at[0].set(8.0).at[1].set(4.0)
        params = dict(params, wg=params["wg"] * 0.05
                      + jnp.zeros_like(params["wg"]).at[0, :].set(bias))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16, 32))
        return cfg0, params, x.at[..., 0].set(1.0)

    def run(cfg0, params, x, sched, nc, wire, placement):
        cfg = replace(cfg0, pipeline_chunks=nc,
                      comm=CommConfig(wire_dtype=wire),
                      placement=placement)

        def loss(p, x):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                               schedule=sched)
            return (jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"],
                    (y, aux))

        out = jax.jit(jax.value_and_grad(loss, has_aux=True))(params, x)
        (_, (y, aux)), g = out
        return jax.tree.map(np.asarray, (y, aux, g))

    def check_aux_bitwise(aux, aux_ref, tag):
        for k in ("aux_loss", "z_loss", "drop_frac"):
            assert float(aux[k]) == float(aux_ref[k]), (tag, k)
        np.testing.assert_array_equal(aux["expert_load"],
                                      aux_ref["expert_load"],
                                      err_msg=f"{tag} expert_load")

    cfgA, paramsA, xA = make_inputs(0.5)      # real drops
    cfgB, paramsB, xB = make_inputs(6.0)      # drop-free even when hot
    for sched in ("s1", "s2"):
        for nc in (1, 2):
            for wire in ("f32", "bf16"):
                tag = f"{sched} nc={nc} wire={wire} [{mode}]"
                y0, a0, g0 = run(cfgA, paramsA, xA, sched, nc, wire, None)

                # identity placement: the full machinery, bitwise
                y1, a1, g1 = run(cfgA, paramsA, xA, sched, nc, wire,
                                 identity_placement(E, n_ep))
                np.testing.assert_array_equal(
                    y1, y0, err_msg=f"{tag} identity fwd")
                check_aux_bitwise(a1, a0, f"{tag} identity")
                jax.tree.map(
                    lambda a, b: np.testing.assert_array_equal(
                        a, b, err_msg=f"{tag} identity grad"), g1, g0)

                # x2 replication at half capacity: same effective
                # capacities -> same drop decisions, with real drops
                y2, a2, g2 = run(cfgA, paramsA, xA, sched, nc, wire,
                                 rep2_placement(n_ep))
                assert float(a0["drop_frac"]) > 0.0, tag
                check_aux_bitwise(a2, a0, f"{tag} rep2")
                np.testing.assert_array_equal(
                    (np.abs(y2) == 0.0).all(axis=-1),
                    (np.abs(y0) == 0.0).all(axis=-1),
                    err_msg=f"{tag} rep2 drop mask")
                np.testing.assert_allclose(y2, y0,
                                           err_msg=f"{tag} rep2 fwd",
                                           **FWD_TOL)
                jax.tree.map(
                    lambda a, b: np.testing.assert_allclose(
                        a, b, err_msg=f"{tag} rep2 grad", **GRAD_TOL),
                    g2, g0)

            # hot-expert replication, drop-free, wire f32
            tag = f"{sched} nc={nc} hot [{mode}]"
            y0, a0, g0 = run(cfgB, paramsB, xB, sched, nc, "f32", None)
            y3, a3, g3 = run(cfgB, paramsB, xB, sched, nc, "f32",
                             hot_placement(n_ep))
            assert float(a0["drop_frac"]) == 0.0, tag
            check_aux_bitwise(a3, a0, tag)
            np.testing.assert_allclose(y3, y0, err_msg=f"{tag} fwd",
                                       **FWD_TOL)
            jax.tree.map(
                lambda a, b: np.testing.assert_allclose(
                    a, b, err_msg=f"{tag} grad", **GRAD_TOL), g3, g0)
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
