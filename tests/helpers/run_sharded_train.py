"""Subprocess helper: MoE training on a real 8-device (4x2) mesh — params
actually sharded (EP over data, ESP==MP over model), loss finite and
decreasing, and per-schedule losses equal step-by-step."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import Trainer


def losses_for(schedule, n_steps=8):
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    model = build_model(cfg)
    tr = Trainer(model, mesh, dims,
                 AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40),
                 schedule=schedule)
    params, opt = tr.setup(jax.random.PRNGKey(0))
    # check actual sharding of an expert weight
    w1 = None
    for r, (kind, n) in enumerate(model.runs):
        if kind.startswith("moe"):
            w1 = params[f"run{r}"]["moe"]["w1"]
            break
    assert w1 is not None
    assert len(w1.sharding.device_set) == 8
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    losses = []
    for step in range(n_steps):
        batch = data.sharded_batch(step, mesh, dims.batch_axes)
        params, opt, metrics = tr._step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    return losses


def main():
    lb = losses_for("baseline")
    l1 = losses_for("s1")
    l2 = losses_for("s2")
    assert all(np.isfinite(lb)), lb
    assert lb[-1] < lb[0], lb
    # schedules are numerically equivalent -> same training trajectory
    np.testing.assert_allclose(lb, l1, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(lb, l2, rtol=2e-3, atol=2e-3)
    print("losses:", [round(x, 4) for x in lb])
    print("SHARDED TRAIN OK")


if __name__ == "__main__":
    main()
