"""The eight hand-written Parm schedule bodies, frozen as golden oracles.

These are the PR 1-3 implementations verbatim (baseline/s1/s2/s1_seqpar
and their chunk-pipelined ``*_pipe`` variants), moved out of
``src/repro/core/{schedules,pipeline}.py`` when the declarative plan IR
(``repro.core.plan`` + ``repro.core.executor``) replaced them.  They
exist only so ``tests/test_plan_executor.py`` can assert exact parity —
forward, gradients, routing and drop masks — between every plan-built
schedule and the body it replaced, per (schedule x n_chunks x
wire_dtype).  Do not extend them; new schedules are plan builders.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.gating import combine, dispatch, topk_gate
from repro.core.schedules import MoEShardInfo, _aux_mean, expert_ffn


# --- baseline ----------------------------------------------------------------

def baseline_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    Ne, Ns = info.n_ep, info.n_esp
    E = info.gate.n_experts
    g = coll.mp_all_gather(x, info.esp_axes, Ns, axis=0)       # (S*Ns, M)
    cap_g = info.cap * Ns
    gate = topk_gate(g, wg, info.gate, cap_g)
    eidx, slot, w, aux = gate
    d = dispatch(g, eidx, slot, cap_g, E, info.kernel,
                 flat=gate.flat(cap_g, E))                     # (E, T*Ns, M)
    sb = d.reshape(Ne, E // Ne, cap_g, -1)
    rb = coll.wire_ep_all_to_all(sb, info.ep_axes, info.comm)  # (Ne, El, T*Ns, M)
    xb = coll.to_expert_batch(rb)                              # (El, Ne*T*Ns, M)
    h = expert_ffn(xb, w1, w3, w2, info)
    h = lax.psum(h, info.esp_axes)
    back = coll.wire_ep_all_to_all(coll.from_expert_batch(h, Ne),
                                   info.ep_axes, info.comm)
    out = combine(back.reshape(E, cap_g, -1), eidx, slot, w, cap_g,
                  info.kernel, flat=gate.flat(cap_g, E))
    y = coll.mp_split(out, info.esp_axes, Ns, axis=0)          # (S, M)
    return y, _aux_mean(aux, info)


# --- S1 ----------------------------------------------------------------------

def s1_body(x, wg, w1, w3, w2, info: MoEShardInfo, *, seqpar: bool = False):
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    xs = x if seqpar else coll.mp_split(x, info.mp_axes, Nm, axis=0)
    c1 = info.cap if seqpar else info.cap // Nm
    gate = topk_gate(xs, wg, info.gate, c1)
    eidx, slot, w, aux = gate
    d = dispatch(xs, eidx, slot, c1, E, info.kernel,
                 flat=gate.flat(c1, E))                        # (E, T/Nm, M)
    sb = coll.dump_em(d, Ne, Ns)                               # (El, G, c1, M)
    rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                     info.comm, split_axis=1,
                                     concat_axis=1)
    xb = coll.to_expert_batch_em(rb)                           # (El, G*c1, M)
    h = expert_ffn(xb, w1, w3, w2, info)
    back = coll.wire_ep_esp_all_to_all(
        coll.from_expert_batch_em(h, info.combined_group),
        info.ep_axes, info.esp_axes, info.comm, split_axis=1,
        concat_axis=1)
    mine = coll.undump_reduce_em(back, Ne, Ns)                 # (E, c1, M)
    y = combine(mine, eidx, slot, w, c1, info.kernel,
                flat=gate.flat(c1, E))                         # (S/Nm, M)
    if not seqpar:
        y = coll.wire_mp_all_gather(y, info.mp_axes, Nm, info.comm, axis=0)
    return y, _aux_mean(aux, info)


# --- S2 ----------------------------------------------------------------------

def s2_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    gate = topk_gate(x, wg, info.gate, info.cap)
    eidx, slot, w, aux = gate
    d = dispatch(x, eidx, slot, info.cap, E, info.kernel,
                 flat=gate.flat(info.cap, E))                  # (E, T, M)
    ds = coll.mp_split(d, info.mp_axes, Nm, axis=1)            # (E, T/Nm, M)
    sb = coll.dump_em(ds, Ne, Ns)                              # (El, G, c, M)
    rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                     info.comm, split_axis=1,
                                     concat_axis=1)
    xb = coll.to_expert_batch_em(rb)
    h = expert_ffn(xb, w1, w3, w2, info)
    y4 = coll.from_expert_batch_em(h, info.combined_group)     # (El, G, T/Nm, M)
    full = coll.saa_combine_allgather(
        y4, info.ep_axes, info.esp_axes, info.mp_axes,
        n_ep=Ne, n_esp=Ns, n_mp=Nm, n_chunks=info.saa_chunks,
        comm=info.comm)                                        # (E, T, M)
    y = combine(full, eidx, slot, w, info.cap, info.kernel,
                flat=gate.flat(info.cap, E))                   # (S, M)
    return y, _aux_mean(aux, info)


# --- pipelined variants (PR 2) -----------------------------------------------

def clamp_chunks(cap: int, want: int) -> int:
    n = max(1, min(want, cap))
    while cap % n:
        n -= 1
    return n


def _chunks(buf, n_chunks: int, axis: int = 1):
    c = buf.shape[axis]
    cs = c // n_chunks
    return [lax.slice_in_dim(buf, i * cs, (i + 1) * cs, axis=axis)
            for i in range(n_chunks)]


def baseline_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    Ne, Ns = info.n_ep, info.n_esp
    E = info.gate.n_experts
    g = coll.mp_all_gather(x, info.esp_axes, Ns, axis=0)        # (S*Ns, M)
    cap_g = info.cap * Ns
    gate = topk_gate(g, wg, info.gate, cap_g)
    eidx, slot, w, aux = gate
    d = dispatch(g, eidx, slot, cap_g, E, info.kernel,
                 flat=gate.flat(cap_g, E))                      # (E, T*Ns, M)
    n = clamp_chunks(cap_g, info.pipeline_chunks)
    parts = []
    for ch in _chunks(d, n, axis=1):                            # (E, cs, M)
        cs = ch.shape[1]
        sb = ch.reshape(Ne, E // Ne, cs, -1)
        rb = coll.wire_ep_all_to_all(sb, info.ep_axes, info.comm)
        xb = coll.to_expert_batch(rb)                           # (El, Ne*cs, M)
        h = expert_ffn(xb, w1, w3, w2, info)
        h = lax.psum(h, info.esp_axes)
        back = coll.wire_ep_all_to_all(coll.from_expert_batch(h, Ne),
                                       info.ep_axes, info.comm)
        parts.append(back.reshape(E, cs, -1))
    full = parts[0] if n == 1 else jnp.concatenate(parts, axis=1)
    out = combine(full, eidx, slot, w, cap_g, info.kernel,
                  flat=gate.flat(cap_g, E))
    y = coll.mp_split(out, info.esp_axes, Ns, axis=0)           # (S, M)
    return y, _aux_mean(aux, info)


def s1_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo, *,
                 seqpar: bool = False):
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    xs = x if seqpar else coll.mp_split(x, info.mp_axes, Nm, axis=0)
    c1 = info.cap if seqpar else info.cap // Nm
    gate = topk_gate(xs, wg, info.gate, c1)
    eidx, slot, w, aux = gate
    d = dispatch(xs, eidx, slot, c1, E, info.kernel,
                 flat=gate.flat(c1, E))                         # (E, c1, M)
    n = clamp_chunks(c1, info.pipeline_chunks)
    parts = []
    for ch in _chunks(d, n, axis=1):                            # (E, cs, M)
        sb = coll.dump_em(ch, Ne, Ns)                           # (El, G, cs, M)
        rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                         info.comm, split_axis=1,
                                         concat_axis=1)
        xb = coll.to_expert_batch_em(rb)                        # (El, G*cs, M)
        h = expert_ffn(xb, w1, w3, w2, info)
        back = coll.wire_ep_esp_all_to_all(
            coll.from_expert_batch_em(h, info.combined_group),
            info.ep_axes, info.esp_axes, info.comm, split_axis=1,
            concat_axis=1)
        parts.append(coll.undump_reduce_em(back, Ne, Ns))       # (E, cs, M)
    mine = parts[0] if n == 1 else jnp.concatenate(parts, axis=1)
    y = combine(mine, eidx, slot, w, c1, info.kernel,
                flat=gate.flat(c1, E))                          # (S/Nm, M)
    if not seqpar:
        y = coll.wire_mp_all_gather(y, info.mp_axes, Nm, info.comm,
                                    axis=0)
    return y, _aux_mean(aux, info)


def s2_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    gate = topk_gate(x, wg, info.gate, info.cap)
    eidx, slot, w, aux = gate
    d = dispatch(x, eidx, slot, info.cap, E, info.kernel,
                 flat=gate.flat(info.cap, E))                   # (E, T, M)
    ds = coll.mp_split(d, info.mp_axes, Nm, axis=1)             # (E, T/Nm, M)
    c = ds.shape[1]
    n = clamp_chunks(c, info.pipeline_chunks)
    parts = []
    for ch in _chunks(ds, n, axis=1):                           # (E, cs, M)
        sb = coll.dump_em(ch, Ne, Ns)                           # (El, G, cs, M)
        rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                         info.comm, split_axis=1,
                                         concat_axis=1)
        xb = coll.to_expert_batch_em(rb)
        h = expert_ffn(xb, w1, w3, w2, info)
        y4 = coll.from_expert_batch_em(h, info.combined_group)
        back = coll.wire_ep_esp_all_to_all(y4, info.ep_axes,
                                           info.esp_axes, info.comm,
                                           split_axis=1, concat_axis=1)
        comb = coll.undump_reduce_em(back, Ne, Ns)              # (E, cs, M)
        if Nm == 1:
            parts.append(comb[:, None])                         # (E, 1, cs, M)
        else:
            parts.append(coll.wire_all_gather_stacked(
                comb, tuple(info.mp_axes), Nm, info.comm,
                axis=1))                                        # (E, Nm, cs, M)
    stacked = jnp.stack(parts, axis=2)
    full = stacked.reshape(E, Nm * c, -1)                       # (E, T, M)
    y = combine(full, eidx, slot, w, info.cap, info.kernel,
                flat=gate.flat(info.cap, E))                    # (S, M)
    return y, _aux_mean(aux, info)


LEGACY_BODY = {
    "baseline": baseline_body,
    "s1": s1_body,
    "s2": s2_body,
    "s1_seqpar": lambda *a, **k: s1_body(*a, seqpar=True, **k),
    "baseline_pipe": baseline_pipe_body,
    "s1_pipe": s1_pipe_body,
    "s2_pipe": s2_pipe_body,
    "s1_seqpar_pipe": lambda *a, **k: s1_pipe_body(*a, seqpar=True, **k),
}
