"""Multi-device serving smoke (run with 8 fake CPU devices).

Drives the continuous-batching engine on the MoE arch over a (4, 2)
data x model mesh — decode pools big enough to shard (s_local >= n_mp),
so decode steps run the REAL decode-schedule path (s1d), not the
replicated fallback — and checks:

  * every request completes with its full token budget;
  * the forced-s1d decode output matches forced-s2 (same pool gate ->
    identical routing; s1d's redundant-MP dataflow must reproduce the
    split dataflow numerically);
  * prefill stays one jitted call per admitted request.

Prints SERVE MULTIDEV OK on success.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.moe import MoEConfig, apply_moe, init_moe_params  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.parallel.mesh import ParallelDims, make_mesh  # noqa: E402
from repro.serve import Engine  # noqa: E402


def check_s1d_matches_s2(mesh, dims):
    """Forced decode-dedicated schedule vs S2 on the live 8-dev mesh."""
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    capacity_factor=2.0, schedule="s2")
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32))
    y2, _ = jax.jit(lambda x, p: apply_moe(
        x, p, mesh=mesh, dims=dims, cfg=cfg))(x, params)
    yd, _ = jax.jit(lambda x, p: apply_moe(
        x, p, mesh=mesh, dims=dims, cfg=cfg, schedule="s1d"))(x, params)
    err = float(np.max(np.abs(np.asarray(y2) - np.asarray(yd))))
    assert err < 1e-5, f"s1d vs s2 diverge on the 8-dev mesh: {err}"


def main():
    assert jax.device_count() == 8, jax.device_count()
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    check_s1d_matches_s2(mesh, dims)

    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # max_batch 8 over 4 batch-axis ranks: decode s_local = 2 >= n_mp = 2,
    # so decode MoE runs the sharded schedule path (not dense fallback)
    engine = Engine(model, mesh, dims, max_batch=8, max_len=64)
    rng = np.random.RandomState(0)
    n_req, gen = 10, 6
    for _ in range(n_req):
        engine.submit(rng.randint(0, cfg.vocab_size, rng.randint(4, 12)),
                      gen)
    done = engine.run(params)
    assert len(done) == n_req
    assert all(len(c.tokens) == gen for c in done)
    assert engine.stats["prefill_calls"] == n_req  # one call per admission
    assert engine.stats["max_active"] > 1          # actually batched
    assert engine.pool.n_live == 0                 # every slot evicted
    from repro.core import autosched
    summary = autosched.cache_summary()
    assert "decode" in summary, summary
    print(summary)
    print("SERVE MULTIDEV OK")


if __name__ == "__main__":
    main()
