"""Subprocess helper: telemetry must never change the numbers.

Run as:  python tests/helpers/run_obs_parity.py <mode>
  mode = merged   : mesh (4, 2) data/model, ESP == MP (production mapping)
  mode = distinct : mesh (2, 2, 2) ep/esp/mp

For each mode the same MoE layer (schedule s1, then s2) runs three ways:

  1. obs unconfigured — the plain baseline path,
  2. obs sink configured — every emitter live (trace_tag, debug
     callbacks armed),
  3. after the timed prefix harness (``time_plan_stages`` via
     ``trace_schedule``) compiled and ran on the same mesh + schedule,

and the forward output + aux scalars must be BITWISE identical across
all three — the observability layer is read-only by construction, and
this is the proof.  The merged mode additionally runs a real
``run_schedule_audit`` and checks the joined report, and pushes
saturating fp8 traffic through the wire to assert the ``fp8_sat``
events arrive in the sink with schedule/wire/moe_call trace context.

Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.collectives import CommConfig
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.obs.audit import run_schedule_audit, trace_schedule
from repro.obs.sink import read_events
from repro.obs.trace import chrome_trace_events
from repro.parallel.mesh import ParallelDims, make_mesh


def _forward(mesh, dims, cfg, params, x, sched):
    """Fresh trace every call: the claim is that traces built with obs
    enabled produce identical programs, so never reuse a jit cache
    entry across obs states."""
    def f(p, x):
        return apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                         schedule=sched)
    y, aux = jax.jit(f)(params, x)
    return np.asarray(y), {k: float(v) for k, v in aux.items()
                           if getattr(v, "ndim", 0) == 0}


def _assert_bitwise(tag, ref, got):
    y0, a0 = ref
    y1, a1 = got
    assert y0.dtype == y1.dtype, tag
    np.testing.assert_array_equal(y0, y1, err_msg=tag)
    assert a0 == a1, (tag, a0, a1)


def main(mode: str):
    if mode == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))

    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    capacity_factor=8.0, schedule="s1")
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 16, 32))

    for sched in ("s1", "s2"):
        ref = _forward(mesh, dims, cfg, params, x, sched)

        with tempfile.TemporaryDirectory() as td:
            obs.configure(td, meta={"kind": "parity"})
            try:
                got = _forward(mesh, dims, cfg, params, x, sched)
            finally:
                obs.close()
        _assert_bitwise(f"{sched} sink-on", ref, got)

        st = trace_schedule(mesh, dims, cfg, x.shape[0] * x.shape[1],
                            sched, iters=2, warmup=1)
        assert st.n_stages > 0 and st.total_s >= 0.0
        assert all(t.measured_s >= 0.0 for t in st.stages)
        assert len(chrome_trace_events(st)) > st.n_stages
        got = _forward(mesh, dims, cfg, params, x, sched)
        _assert_bitwise(f"{sched} post-timing", ref, got)

    if mode == "merged":
        # real joined audit on the live mesh: schema + stage coverage
        import json
        [rep] = run_schedule_audit(mesh, dims, cfg,
                                   tokens_global=x.shape[0] * x.shape[1],
                                   schedules=("s1",), iters=2, warmup=1)
        json.dumps(rep)
        assert rep["schedule"] == "s1"
        assert rep["n_stages"] == len(rep["stages"]) > 0
        assert rep["total_measured_s"] > 0.0
        assert rep["worst"], "no priced stage in the audit"

        # fp8 wire saturation events reach the sink with trace context
        # (scaling="none" casts directly, so the 1e3-scaled activations
        # genuinely clip at +-448 — per_chunk absmax never saturates)
        cfg8 = replace(cfg, comm=CommConfig(wire_dtype="fp8_e4m3",
                                            scaling="none"))
        with tempfile.TemporaryDirectory() as td:
            obs.configure(td, meta={"kind": "fp8"})
            try:
                _forward(mesh, dims, cfg8, params, x * 1e3, "s1")
                obs.flush()
                evs = read_events(obs.get_sink().paths)
            finally:
                obs.close()
        sat = [e for e in evs if e["event"] == "fp8_sat"]
        assert sat, f"no fp8_sat events in {[e['event'] for e in evs]}"
        for e in sat:
            assert e["sat"] > 0 and e["total"] > 0
            assert e["schedule"] == "s1"
            assert e["wire"] == "fp8_e4m3"
            assert "moe_call" in e

    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
