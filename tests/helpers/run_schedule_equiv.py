"""Subprocess helper: verify baseline/S1/S2 equivalence on fake devices.

Run as:  python tests/helpers/run_schedule_equiv.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP (production mapping)
  mode = distinct : mesh (ep=2, esp=2, mp=2), N_MP != N_ESP exercised
Prints "OK" on success; asserts otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh


def reference_moe(x, p, cfg: MoEConfig):
    """Single-device oracle: same gate + dense per-expert compute."""
    from repro.core.gating import capacity, combine, dispatch, topk_gate
    B, L, M = x.shape
    xt = x.reshape(B * L, M)
    # must match apply_moe's capacity computation for the sharded pool
    return None  # computed in main via schedule cross-check instead


def main(mode: str):
    if mode == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))

    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                    capacity_factor=8.0, schedule="baseline")
    key = jax.random.PRNGKey(0)
    params = init_moe_params(key, cfg)
    B, L = 16, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, 32))

    outs, auxes = {}, {}
    scheds = ["baseline", "s1", "s2"] + (["s1_seqpar"] if mode == "merged" else [])
    for sched in scheds:
        f = jax.jit(lambda x, p, s=sched: apply_moe(
            x, p, mesh=mesh, dims=dims, cfg=cfg, schedule=s))
        y, aux = f(x, params)
        assert y.shape == x.shape, (sched, y.shape)
        assert not np.isnan(np.asarray(y)).any(), sched
        outs[sched] = np.asarray(y)
        auxes[sched] = {k: float(v) for k, v in aux.items()
                        if getattr(v, "ndim", 0) == 0}
        assert auxes[sched]["drop_frac"] == 0.0, (sched, auxes[sched])

    for sched in scheds[1:]:
        np.testing.assert_allclose(outs[sched], outs["baseline"],
                                   rtol=2e-4, atol=2e-5, err_msg=sched)

    # gradient equivalence
    grads = {}
    for sched in ["baseline", "s1", "s2"]:
        def loss(p, x, s=sched):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg, schedule=s)
            return jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"]
        g = jax.jit(jax.grad(loss))(params, x)
        grads[sched] = jax.tree.map(np.asarray, g)
    for sched in ["s1", "s2"]:
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4),
            grads[sched], grads["baseline"])

    # auto selection runs end-to-end
    y, _ = jax.jit(lambda x, p: apply_moe(
        x, p, mesh=mesh, dims=dims, cfg=cfg, schedule="auto"))(x, params)
    np.testing.assert_allclose(np.asarray(y), outs["baseline"],
                               rtol=2e-4, atol=2e-5)

    # decode fallback: tiny batch
    xd = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 32))
    yd, _ = jax.jit(lambda x, p: apply_moe(
        x, p, mesh=mesh, dims=dims, cfg=cfg, schedule="s1"))(xd, params)
    assert yd.shape == xd.shape and not np.isnan(np.asarray(yd)).any()
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
