"""Subprocess helper: plan-executor parity vs the golden legacy bodies.

Run as:  python tests/helpers/run_plan_parity.py <mode>
  mode = merged   : mesh (ep=4, model=2), MP==ESP — FULL matrix: every
                    schedule x n_chunks in {1,2,4} x wire in {f32,bf16}
  mode = distinct : mesh (ep=2, esp=2, mp=2) — reduced grid
  mode = drops    : merged mesh, capacity_factor < 1 — reduced grid plus
                    bit-identical drop-mask assertions

For every combination, the plan-built schedule (``repro.core.plan`` +
``repro.core.executor``) and the hand-written legacy body it replaced
(``tests/helpers/legacy_bodies.py``, swapped into ``schedules.BODY`` for
the reference trace) run inside ONE jitted function that returns both
paths' forward outputs, aux scalars and parameter gradients:

  * forward outputs within a tight f32 envelope of the legacy body's,
  * gradients within the run_schedule_equiv envelopes,
  * gate-derived aux scalars (aux_loss / z_loss / drop_frac)
    bit-identical — the executor runs the identical gate,
  * in drops mode the zero-row drop masks bit-identical.

``s2h`` (hierarchical, IR-only — no legacy body ever existed) is checked
against the legacy **s2** body: the two-hop AlltoAll decomposition is
pure data movement, so they compute the same function.

Prints "OK <mode>" on success; asserts otherwise.
"""
import os
import sys
from contextlib import contextmanager

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

import legacy_bodies
import repro.core.schedules as S
from repro.core.collectives import CommConfig
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.parallel.mesh import ParallelDims, make_mesh

FWD_TOL = dict(rtol=2e-4, atol=2e-5)    # f32 reassociation headroom only
GRAD_TOL = dict(rtol=5e-3, atol=5e-4)


@contextmanager
def legacy_world():
    """Swap the golden legacy bodies into the live BODY registry (the
    dict is shared with apply_moe, so patching it redirects the trace)."""
    saved = dict(S.BODY)
    S.BODY.update(legacy_bodies.LEGACY_BODY)
    try:
        yield
    finally:
        S.BODY.clear()
        S.BODY.update(saved)


def main(mode: str):
    if mode in ("merged", "drops"):
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        scheds = ["baseline", "s1", "s2", "s1_seqpar", "s2h"]
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
        scheds = ["baseline", "s1", "s2", "s2h"]

    # the full matrix runs once (merged); the other modes keep CI time
    # bounded with a reduced grid over the same code paths
    full = mode == "merged"
    chunk_grid = (1, 2, 4) if full else (1, 2)
    wire_grid = ("f32", "bf16") if mode != "distinct" else ("f32",)

    f = 0.5 if mode == "drops" else 8.0
    cfg0 = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                     capacity_factor=f, schedule="baseline")
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    B = 32 if mode == "drops" else 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, 32))

    def run_pair(sched, nc, wire):
        """One jit: (y, aux, grads) for the plan path AND the golden
        legacy path (s2h's golden reference is the legacy s2 body)."""
        cfg = replace(cfg0, pipeline_chunks=nc,
                      comm=CommConfig(wire_dtype=wire))
        golden = "s2" if sched == "s2h" else sched

        def loss(p, x, s):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                               schedule=s)
            return (jnp.sum(y ** 2) + aux["aux_loss"] + aux["z_loss"],
                    (y, aux))

        def both(p, x):
            (_, (y1, a1)), g1 = jax.value_and_grad(
                loss, has_aux=True)(p, x, sched)
            with legacy_world():
                (_, (y2, a2)), g2 = jax.value_and_grad(
                    loss, has_aux=True)(p, x, golden)
            return y1, a1, g1, y2, a2, g2

        out = jax.jit(both)(params, x)
        return jax.tree.map(np.asarray, out)

    for sched in scheds:
        for nc in chunk_grid:
            for wire in wire_grid:
                tag = f"{sched} nc={nc} wire={wire}"
                y, aux, g, y_ref, aux_ref, g_ref = run_pair(sched, nc,
                                                            wire)
                np.testing.assert_allclose(y, y_ref, err_msg=tag,
                                           **FWD_TOL)
                # the executor runs the identical pre-wire gate: every
                # gate-derived scalar must be bit-identical
                for k in ("aux_loss", "z_loss", "drop_frac"):
                    assert float(aux[k]) == float(aux_ref[k]), \
                        (tag, k, aux, aux_ref)
                if mode == "drops":
                    assert float(aux_ref["drop_frac"]) > 0.0, tag
                    # dropped tokens are exact zeros: identical zero
                    # masks <=> identical drop sets, bit-for-bit
                    np.testing.assert_array_equal(
                        (np.abs(y) == 0.0).all(axis=-1),
                        (np.abs(y_ref) == 0.0).all(axis=-1),
                        err_msg=f"{tag} drop mask")
                jax.tree.map(
                    lambda a, b: np.testing.assert_allclose(
                        a, b, err_msg=f"{tag} grad", **GRAD_TOL),
                    g, g_ref)
    print("OK", mode)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "merged")
