"""Observability tests: the one quantile codepath (registry units), the
JSONL sink round-trip + rotation contract, the obs module facade
(context planes, no-op when unconfigured), the audit report schema, the
timed-executor bitwise-parity matrix (subprocess, 8 fake devices), and
the serve engine's lifecycle event ordering."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_env
from repro import obs
from repro.obs.audit import audit_report
from repro.obs.registry import (Counter, Gauge, Histogram, Registry,
                                quantile)
from repro.obs.sink import JsonlSink, read_events
from repro.obs.trace import StageTime, StageTrace, chrome_trace_events

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def _run(script, *args, n_devices=8, timeout=900):
    env = subprocess_env(n_devices)
    env["PYTHONPATH"] = HELPERS + os.pathsep + env["PYTHONPATH"]
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.fixture(autouse=True)
def obs_reset():
    """No test leaks a configured sink or context into the next."""
    obs.close()
    yield
    obs.close()


class TestQuantile:
    def test_empty_raises(self):
        with pytest.raises(ValueError):
            quantile([], 50)

    def test_single_sample_every_p(self):
        for p in (0, 50, 95, 99, 100):
            assert quantile([7.0], p) == 7.0

    def test_median_matches_legacy_convention(self):
        """p50 == sorted[n // 2]: the exact element the guard-rail spike
        detector and the serve engine's pct() used before unification —
        delegating cannot shift either by a single sample."""
        for n in (1, 2, 3, 8, 9, 31, 32):
            xs = sorted(float(v) for v in np.random.RandomState(n)
                        .randn(n))
            assert quantile(xs, 50) == xs[n // 2]

    def test_upper_percentiles(self):
        xs = [float(i) for i in range(100)]
        assert quantile(xs, 95) == 95.0
        assert quantile(xs, 99) == 99.0
        assert quantile(xs, 100) == 99.0    # clamped to the last element
        assert quantile(xs, 0) == 0.0


class TestHistogram:
    def test_window_trims_oldest(self):
        h = Histogram("h", window=4)
        for v in range(10):
            h.add(float(v))
        assert len(h) == 4
        assert h.sorted_values() == [6.0, 7.0, 8.0, 9.0]

    def test_median_and_mad(self):
        h = Histogram("h")
        for v in (1.0, 9.0, 2.0, 8.0, 5.0):
            h.add(v)
        assert h.median() == 5.0
        devs = sorted(abs(v - 5.0) for v in (1.0, 9.0, 2.0, 8.0, 5.0))
        assert h.mad() == devs[len(devs) // 2]

    def test_summary_schema_and_empty(self):
        h = Histogram("h")
        s = h.summary()
        assert s == {"count": 0, "min": 0.0, "max": 0.0, "mean": 0.0,
                     "p50": 0.0, "p95": 0.0, "p99": 0.0}
        h.add(3.0)
        h.add(1.0)
        s = h.summary()
        assert s["count"] == 2 and s["min"] == 1.0 and s["max"] == 3.0
        assert s["mean"] == 2.0 and s["p50"] == 3.0

    def test_reset(self):
        h = Histogram("h")
        h.add(1.0)
        h.reset()
        assert len(h) == 0 and h.summary()["count"] == 0


class TestRegistry:
    def test_counter_gauge_histogram(self):
        r = Registry()
        r.counter("steps").inc()
        r.counter("steps").inc(2)
        r.gauge("lr").set(0.5)
        r.histogram("lat").add(1.0)
        snap = r.snapshot()
        assert snap["steps"] == 3
        assert snap["lr"] == 0.5
        assert snap["lat.count"] == 1 and snap["lat.p50"] == 1.0

    def test_units_standalone(self):
        c = Counter("c")
        c.inc(5)
        assert c.value == 5
        g = Gauge("g")
        g.set(2.5)
        assert g.value == 2.5


class TestJsonlSink:
    def test_round_trip_with_meta_header(self, tmp_path):
        with JsonlSink(tmp_path, meta={"arch": "x", "mesh": [4, 2]}) as s:
            s.emit("a", v=1)
            s.emit("b", v=2.5, tag="t")
        evs = read_events(s.paths)
        assert [e["event"] for e in evs] == ["meta", "a", "b"]
        assert evs[0]["arch"] == "x" and evs[0]["mesh"] == [4.0, 2.0]
        assert evs[1]["v"] == 1 and evs[2]["tag"] == "t"
        assert [e["seq"] for e in evs] == [0, 1, 2]
        assert all(e["t"] >= 0.0 for e in evs)

    def test_rotation_recarries_header_and_global_seq(self, tmp_path):
        s = JsonlSink(tmp_path, meta={"run": "r"}, rotate_bytes=256,
                      buffer_events=1)
        for i in range(20):
            s.emit("tick", i=i)
        s.close()
        assert len(s.paths) > 1
        for p in s.paths:
            first = json.loads(open(p).readline())
            assert first["event"] == "meta" and first["run"] == "r"
        evs = read_events(s.paths)
        seqs = [e["seq"] for e in evs]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
        ticks = [e["i"] for e in evs if e["event"] == "tick"]
        assert ticks == list(range(20))

    def test_reserved_keys_win_on_collision(self, tmp_path):
        with JsonlSink(tmp_path, meta={"seq": 999, "kind": "k"}) as s:
            s.emit("e", seq=888, t=-1.0, ok=1)
        evs = read_events(s.paths)
        assert evs[0]["seq"] == 0 and evs[0]["kind"] == "k"
        assert evs[1]["event"] == "e" and evs[1]["seq"] == 1
        assert evs[1]["t"] >= 0.0 and evs[1]["ok"] == 1

    def test_numpy_scalars_coerced(self, tmp_path):
        with JsonlSink(tmp_path) as s:
            s.emit("e", a=np.float32(1.5), b=np.int64(3),
                   c=np.array([1, 2]))
        e = read_events(s.paths)[1]
        assert e["a"] == 1.5 and e["b"] == 3 and e["c"] == [1, 2]

    def test_emit_after_close_is_noop(self, tmp_path):
        s = JsonlSink(tmp_path)
        s.close()
        s.emit("late")     # must not raise or write
        assert len(read_events(s.paths)) == 1


class TestObsFacade:
    def test_unconfigured_is_noop(self):
        assert not obs.enabled()
        obs.emit("anything", x=1)    # must not raise
        obs.flush()

    def test_configure_emit_close(self, tmp_path):
        obs.configure(tmp_path, meta={"kind": "t"})
        assert obs.enabled()
        obs.emit("e", v=1)
        paths = obs.get_sink().paths
        obs.close()
        assert not obs.enabled()
        evs = read_events(paths)
        assert [e["event"] for e in evs] == ["meta", "e"]

    def test_runtime_context_merged_and_cleared(self, tmp_path):
        obs.configure(tmp_path)
        obs.set_context(step=3, run="r")
        obs.emit("a")
        obs.set_context(run=None)          # None removes the key
        obs.emit("b", step=9)              # explicit field wins
        paths = obs.get_sink().paths
        obs.close()
        a, b = [e for e in read_events(paths) if e["event"] in "ab"]
        assert a["step"] == 3 and a["run"] == "r"
        assert b["step"] == 9 and "run" not in b

    def test_close_clears_context(self, tmp_path):
        obs.configure(tmp_path)
        obs.set_context(step=1)
        obs.close()
        obs.configure(tmp_path)
        obs.emit("e")
        paths = obs.get_sink().paths
        obs.close()
        assert "step" not in read_events(paths)[-1]

    def test_trace_tag_nests_and_restores(self):
        assert obs.trace_context() == {}
        with obs.trace_tag(moe_call=1, schedule="s1"):
            assert obs.trace_context() == {"moe_call": 1,
                                           "schedule": "s1"}
            with obs.trace_tag(schedule="s2"):
                assert obs.trace_context()["schedule"] == "s2"
                assert obs.trace_context()["moe_call"] == 1
            assert obs.trace_context()["schedule"] == "s1"
        assert obs.trace_context() == {}


class TestAuditReport:
    def _trace(self):
        return StageTrace(
            plan="s1", schedule="s1", total_s=7e-3, overhead_s=1e-4,
            stages=(StageTime("gate", "gate", 1e-4),
                    StageTime("a2a_d", "dispatch_a2a", 3e-3),
                    StageTime("ffn", "expert_ffn", 2e-3),
                    StageTime("a2a_c", "combine_a2a", 1.9e-3)))

    def test_schema_locked(self):
        rep = audit_report(self._trace(),
                           {"a2a_d": 1e-3, "ffn": 2e-3, "a2a_c": 1e-3},
                           total_predicted_s=4e-3)
        json.dumps(rep)     # artifact JSONs embed it verbatim
        assert set(rep) == {"schedule", "plan", "n_stages",
                            "total_predicted_s", "total_measured_s",
                            "overhead_s", "stages", "worst",
                            "calibration"}
        assert rep["n_stages"] == 4 == len(rep["stages"])
        for st in rep["stages"]:
            assert set(st) == {"name", "kind", "predicted_s",
                               "measured_s", "rel_err"}

    def test_rel_err_and_worst_ranking(self):
        rep = audit_report(self._trace(),
                           {"a2a_d": 1e-3, "ffn": 2e-3, "a2a_c": 1e-3},
                           total_predicted_s=4e-3)
        by = {s["name"]: s for s in rep["stages"]}
        assert by["gate"]["rel_err"] is None       # priced at zero
        assert by["ffn"]["rel_err"] == pytest.approx(0.0)
        assert by["a2a_d"]["rel_err"] == pytest.approx(2.0)
        assert by["a2a_c"]["rel_err"] == pytest.approx(0.9)
        assert rep["worst"] == ["a2a_d", "a2a_c", "ffn"]
        assert rep["calibration"]["time_scale"] == pytest.approx(7 / 4)

    def test_zero_predicted_total(self):
        rep = audit_report(self._trace(), {}, total_predicted_s=0.0)
        assert rep["calibration"]["time_scale"] is None
        assert rep["worst"] == []

    def test_chrome_trace_export(self, tmp_path):
        from repro.obs.trace import save_chrome_trace
        path = tmp_path / "trace.json"
        save_chrome_trace(self._trace(), path)
        doc = json.load(open(path))
        evs = doc["traceEvents"]
        slices = [e for e in evs if e["ph"] == "X"]
        assert [s["name"] for s in slices] == ["gate", "a2a_d", "ffn",
                                               "a2a_c"]
        # slices tile the measured timeline back-to-back in order
        assert slices[1]["ts"] == pytest.approx(slices[0]["dur"])
        assert sum(s["dur"] for s in slices) == pytest.approx(7e-3 * 1e6)


class TestTimedExecutorParity:
    """Telemetry on, telemetry off, and after the prefix-timing harness:
    bitwise-identical forward outputs (subprocess, 8 fake devices).
    The merged mode also locks the live audit-report pipeline and the
    fp8 saturation event flow."""

    def test_merged(self):
        assert "OK merged" in _run("run_obs_parity.py", "merged")

    def test_distinct_axes(self):
        assert "OK distinct" in _run("run_obs_parity.py", "distinct")


class TestServeLifecycle:
    def test_event_ordering_and_rollup(self, tmp_path):
        import jax

        from repro.models import build_model
        from repro.parallel.mesh import ParallelDims, make_mesh
        from repro.serve import Engine
        from test_serve import tiny_dense_cfg

        cfg = tiny_dense_cfg()
        model = build_model(cfg)
        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(dp=("data",), mp=("model",))
        params = model.init(jax.random.PRNGKey(0))

        obs.configure(tmp_path, meta={"kind": "serve-test"})
        eng = Engine(model, mesh, dims, max_batch=2, max_len=64)
        rng = np.random.RandomState(0)
        for _ in range(3):
            eng.submit(rng.randint(0, cfg.vocab_size, 5), 4)
        eng.run(params, progress=False)
        rollup = eng.emit_rollup()
        paths = obs.get_sink().paths
        obs.close()

        evs = read_events(paths)
        per_rid = {}
        for e in evs:
            if e["event"].startswith("req_"):
                per_rid.setdefault(e["rid"], []).append(e["event"])
        assert set(per_rid) == {0, 1, 2}
        for rid, seq in per_rid.items():
            assert seq == ["req_queued", "req_admitted",
                           "req_prefilled", "req_finished"], (rid, seq)
        fin = [e for e in evs if e["event"] == "req_finished"]
        assert all(e["tokens"] == 4 and e["latency_s"] >= e["ttft_s"] >= 0
                   for e in fin)
        assert any(e["event"] == "decode_round" for e in evs)

        # the run-end rollup mirrors the registry through ONE quantile
        # codepath: its p50 is exactly quantile() of the event latencies
        lats = sorted(e["latency_s"] for e in fin)
        assert rollup["latency_s.p50"] == quantile(lats, 50)
        assert rollup["latency_s.count"] == 3
        assert "prefix_hit_rate" in rollup
        roll_evs = [e for e in evs if e["event"] == "serve_rollup"]
        assert len(roll_evs) >= 1

    def test_latency_stats_uses_quantile(self):
        """Engine.latency_stats' percentiles delegate to the registry
        quantile — same element, not an interpolated neighbour."""
        from types import SimpleNamespace

        from repro.serve.engine import latency_stats
        done = [SimpleNamespace(status="ok", tokens=[1] * 4,
                                timing={"latency": float(v),
                                        "ttft": float(v) / 2})
                for v in (5.0, 1.0, 3.0, 2.0, 4.0)]
        st = latency_stats(done)
        assert st["p50_ms"] == 3.0 * 1e3
        assert st["p95_ms"] == 5.0 * 1e3
        assert st["ttft_p50_ms"] == 1.5 * 1e3


class TestGuardHistogramDelegation:
    def test_spike_window_median_unchanged(self):
        """The guard spike detector now rides the obs Histogram; its
        median/MAD must be the identical elements the old deque+sorted
        code produced."""
        from repro.runtime.guards import GuardConfig, GuardState

        gs = GuardState(cfg=GuardConfig(spike_min=4, spike_window=8))
        losses = [2.0, 2.1, 1.9, 2.05, 2.0, 1.95]
        for i, v in enumerate(losses):
            assert gs.observe(i, v, False) == "ok"
        window = sorted(losses)
        assert gs._losses.median() == window[len(window) // 2]
        # a 10-sigma excursion over the rolling median still fires
        assert gs.observe(9, 50.0, False) == "rollback"
