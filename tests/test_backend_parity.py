"""Backend parity: ref (jnp oracles) vs pallas (interpret mode on CPU).

Every schedule body must produce the same outputs under both backends —
including dropped-token regimes (capacity_factor < 1) and top_k=2 routing —
and the op-level contracts must agree on adversarial inputs the gate never
produces (duplicate slots, all-dropped tokens).  Grads flow through the
pallas backend via its ref-recompute custom_vjp and must match ref grads.
"""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gating import (GateConfig, capacity, combine, dispatch,
                               flat_slots, topk_gate)
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.core.schedules import BODY
from repro.kernels.registry import (BACKENDS, KernelConfig,
                                    available_backends, get_op, list_ops,
                                    resolve_backend)
from repro.parallel.mesh import ParallelDims, make_mesh

REF = KernelConfig(backend="ref")
PAL = KernelConfig(backend="pallas")

HOT_OPS = ("expert_ffn", "moe_dispatch", "moe_combine", "rmsnorm",
           "flash_attention")


class TestRegistry:
    def test_every_hot_op_has_both_backends(self):
        assert set(HOT_OPS) <= set(list_ops())
        for op in HOT_OPS:
            assert available_backends(op) == BACKENDS, op

    def test_auto_resolves_off_tpu_to_ref(self):
        if jax.default_backend() != "tpu":
            assert resolve_backend(cfg=KernelConfig()) == "ref"

    def test_explicit_arg_wins(self):
        assert resolve_backend("pallas", KernelConfig(backend="ref")) \
            == "pallas"

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            resolve_backend("cuda")
        with pytest.raises(KeyError):
            get_op("no_such_op", backend="ref")


def _moe_setup(cfg, seed=0, B=4, L=8):
    params = init_moe_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, L, cfg.d_model))
    mesh = make_mesh((1, 1), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    return x, params, mesh, dims


class TestScheduleParity:
    @pytest.mark.parametrize("sched", sorted(BODY) + ["auto"])
    def test_outputs_match(self, sched):
        cfg = MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                        capacity_factor=2.0, schedule=sched)
        x, params, mesh, dims = _moe_setup(cfg)
        outs = {}
        for name, k in (("ref", REF), ("pallas", PAL)):
            y, aux = apply_moe(x, params, mesh=mesh, dims=dims,
                               cfg=replace(cfg, kernel=k))
            outs[name] = np.asarray(y)
            assert np.isfinite(outs[name]).all(), (sched, name)
        np.testing.assert_allclose(outs["pallas"], outs["ref"],
                                   atol=1e-5, rtol=1e-5, err_msg=sched)

    @pytest.mark.parametrize("sched", sorted(BODY))
    def test_dropped_tokens_match(self, sched):
        """capacity_factor < 1 forces drops; parity must hold and the two
        backends must agree on which tokens got zeroed."""
        cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                        capacity_factor=0.25, schedule=sched)
        x, params, mesh, dims = _moe_setup(cfg, B=8, L=8)
        ys = {}
        for name, k in (("ref", REF), ("pallas", PAL)):
            y, aux = apply_moe(x, params, mesh=mesh, dims=dims,
                               cfg=replace(cfg, kernel=k))
            ys[name] = np.asarray(y)
            assert float(aux["drop_frac"]) > 0.0, (sched, name)
        np.testing.assert_allclose(ys["pallas"], ys["ref"],
                                   atol=1e-5, rtol=1e-5, err_msg=sched)

    def test_glu_false_schedule_runs_both_backends(self):
        """2-layer (non-GLU) experts: the w3 operand is a zero-size
        placeholder end-to-end, on both backends."""
        cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                        capacity_factor=2.0, glu=False, act="gelu",
                        schedule="s1")
        x, params, mesh, dims = _moe_setup(cfg)
        assert "w3" not in params
        yr, _ = apply_moe(x, params, mesh=mesh, dims=dims,
                          cfg=replace(cfg, kernel=REF))
        yp, _ = apply_moe(x, params, mesh=mesh, dims=dims,
                          cfg=replace(cfg, kernel=PAL))
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   atol=1e-5, rtol=1e-5)

    def test_grads_match_across_backends(self):
        """The pallas ops' ref-recompute custom_vjp must reproduce the ref
        backend's gradients through a full schedule body."""
        cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=2,
                        capacity_factor=2.0, schedule="s2")
        x, params, mesh, dims = _moe_setup(cfg)

        def loss(p, k):
            y, aux = apply_moe(x, p, mesh=mesh, dims=dims,
                               cfg=replace(cfg, kernel=k))
            return jnp.sum(y ** 2) + aux["aux_loss"]

        g_ref = jax.grad(lambda p: loss(p, REF))(params)
        g_pal = jax.grad(lambda p: loss(p, PAL))(params)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5),
            g_ref, g_pal)


class TestModelKernelThreading:
    """The ModelConfig-level backend choice must reach every op call site."""

    def _model_cfg(self, **kw):
        from repro.configs.base import ModelConfig
        from repro.core.moe import MoEConfig
        return ModelConfig(
            name="t", arch_type="moe", n_layers=2, d_model=32, n_heads=2,
            n_kv_heads=2, d_ff=64, vocab_size=64, remat=False,
            moe=MoEConfig(d_model=32, d_ff=64, n_experts=2, top_k=1,
                          capacity_factor=2.0, schedule="s1"), **kw)

    def test_use_pallas_pins_backend(self):
        assert self._model_cfg(use_pallas=True).kernel_cfg.backend == "pallas"
        assert self._model_cfg().kernel_cfg.backend == "auto"

    def test_moe_inherits_model_kernel(self):
        from repro.models.blocks import _moe_cfg
        cfg = self._model_cfg(kernel=PAL)
        assert _moe_cfg(cfg, cfg.kernel_cfg).kernel == PAL
        # an explicit MoE-level kernel wins over the model-level pin
        cfg2 = self._model_cfg(kernel=PAL)
        cfg2 = replace(cfg2, moe=replace(cfg2.moe, kernel=REF))
        assert _moe_cfg(cfg2, cfg2.kernel_cfg).kernel == REF

    def test_full_model_forward_parity(self):
        """One reduced MoE transformer forward, ref vs pallas end to end
        (attention + rmsnorm + dispatch/FFN/combine all through the
        registry)."""
        from repro.models import build_model
        mesh = make_mesh((1, 1), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
        tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)
        outs = {}
        for name, k in (("ref", REF), ("pallas", PAL)):
            cfg = self._model_cfg(kernel=k)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(1))
            logits, _ = model.forward(params, {"tokens": tokens},
                                      mesh=mesh, dims=dims)
            outs[name] = np.asarray(logits)
        np.testing.assert_allclose(outs["pallas"], outs["ref"],
                                   atol=2e-4, rtol=2e-4)


class TestOpLevelParity:
    def _routed(self, S=64, M=32, E=4, k=2, f=4.0, seed=0):
        x = jax.random.normal(jax.random.PRNGKey(seed), (S, M))
        wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, E)) * 0.3
        gcfg = GateConfig(n_experts=E, top_k=k, capacity_factor=f)
        cap = capacity(S, gcfg)
        eidx, slot, w, _ = topk_gate(x, wg, gcfg, cap)
        return x, eidx, slot, w, cap, E

    def test_dispatch_combine_topk2(self):
        """top_k=2 routing (tokens land twice, slots interleave across
        choices): both backends and both entry points agree."""
        x, eidx, slot, w, cap, E = self._routed(k=2)
        br = dispatch(x, eidx, slot, cap, E, REF)
        bp = dispatch(x, eidx, slot, cap, E, PAL)
        np.testing.assert_allclose(np.asarray(bp), np.asarray(br), atol=1e-6)
        yr = combine(br, eidx, slot, w, cap, REF)
        yp = combine(br, eidx, slot, w, cap, PAL)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   atol=1e-5, rtol=1e-5)

    def test_dispatch_duplicate_slot_collision(self):
        """Adversarial duplicate flat slots (never produced by the gate):
        the op contract is scatter-ADD, identical across backends."""
        S, M, n_slots = 8, 16, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (S, M))
        # every token's two choices collide on slots {0, 1}, plus drops
        flat = jnp.array([[0, 1]] * 4 + [[1, 0]] * 2 + [[n_slots, 0]] * 2,
                         jnp.int32)
        br = get_op("moe_dispatch", backend="ref", n_slots=n_slots)(x, flat)
        bp = get_op("moe_dispatch", backend="pallas", n_slots=n_slots)(
            x, flat)
        np.testing.assert_allclose(np.asarray(bp), np.asarray(br), atol=1e-5)

    def test_all_dropped(self):
        S, M, n_slots = 4, 8, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (S, M))
        flat = jnp.full((S, 1), n_slots, jnp.int32)
        for b in BACKENDS:
            buf = get_op("moe_dispatch", backend=b, n_slots=n_slots)(x, flat)
            np.testing.assert_allclose(np.asarray(buf), 0.0, atol=0)
            y = get_op("moe_combine", backend=b)(buf, flat,
                                                 jnp.ones((S, 1)))
            np.testing.assert_allclose(np.asarray(y), 0.0, atol=0)

    def test_flat_slots_drop_sentinel(self):
        eidx = jnp.array([[1, 0]], jnp.int32)
        slot = jnp.array([[2, 9]], jnp.int32)   # second choice dropped
        flat = flat_slots(eidx, slot, cap=4, n_experts=2)
        assert flat.tolist() == [[6, 8]]        # 8 == E*cap == drop

    def test_expert_ffn_block_config_irrelevant_to_values(self):
        """Tile sizes change scheduling, never results."""
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (2, 64, 32))
        w1 = jax.random.normal(ks[1], (2, 32, 64)) * 0.1
        w3 = jax.random.normal(ks[2], (2, 32, 64)) * 0.1
        w2 = jax.random.normal(ks[3], (2, 64, 32)) * 0.1
        base = get_op("expert_ffn", backend="pallas", act="silu")(
            x, w1, w3, w2)
        small = get_op("expert_ffn", backend="pallas",
                       cfg=KernelConfig(backend="pallas", block_t=32,
                                        block_f=32), act="silu")(
            x, w1, w3, w2)
        np.testing.assert_allclose(np.asarray(small), np.asarray(base),
                                   atol=1e-5, rtol=1e-5)
