"""Property-based suite for the paged KV block pool (PR 7).

The ``BlockAllocator`` is plain host-side bookkeeping, which makes it a
perfect target for model-based testing: a pure-Python mirror
(``AllocatorModel``) applies every operation to the real allocator AND to
its own refcount/free-set model, asserting after each step that

  * allocation is deterministic lowest-free-first;
  * no block is ever double-freed (release past zero raises);
  * a block returns to the free heap at EXACTLY the release that takes
    both refcounts (request + cache) to zero — never before, never after;
  * free + live block counts conserve ``n_blocks`` at every step;
  * the reservation ledger never goes negative or exceeds the free set.

The same op table is driven two ways: a hypothesis
``RuleBasedStateMachine`` (when hypothesis is installed) and an
always-running seeded stdlib-``random`` fuzz walk, so the invariants are
exercised on every CI run even without hypothesis.

Pool-level tests cover construction-time validation (``max_len`` must
divide into whole blocks; every cache leaf — including dtype-overridden
ones — must be paged-shaped) and the prefix-sharing lifecycle.
"""

import random

import pytest

from hypothesis_compat import (HAS_HYPOTHESIS, RuleBasedStateMachine,
                               invariant, rule, run_state_machine_as_test,
                               settings, st)

from repro.serve.kvcache import (NULL_BLOCK, BlockAllocator, KVCachePool,
                                 PrefixCache)

N_BLOCKS = 16
BLOCK_SIZE = 4


class AllocatorModel:
    """Real allocator + pure-Python mirror; every op cross-checks both."""

    def __init__(self):
        self.a = BlockAllocator(N_BLOCKS, BLOCK_SIZE)
        self.free = set(range(1, N_BLOCKS + 1))
        self.req = {}                   # bid -> expected req_rc
        self.cache = {}                 # bid -> expected cache_rc
        self.reserved = 0

    # --- ops (each returns True if it could run in the current state) ---
    def op_alloc(self, _):
        if not self.free:
            with pytest.raises(RuntimeError, match="exhausted"):
                self.a.alloc()
            return True
        bid = self.a.alloc()
        assert bid == min(self.free), (
            f"alloc gave {bid}, lowest free is {min(self.free)}")
        assert bid != NULL_BLOCK
        self.free.remove(bid)
        self.req[bid] = 1
        return True

    def _live(self):
        return sorted(set(self.req) | set(self.cache))

    def op_share(self, pick):
        live = self._live()
        if not live:
            return False
        bid = live[pick % len(live)]
        self.a.share(bid)
        self.req[bid] = self.req.get(bid, 0) + 1
        return True

    def op_release(self, pick):
        held = sorted(b for b, rc in self.req.items() if rc > 0)
        if not held:
            return False
        bid = held[pick % len(held)]
        last = (self.req[bid] == 1 and self.cache.get(bid, 0) == 0)
        freed = self.a.release(bid)
        assert freed == last, (
            f"block {bid} freed={freed} but model says last-holder={last}")
        self.req[bid] -= 1
        if self.req[bid] == 0:
            del self.req[bid]
        if last:
            self.free.add(bid)
        return True

    def op_cache_hold(self, pick):
        live = self._live()
        if not live:
            return False
        bid = live[pick % len(live)]
        self.a.cache_hold(bid)
        self.cache[bid] = self.cache.get(bid, 0) + 1
        return True

    def op_cache_drop(self, pick):
        held = sorted(b for b, rc in self.cache.items() if rc > 0)
        if not held:
            return False
        bid = held[pick % len(held)]
        last = (self.cache[bid] == 1 and self.req.get(bid, 0) == 0)
        freed = self.a.cache_drop(bid)
        assert freed == last
        self.cache[bid] -= 1
        if self.cache[bid] == 0:
            del self.cache[bid]
        if last:
            self.free.add(bid)
        return True

    def op_double_free(self, pick):
        """Releasing a block with no request holds must raise, not
        corrupt the free heap."""
        unheld = sorted(self.free | (set(self.cache) - set(self.req)))
        if not unheld:
            return False
        bid = unheld[pick % len(unheld)]
        with pytest.raises(KeyError, match="double free"):
            self.a.release(bid)
        return True

    def op_reserve(self, pick):
        n = pick % 3
        self.a.reserve(n)
        self.reserved += n
        return True

    def op_unreserve(self, pick):
        if self.reserved == 0:
            with pytest.raises(ValueError):
                self.a.unreserve(1)
            return True
        n = pick % self.reserved + 1
        self.a.unreserve(n)
        self.reserved -= n
        return True

    OPS = (op_alloc, op_share, op_release, op_cache_hold, op_cache_drop,
           op_double_free, op_reserve, op_unreserve)

    # --- cross-check ---------------------------------------------------
    def audit(self):
        self.a.check()
        assert set(self.a._free) == self.free
        assert self.a.n_free + self.a.n_live == N_BLOCKS
        assert self.a.reserved == self.reserved
        for bid in range(1, N_BLOCKS + 1):
            assert self.a.req_rc(bid) == self.req.get(bid, 0)
            assert self.a.cache_rc(bid) == self.cache.get(bid, 0)


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.m = AllocatorModel()

    @rule(op=st.integers(min_value=0, max_value=7),
          pick=st.integers(min_value=0, max_value=10**6))
    def step(self, op, pick):
        AllocatorModel.OPS[op](self.m, pick)

    @invariant()
    def conserved(self):
        self.m.audit()


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_allocator_state_machine():
    run_state_machine_as_test(
        AllocatorMachine,
        settings=settings(max_examples=30, stateful_step_count=40,
                          deadline=None))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_allocator_fuzz_walk(seed):
    """Seeded stdlib-random walk over the same op table — runs on every
    CI box, hypothesis installed or not."""
    rng = random.Random(seed)
    m = AllocatorModel()
    for _ in range(400):
        op = rng.choice(AllocatorModel.OPS)
        op(m, rng.randrange(10**6))
        m.audit()
    # drain to empty: releasing every hold must hand back every block
    while m.req or m.cache:
        if m.req:
            m.op_release(rng.randrange(10**6))
        else:
            m.op_cache_drop(rng.randrange(10**6))
        m.audit()
    assert m.a.n_free == N_BLOCKS


class TestAllocatorUnits:
    def test_alloc_order_is_deterministic(self):
        a = BlockAllocator(8, 4)
        assert [a.alloc() for _ in range(8)] == list(range(1, 9))
        a.release(3)
        a.release(7)
        a.release(5)
        assert [a.alloc() for _ in range(3)] == [3, 5, 7]

    def test_null_block_never_handed_out(self):
        a = BlockAllocator(4, 4)
        got = {a.alloc() for _ in range(4)}
        assert NULL_BLOCK not in got

    def test_refcount_zero_exactly_at_last_release(self):
        a = BlockAllocator(4, 4)
        bid = a.alloc()
        a.share(bid)
        a.cache_hold(bid)
        assert a.release(bid) is False          # one req hold left
        assert a.release(bid) is False          # cache hold left
        assert a.cache_drop(bid) is True        # last hold -> freed
        assert a.n_free == 4
        assert a.freed_log == [bid]

    def test_double_free_raises(self):
        a = BlockAllocator(4, 4)
        bid = a.alloc()
        a.release(bid)
        with pytest.raises(KeyError, match="double free"):
            a.release(bid)
        with pytest.raises(KeyError, match="not live"):
            a.share(bid)

    def test_reservation_ledger(self):
        a = BlockAllocator(4, 4)
        a.reserve(3)
        assert a.available == 1
        with pytest.raises(ValueError):
            a.unreserve(4)
        a.unreserve(3)
        assert a.available == 4


class TestPrefixCacheUnits:
    def _cached(self):
        a = BlockAllocator(8, 2)
        pc = PrefixCache(a)
        blocks = [a.alloc(), a.alloc()]
        prompt = (1, 2, 3, 4, 5)        # 2 full blocks + 1 tail token
        assert pc.insert(prompt, blocks) == 2
        return a, pc, blocks, prompt

    def test_lookup_longest_prefix_and_counters(self):
        a, pc, blocks, prompt = self._cached()
        assert pc.lookup((1, 2, 3, 4, 9, 9), 4) == tuple(blocks)
        assert pc.lookup((1, 2, 9), 4) == (blocks[0],)
        assert pc.lookup((9, 9, 9, 9), 4) == ()
        assert (pc.hits, pc.misses) == (2, 1)

    def test_eviction_refused_while_held(self):
        a, pc, blocks, prompt = self._cached()
        key = prompt[:4]
        with pytest.raises(RuntimeError, match="refused"):
            pc.evict(key)               # computing request still holds
        for b in blocks:
            a.release(b)
        # the 1-block entry (1, 2) still cache-holds blocks[0], so only
        # the deep block comes back here
        assert pc.evict(key) == 1
        assert pc.evict(prompt[:2]) == 1
        assert a.n_free == 8

    def test_evict_lru_skips_held_entries(self):
        a, pc, blocks, prompt = self._cached()
        assert pc.evict_lru(4) == 0     # every entry still held
        for b in blocks:
            a.release(b)
        assert pc.evict_lru(1) >= 1
        assert len(pc) < 2


def tiny_dense_model():
    from repro.configs import ModelConfig
    from repro.models import build_model
    return build_model(ModelConfig(
        name="kvpool-test", arch_type="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, rope_theta=1e4,
        remat=False))


@pytest.fixture(scope="module")
def model():
    return tiny_dense_model()


class TestPoolConstruction:
    def test_max_len_must_divide_into_blocks(self, model):
        with pytest.raises(ValueError, match="not divisible"):
            KVCachePool(model, 2, 24, block_size=16)

    def test_block_size_capped_at_max_len(self, model):
        pool = KVCachePool(model, 2, 16, block_size=64)
        assert pool.block_size == 16 and pool.max_blocks == 1

    def test_leaves_are_paged_shaped(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8)
        import jax
        for leaf in jax.tree.leaves(pool.cache):
            assert leaf.shape[1] == pool.n_blocks + 1
            if leaf.ndim >= 3:
                assert leaf.shape[2] == 8

    def test_dtype_override_is_validated_too(self, model):
        """The old slab pool only shape-checked the default-dtype path;
        the paged pool validates every construction."""
        import jax
        import jax.numpy as jnp
        pool = KVCachePool(model, 2, 32, jnp.bfloat16, block_size=8)
        ks = [leaf for leaf in jax.tree.leaves(pool.cache)
              if leaf.dtype == jnp.bfloat16]
        assert ks, "dtype override ignored"

        class BadModel:
            def init_cache(self, n, w, dtype=None):
                import jax.numpy as jnp
                return {"l0": {"attn": {
                    "k": jnp.zeros((2, n - 1, w, 2, 4), jnp.float32)}}}

        with pytest.raises(ValueError, match="n_blocks"):
            KVCachePool(BadModel(), 2, 32, block_size=8)

    def test_default_arena_is_slab_equivalent(self, model):
        pool = KVCachePool(model, 4, 32, block_size=8)
        assert pool.n_blocks == 4 * (32 // 8)


class TestPoolLifecycle:
    def test_rows_and_blocks_conserve(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8, prefix_cache=False)
        row, shared = pool.alloc("a", (1, 2, 3), max_new=8)
        assert (row, shared) == (0, 0)
        pool.ensure("a", 2)
        assert len(pool.table_of("a")) == 1
        pool.ensure("a", 9)              # crosses into a second block
        assert len(pool.table_of("a")) == 2
        assert pool.n_free_blocks == pool.n_blocks - 2
        pool.release("a")
        assert pool.n_free_blocks == pool.n_blocks
        assert pool.n_live == 0 and pool.n_free == 2
        assert sorted(pool.drain_freed()) == [1, 2]

    def test_admission_is_block_budget_not_rows(self, model):
        # 4 blocks of 8 = 32 tokens of arena for 2 rows: a second long
        # request must be refused even though a row is free
        pool = KVCachePool(model, 2, 32, block_size=8, n_blocks=4,
                           prefix_cache=False)
        assert pool.can_admit(17, 8)
        pool.alloc("big", tuple(range(17)), max_new=8)   # needs 4 blocks
        assert pool.n_free == 1                          # row IS free
        assert not pool.can_admit(9, 8)                  # blocks are not
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.alloc("second", tuple(range(9)), max_new=8)

    def test_reservation_guarantees_growth(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8, n_blocks=4,
                           prefix_cache=False)
        pool.alloc("a", tuple(range(9)), max_new=7)      # reserves 2
        pool.alloc("b", tuple(range(9)), max_new=7)      # reserves 2
        for pos in range(16):
            pool.ensure("a", pos)
            pool.ensure("b", pos)
        assert pool.n_free_blocks == 0                   # fully drawn down
        pool.release("a")
        pool.release("b")

    def test_prefix_shared_blocks_counted_once(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8)
        prompt = tuple(range(1, 18))                     # 17 tokens
        row_a, shared_a = pool.alloc("a", prompt, max_new=4)
        assert shared_a == 0
        pool.ensure("a", 16)
        pool.commit_prefix("a", prompt)
        row_b, shared_b = pool.alloc("b", prompt, max_new=4)
        assert shared_b == 16                            # 2 full blocks
        assert pool.table_of("b")[:2] == pool.table_of("a")[:2]
        for bid in pool.table_of("b")[:2]:
            assert pool.alloc_blocks.req_rc(bid) == 2
        pool.release("a")
        # cache still holds the prefix blocks: b reads valid K/V
        for bid in pool.table_of("b")[:2]:
            assert pool.alloc_blocks.req_rc(bid) == 1
            assert pool.alloc_blocks.cache_rc(bid) > 0
        pool.release("b")
        assert pool.alloc_blocks.n_live == 2             # cache-only now
        pool.prefix.evict_lru(2)
        assert pool.alloc_blocks.n_live == 0

    def test_prefix_eviction_refused_while_held(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8)
        prompt = tuple(range(1, 18))
        pool.alloc("a", prompt, max_new=4)
        pool.ensure("a", 16)
        pool.commit_prefix("a", prompt)
        key = prompt[:8]
        with pytest.raises(RuntimeError, match="refused"):
            pool.prefix.evict(key)
        assert pool.prefix.holders(key) == 1
        pool.release("a")
        pool.prefix.evict(key)

    def test_lru_eviction_under_pressure(self, model):
        # arena of only 2 blocks; a dead request's cached prefix must be
        # evicted to admit the next request
        pool = KVCachePool(model, 2, 16, block_size=8, n_blocks=2)
        p1 = tuple(range(1, 10))
        pool.alloc("a", p1, max_new=4)
        pool.ensure("a", 8)
        pool.commit_prefix("a", p1)
        pool.release("a")
        assert pool.alloc_blocks.n_live == 1             # cached block
        assert pool.n_free_blocks == 1
        assert pool.can_admit(9, 7)                      # via eviction
        p2 = tuple(range(20, 29))
        pool.alloc("b", p2, max_new=7)                   # needs 2 blocks
        for pos in range(16):
            pool.ensure("b", pos)
        assert len(pool.prefix) == 0                     # p1 evicted
        pool.release("b")

    def test_shared_prefix_never_includes_final_token_block(self, model):
        """At least one prompt token must remain to prefill — a 16-token
        prompt with 2 cached blocks shares only the first."""
        pool = KVCachePool(model, 2, 32, block_size=8)
        prompt = tuple(range(1, 17))                     # exactly 2 blocks
        pool.alloc("a", prompt, max_new=4)
        pool.ensure("a", 15)
        pool.commit_prefix("a", prompt)
        _, shared = pool.alloc("b", prompt, max_new=4)
        assert shared == 8                               # 1 block, not 2
        pool.release("a")
        pool.release("b")

    def test_block_tables_view(self, model):
        pool = KVCachePool(model, 2, 32, block_size=8, prefix_cache=False)
        pool.alloc("a", (1, 2, 3), max_new=0)
        pool.ensure("a", 2)
        t = pool.block_tables()
        assert t.shape == (2, 4)
        assert t[0, 0] == pool.table_of("a")[0]
        assert (t[1] == NULL_BLOCK).all()


class TestPressureAdmission:
    """Regression suite for the alloc-vs-eviction races: a matched
    prefix must be pinned before pressure eviction runs, refused
    admissions must leave the cache untouched, and the evictable count
    must agree with what evict_lru can actually free."""

    def _warm(self, model, *, n_blocks=3, max_batch=2):
        """Pool with prompt p cached as entries (b1,), (b1, b2) and no
        live holders; returns (pool, p)."""
        pool = KVCachePool(model, max_batch, 24, block_size=8,
                           n_blocks=n_blocks)
        p = tuple(range(1, 18))                          # 17 tokens
        pool.alloc("a", p, max_new=7)
        pool.ensure("a", 16)
        pool.commit_prefix("a", p)
        pool.release("a")
        return pool, p

    def test_refused_alloc_does_not_evict_matched_prefix(self, model):
        """The old code looked up the prefix hit WITHOUT holds, let
        evict_lru free the matched blocks, then crashed in share() with
        KeyError.  Now the infeasible request is refused up front and
        the cache survives intact."""
        pool, p = self._warm(model)
        pool.alloc("c", (99,), max_new=0)        # reserves the last block
        assert pool.alloc_blocks.available == 0
        assert not pool.can_admit(17, 7)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.alloc("b", p, max_new=7)
        # no KeyError, request not half-admitted, cache untouched
        assert "b" not in pool.live()
        assert len(pool.prefix) == 2
        assert pool.alloc_blocks.cache_rc(1) == 2
        assert pool.alloc_blocks.cache_rc(2) == 1
        assert pool.alloc_blocks.req_rc(1) == 0
        pool.alloc_blocks.check()
        # freeing the blocker makes the same request admissible, with
        # the (preserved) prefix hit
        pool.release("c")
        assert pool.can_admit(17, 7)
        _, shared = pool.alloc("b", p, max_new=7)
        assert shared == 16

    def test_pressure_eviction_spares_matched_prefix(self, model):
        """Under block pressure, evict_lru takes the holder-free decoy
        entry, never the prefix the incoming request just matched."""
        pool = KVCachePool(model, 3, 24, block_size=8, n_blocks=4)
        q = tuple(range(50, 59))                         # decoy, 9 tokens
        pool.alloc("q", q, max_new=0)
        pool.ensure("q", 8)
        pool.commit_prefix("q", q)                       # entry (1,)
        pool.release("q")
        p = tuple(range(1, 18))
        pool.alloc("a", p, max_new=0)
        pool.ensure("a", 16)
        pool.commit_prefix("a", p)                       # (2,), (2, 3)
        pool.release("a")
        pool.alloc("c", (99,), max_new=0)                # available -> 0
        assert pool.can_admit(17, 7)
        _, shared = pool.alloc("b", p, max_new=7)
        assert shared == 16                              # hit preserved
        assert pool.table_of("b") == [2, 3]
        assert q[:8] not in pool.prefix.keys()           # decoy evicted
        assert len(pool.prefix) == 2
        for pos in range(16, 24):
            pool.ensure("b", pos)
        pool.alloc_blocks.check()
        pool.release("b")
        pool.release("c")

    def test_fallback_gives_up_hit_when_chain_pins_all_headroom(self,
                                                                model):
        """When the matched entry's own chain is the only evictable
        headroom, pinning it would deadlock admission — alloc must fall
        back to a share-free allocation (the old code crashed with
        KeyError here: evict_lru freed the matched blocks mid-alloc)."""
        pool, p = self._warm(model)
        pool.alloc("c", (99,), max_new=0)
        assert pool.alloc_blocks.available == 0
        assert pool.can_admit(9, 7)                      # needs 2 blocks
        row, shared = pool.alloc("b", p[:9], max_new=7)
        assert shared == 0                               # hit abandoned
        assert len(pool.prefix) == 0                     # chain evicted
        assert (pool.prefix.hits, pool.prefix.misses) == (0, 2)
        for pos in range(16):
            pool.ensure("b", pos)
        pool.alloc_blocks.check()
        pool.release("b")
        pool.release("c")
        assert pool.n_free_blocks == pool.n_blocks

    def test_evictable_blocks_excludes_pinned_sibling_entries(self,
                                                              model):
        """A block counts as evictable only if NO covering entry has a
        live-held block — evict_lru refuses whole entries, so counting
        per-block refcounts alone overstates admission headroom."""
        pool, p = self._warm(model, n_blocks=8)
        # share only the 1-block prefix: pins (b1,) directly and
        # (b1, b2) through b1, so b2 is unfreeable despite req_rc == 0
        pool.alloc("x", p[:8] + (99,), max_new=0)
        assert pool.alloc_blocks.req_rc(1) == 1
        assert pool.alloc_blocks.req_rc(2) == 0
        assert pool.prefix.evictable_blocks == 0
        assert pool.prefix.evict_lru(4) == 0             # consistent
        pool.release("x")
        assert pool.prefix.evictable_blocks == 2
