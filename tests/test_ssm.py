"""Recurrent-cell correctness: chunked/parallel training forms must match
step-by-step decode recurrences exactly (the property that makes long_500k
serving trustworthy)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


class TestMamba:
    def _cfg(self):
        return ssm.MambaConfig(d_model=32, d_inner=64, d_state=8, d_conv=4,
                               chunk=16)

    def test_train_matches_stepwise_decode(self):
        cfg = self._cfg()
        p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32))
        y_train = ssm.apply_mamba(p, cfg, x)
        state = ssm.init_mamba_state(cfg, 2)
        ys = []
        for t in range(48):
            y, state = ssm.apply_mamba(p, cfg, x[:, t:t + 1], state=state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_train),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("chunk", [4, 16, 64])
    def test_chunk_size_invariance(self, chunk):
        cfg = ssm.MambaConfig(d_model=16, d_inner=32, d_state=4,
                              chunk=chunk)
        p = ssm.init_mamba(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
        ref_cfg = ssm.MambaConfig(d_model=16, d_inner=32, d_state=4,
                                  chunk=64)
        np.testing.assert_allclose(
            np.asarray(ssm.apply_mamba(p, cfg, x)),
            np.asarray(ssm.apply_mamba(p, ref_cfg, x)),
            rtol=2e-5, atol=2e-6)


class TestMLSTM:
    def _cfg(self, chunk=16):
        return ssm.MLSTMConfig(d_model=32, n_heads=2, chunk=chunk)

    def test_train_matches_stepwise_decode(self):
        cfg = self._cfg()
        p = ssm.init_mlstm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
        y_train = ssm.apply_mlstm(p, cfg, x)
        state = ssm.init_mlstm_state(cfg, 2)
        ys = []
        for t in range(32):
            y, state = ssm.apply_mlstm(p, cfg, x[:, t:t + 1], state=state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_train),
                                   rtol=3e-4, atol=3e-5)

    def test_chunk_invariance(self):
        p = ssm.init_mlstm(jax.random.PRNGKey(0), self._cfg())
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32))
        y8 = ssm.apply_mlstm(p, self._cfg(8), x)
        y32 = ssm.apply_mlstm(p, self._cfg(32), x)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                                   rtol=3e-4, atol=3e-5)

    def test_stability_long_sequence(self):
        """exponential gating must stay finite over long contexts."""
        cfg = self._cfg()
        p = ssm.init_mlstm(jax.random.PRNGKey(0), cfg)
        x = 5.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 512, 32))
        y = ssm.apply_mlstm(p, cfg, x)
        assert np.isfinite(np.asarray(y)).all()


class TestSLSTM:
    def test_train_matches_stepwise_decode(self):
        cfg = ssm.SLSTMConfig(d_model=32, n_heads=4)
        p = ssm.init_slstm(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
        y_train = ssm.apply_slstm(p, cfg, x)
        state = ssm.init_slstm_state(cfg, 2)
        ys = []
        for t in range(24):
            y, state = ssm.apply_slstm(p, cfg, x[:, t:t + 1], state=state)
            ys.append(np.asarray(y[:, 0]))
        np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_train),
                                   rtol=2e-5, atol=2e-6)

    def test_stability(self):
        cfg = ssm.SLSTMConfig(d_model=16, n_heads=2)
        p = ssm.init_slstm(jax.random.PRNGKey(0), cfg)
        x = 10.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 256, 16))
        assert np.isfinite(np.asarray(ssm.apply_slstm(p, cfg, x))).all()
