"""Chunk-pipelined schedule tests: parity with the unchunked bodies
(subprocess, 8 fake devices), chunk clamping, and the end-to-end
``schedule="auto"`` one-step train through launch/dryrun.py."""

import os
import subprocess
import sys

from conftest import subprocess_env

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script, *args, n_devices=8, timeout=900):
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        env=subprocess_env(n_devices), capture_output=True, text=True,
        timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestPipelineParity:
    def test_merged_production_mapping(self):
        """pipelined == unchunked (outputs + grads), n_chunks in {1,2,4},
        for baseline/s1/s2/s1_seqpar on the MP==ESP mesh."""
        out = _run("run_pipeline_equiv.py", "merged")
        assert "OK merged" in out

    def test_distinct_axes(self):
        out = _run("run_pipeline_equiv.py", "distinct")
        assert "OK distinct" in out

    def test_dropped_tokens(self):
        """capacity_factor < 1: drop patterns and outputs identical at
        every chunk count (chunking happens after the gate)."""
        out = _run("run_pipeline_equiv.py", "drops")
        assert "OK drops" in out


class TestChunkClamping:
    def test_clamp_to_divisor(self):
        from repro.core.pipeline import clamp_chunks
        assert clamp_chunks(16, 4) == 4
        assert clamp_chunks(16, 5) == 4     # largest divisor <= 5
        assert clamp_chunks(16, 100) == 16  # never exceeds the dim
        assert clamp_chunks(7, 2) == 1      # prime capacity -> unchunked
        assert clamp_chunks(12, 0) == 1

    def test_pipeline_registry(self):
        from repro.core.pipeline import PIPELINE_OF, UNCHUNKED_OF
        from repro.core.schedules import BODY
        for base, pipe in PIPELINE_OF.items():
            assert base in BODY and pipe in BODY
            assert UNCHUNKED_OF[pipe] == base


class TestAutoTrainsEndToEnd:
    def test_dryrun_auto_one_step(self):
        """schedule="auto" decides, compiles, and executes one optimizer
        step through launch/dryrun.py --run-step."""
        env = subprocess_env(8)
        env["REPRO_DRYRUN_DEVICES"] = "8"
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "gpt2-moe", "--shape", "train_4k", "--seq", "64", "--batch",
             "8", "--reduced", "--run-step"],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.dirname(SRC))
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "[step]" in r.stdout and "loss=" in r.stdout
        assert "dry-run complete" in r.stdout
