"""Benchmark runner: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Analytic benches run
in-process; measured multi-device benches run in subprocesses with 8 fake
CPU devices (the main process must keep seeing 1 device).
"""

from __future__ import annotations

import os
import subprocess
import sys

IN_PROCESS = [
    "benchmarks.bench_fig1_comm_ratio",
    "benchmarks.bench_table4_speedups",
    "benchmarks.bench_fig7_stats",
    "benchmarks.bench_roofline",
    "benchmarks.bench_kernels",
]
SUBPROCESS = [
    "benchmarks.bench_fig6_perfmodel",
    "benchmarks.bench_table4_measured",
    "benchmarks.bench_table5_realworld",
]


def main() -> None:
    from importlib import import_module
    print("name,us_per_call,derived")
    for mod in IN_PROCESS:
        import_module(mod).main()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    for mod in SUBPROCESS:
        r = subprocess.run([sys.executable, "-m", mod], env=env, cwd=root,
                           capture_output=True, text=True, timeout=3600)
        if r.returncode != 0:
            print(f"{mod},0,FAILED: {r.stderr[-300:]!r}")
            raise SystemExit(1)
        for line in r.stdout.splitlines():
            if "," in line:
                print(line)


if __name__ == '__main__':
    main()
