"""Benchmark runner: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Analytic benches run
in-process; measured multi-device benches run in subprocesses with 8 fake
CPU devices (the main process must keep seeing 1 device).

Every row is also collected into the canonical ``BENCH_pr10.json`` at the
repo root — the machine-readable perf trajectory successive PRs diff
against (schema: ``{"rows": [{"name", "us_per_call", "derived"}, ...]}``).
"""

from __future__ import annotations

import contextlib
import io
import json
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):   # python benchmarks/run.py
    if _p not in sys.path:
        sys.path.insert(0, _p)

IN_PROCESS = [
    "benchmarks.bench_fig1_comm_ratio",
    "benchmarks.bench_table4_speedups",
    "benchmarks.bench_fig7_stats",
    "benchmarks.bench_roofline",
    "benchmarks.bench_kernels",
]
SUBPROCESS = [
    "benchmarks.bench_fig6_perfmodel",
    "benchmarks.bench_table4_measured",
    "benchmarks.bench_table5_realworld",
    "benchmarks.bench_comm_precision",
    "benchmarks.bench_plan_overhead",
    "benchmarks.bench_serve",
    "benchmarks.bench_guards",
    "benchmarks.bench_loadbalance",
    "benchmarks.bench_obs_overhead",
]

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_pr10.json")


def _collect(rows: list, line: str) -> None:
    """Parse one ``name,us_per_call,derived`` CSV row into ``rows``."""
    parts = line.split(",", 2)
    if len(parts) != 3 or parts[0] in ("", "name"):
        return
    try:
        us = float(parts[1])
    except ValueError:
        return
    rows.append({"name": parts[0], "us_per_call": us,
                 "derived": parts[2]})


def main() -> None:
    from importlib import import_module
    rows: list = []
    print("name,us_per_call,derived")
    for mod in IN_PROCESS:
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            import_module(mod).main()
        for line in buf.getvalue().splitlines():
            print(line)
            _collect(rows, line)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    for mod in SUBPROCESS:
        r = subprocess.run([sys.executable, "-m", mod], env=env, cwd=root,
                           capture_output=True, text=True, timeout=3600)
        if r.returncode != 0:
            print(f"{mod},0,FAILED: {r.stderr[-300:]!r}")
            raise SystemExit(1)
        for line in r.stdout.splitlines():
            if "," in line:
                print(line)
                _collect(rows, line)
    with open(BENCH_JSON, "w") as f:
        json.dump({"rows": rows}, f, indent=1)
    print(f"# wrote {len(rows)} rows to {os.path.basename(BENCH_JSON)}")


if __name__ == '__main__':
    main()
