"""Fig. 7 reproduction: distribution of Parm speedups over the baseline on
the Table III grid at N_MP = N_ESP = 4 (the paper's 32-GPU statistic:
4.91x average, >4x in ~89% of cases)."""

from __future__ import annotations

from benchmarks.common import emit, table3_grid
from repro.core.perfmodel import MoELayerShape, speedup_table, tpu_v5e_model


def main():
    speedups = []
    for c in table3_grid():
        if not (c["n_mp"] == 4 and c["n_esp"] == 4 and c["P"] == 32):
            continue
        m = tpu_v5e_model(c["n_ep"], c["n_esp"], c["n_mp"])
        s = MoELayerShape(B=c["B"], L=c["L"], M=c["M"], H=c["H"],
                          E=c["E"], k=c["k"], f=c["f"], n_mp=4, n_esp=4,
                          n_ep=c["n_ep"])
        speedups.append(speedup_table(s, m)["speedup_parm"])

    speedups.sort()
    n = len(speedups)
    avg = sum(speedups) / n
    emit("fig7/configs", 0.0, f"n={n}")
    emit("fig7/avg_speedup", 0.0, f"{avg:.2f}x (paper: 4.91x)")
    emit("fig7/p10", 0.0, f"{speedups[n // 10]:.2f}x")
    emit("fig7/p90", 0.0, f"{speedups[9 * n // 10]:.2f}x")
    frac4 = sum(s > 4 for s in speedups) / n
    emit("fig7/frac_gt_4x", 0.0, f"{frac4:.2f} (paper: ~0.89)")
    assert avg > 1.5


if __name__ == "__main__":
    main()
