"""Guard-rail overhead benchmark: guards-on vs guards-off train step.

The fault-tolerant loop (PR 8) wraps every train step in a non-finite
where-select + LR-scale multiply; the acceptance bar is < 2% overhead on
the bench smoke.  Both variants run the SAME reduced MoE arch and batch
through jitted steps and report microseconds per step (median); the
derived column of the ``guards_overhead`` row is the measured ratio.

Measurement matches the production loop: ``donate_argnums=(0, 1)`` with
outputs fed back as the next step's inputs (exactly how the Trainer
drives the step — donation lets XLA fold the guard's where-select into
the in-place update), and a training-shaped batch (8x256 tokens) so the
fwd+bwd compute fraction is representative.  Without donation the
select materializes a second copy of params+moments and the "overhead"
triples — that regime never occurs in the real loop.

A third row times the guarded step with the fp8 saturation monitor
installed on an fp8-wire config — the full production guard stack, so a
regression in the debug-callback path shows up here and not in a prod
incident.

Run under 8 fake CPU devices (benchmarks/run.py does this):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_guards [--smoke]
"""

from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.collectives import CommConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train.loop import make_guarded_train_step, make_train_step

ARCH = "qwen3-moe-30b-a3b"


def _setup(wire="f32"):
    cfg = get_config(ARCH).reduced()
    if wire != "f32":
        cfg = replace(cfg, moe=replace(
            cfg.moe, comm=CommConfig(wire_dtype=wire)))
    model = build_model(cfg)
    n = jax.device_count()
    d = max(1, n // 2) if n > 1 else 1
    mesh = make_mesh((d, max(n // d, 1)), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 256)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 256)),
                              jnp.int32)}
    return model, mesh, dims, params, opt, batch


def _step_loop(fn, params, opt, batch, extra=()):
    """One timed-call closure: donated ping-pong, exactly as the Trainer
    drives the step — outputs feed back as the next inputs so XLA
    updates params/moments in place."""
    jitted = jax.jit(fn, donate_argnums=(0, 1))
    st = {"p": jax.tree.map(jnp.copy, params),
          "o": jax.tree.map(jnp.copy, opt)}

    def once():
        t0 = time.perf_counter()
        st["p"], st["o"], m = jitted(st["p"], st["o"], batch, *extra)
        jax.block_until_ready(m["loss"])
        return time.perf_counter() - t0

    return once


def _median(ts):
    ts = sorted(ts)
    return ts[len(ts) // 2]


def _time_pair(a, b, iters=9, warmup=2):
    """Interleave the two loops sample-by-sample (alternating order) so
    machine-load drift hits both sides equally, then report medians —
    a sequential A-then-B timing at this granularity reads multi-percent
    phantom 'overhead' from drift alone."""
    for _ in range(warmup):
        a()
        b()
    ta, tb = [], []
    for i in range(iters):
        if i % 2:
            ta.append(a())
            tb.append(b())
        else:
            tb.append(b())
            ta.append(a())
    return _median(ta), _median(tb)


def _time_step(fn, params, opt, batch, extra=(), iters=8, warmup=2):
    once = _step_loop(fn, params, opt, batch, extra)
    for _ in range(warmup):
        once()
    return _median([once() for _ in range(iters)])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args, _ = ap.parse_known_args()
    iters = 5 if args.smoke else 9

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    model, mesh, dims, params, opt, batch = _setup()
    plain = make_train_step(model, mesh, dims, opt_cfg, "s1")
    guarded = make_guarded_train_step(model, mesh, dims, opt_cfg, "s1")

    t_off, t_on = _time_pair(
        _step_loop(plain, params, opt, batch),
        _step_loop(guarded, params, opt, batch,
                   extra=(jnp.float32(1.0), jnp.float32(0.0))),
        iters=iters)
    ratio = t_on / max(t_off, 1e-12)
    emit("guards_off_step", 1e6 * t_off, "plain train step")
    emit("guards_on_step", 1e6 * t_on, "guarded (skip-step where-select)")
    emit("guards_overhead", 1e6 * (t_on - t_off),
         f"ratio {ratio:.4f} (accept < 1.02)")

    # full stack: fp8 wire + saturation monitor riding the encodes
    from repro.runtime import (disable_fp8_monitor, enable_fp8_monitor,
                               fp8_sat_counts, reset_fp8_counter)
    model8, mesh8, dims8, params8, opt8, batch8 = _setup(wire="fp8_e4m3")
    guarded8 = make_guarded_train_step(model8, mesh8, dims8, opt_cfg, "s1")
    reset_fp8_counter()
    enable_fp8_monitor()
    try:
        t_mon = _time_step(guarded8, params8, opt8, batch8,
                           extra=(jnp.float32(1.0), jnp.float32(0.0)),
                           iters=iters)
    finally:
        disable_fp8_monitor()
    sat, tot = fp8_sat_counts()
    emit("guards_fp8_monitor_step", 1e6 * t_mon,
         f"fp8 wire + sat counter ({sat}/{tot} saturating)")


if __name__ == "__main__":
    main()
