"""Plan-executor overhead: trace+lower time of the plan-built schedule
bodies vs the golden hand-written legacy bodies, plus an execution
sanity row per schedule (including ``s2h``, which only exists in the IR).

    PYTHONPATH=src python benchmarks/bench_plan_overhead.py
    PYTHONPATH=src python benchmarks/bench_plan_overhead.py --smoke

The executor adds a pure-Python graph walk per trace (validation + one
dict lookup per stage); the emitted jaxpr is op-for-op the legacy
body's, so the only possible regression is trace-time.  ``--smoke`` (the
CI gate) asserts the median trace+lower overhead stays under 10%.

Prints ``name,us_per_call,derived`` CSV rows for ``benchmarks/run.py``
(the ``plan_trace_*`` rows land in ``BENCH_pr4.json``).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests",
                                "helpers"))

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

OVERHEAD_LIMIT = 0.10   # --smoke gate: < 10% trace-time overhead


def median_lower_time(make_fn, x, params, reps):
    """Median seconds to trace+lower (``make_fn()`` returns a FRESH
    function object each rep — jax's jit cache keys on function
    identity, so reusing one object would measure cache lookups)."""
    import jax
    ts = []
    for _ in range(reps):
        fn = make_fn()
        t0 = time.perf_counter()
        jax.jit(fn).lower(x, params)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: assert aggregate overhead < 10%")
    ap.add_argument("--reps", type=int, default=7)
    ap.add_argument("--tokens", type=int, default=256)
    args = ap.parse_args()
    reps = 5 if args.smoke else args.reps

    import jax
    import numpy as np

    import legacy_bodies
    import repro.core.schedules as S
    from repro.core.moe import MoEConfig, apply_moe, init_moe_params
    from repro.parallel.mesh import ParallelDims, make_mesh

    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    cfg = MoEConfig(d_model=64, d_ff=128, n_experts=8, top_k=2,
                    capacity_factor=2.0, schedule="baseline",
                    pipeline_chunks=2)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, args.tokens, 64))

    print("name,us_per_call,derived")
    tot_plan = tot_legacy = 0.0
    scheds = ["baseline", "s1", "s2", "s1_seqpar"]
    for sched in scheds:
        def make_fn(s=sched):
            def fn(x, p):
                return apply_moe(x, p, mesh=mesh, dims=dims, cfg=cfg,
                                 schedule=s)[0]
            return fn

        t_plan = median_lower_time(make_fn, x, params, reps)
        saved = dict(S.BODY)
        S.BODY.update(legacy_bodies.LEGACY_BODY)
        try:
            t_legacy = median_lower_time(make_fn, x, params, reps)
        finally:
            S.BODY.clear()
            S.BODY.update(saved)
        tot_plan += t_plan
        tot_legacy += t_legacy
        print(f"plan_trace_{sched},{t_plan * 1e6:.1f},"
              f"legacy={t_legacy * 1e6:.1f}us "
              f"ratio={t_plan / t_legacy:.3f}")

    # s2h has no legacy twin: record that the IR-only schedule lowers
    # and executes (one real call, 8 fake devices)
    t0 = time.perf_counter()
    y = jax.jit(lambda x, p: apply_moe(
        x, p, mesh=mesh, dims=dims, cfg=cfg, schedule="s2h")[0])(x, params)
    y.block_until_ready()
    assert np.isfinite(np.asarray(y)).all()
    print(f"plan_exec_s2h,{(time.perf_counter() - t0) * 1e6:.1f},"
          "hierarchical dispatch/combine (compile+run, IR-only schedule)")

    # aggregate across schedules: per-schedule medians carry ~10%
    # machine noise at these ~60ms trace times, the sum does not
    overhead = tot_plan / tot_legacy - 1.0
    print(f"plan_trace_total,{tot_plan * 1e6:.1f},"
          f"legacy={tot_legacy * 1e6:.1f}us overhead={overhead:+.1%}")
    if args.smoke:
        assert overhead < OVERHEAD_LIMIT, (
            f"plan-executor trace overhead {overhead:.1%} exceeds "
            f"{OVERHEAD_LIMIT:.0%} vs the golden legacy bodies")
        print(f"# smoke OK: aggregate trace overhead {overhead:+.1%} "
              f"(limit {OVERHEAD_LIMIT:.0%})")


if __name__ == "__main__":
    main()
