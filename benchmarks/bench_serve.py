"""Serving-engine benchmark: throughput + latency percentiles over the
(decode batch x schedule x wire) grid.

Each cell serves a synthetic request trace (mixed prompt lengths, fixed
generation budget) through the continuous-batching engine on the
reduced MoE arch and reports microseconds per generated token plus the
derived tok/s and p50/p95/p99 request-latency percentiles — the serving
analogue of the paper's per-layer schedule sweeps: decode-time pools
pick a different (schedule, wire) point than training, and this is the
bench that shows it.  A final pair of rows serves a shared-system-prompt
trace with the paged pool's prefix cache off vs on (PR 7), reporting
prefix hits / prefill tokens actually skipped.

Run under 8 fake CPU devices (benchmarks/run.py does this):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""

from __future__ import annotations

import argparse
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.collectives import CommConfig
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.serve import Engine, latency_stats

ARCH = "qwen3-moe-30b-a3b"


def serve_once(cfg, mesh, dims, *, max_batch, schedule, wire, n_requests,
               gen, seed=0, **engine_kw):
    if wire != "f32":
        cfg = replace(cfg, moe=replace(
            cfg.moe, comm=CommConfig(wire_dtype=wire)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, mesh, dims, max_batch=max_batch, max_len=64,
                    schedule=None if schedule == "auto" else schedule,
                    **engine_kw)
    rng = np.random.RandomState(seed)
    # warmup: compile prefill buckets + the decode step
    engine.submit(rng.randint(0, cfg.vocab_size, 8), 2)
    engine.run(params)
    import time
    for _ in range(n_requests):
        engine.submit(rng.randint(0, cfg.vocab_size, rng.randint(4, 13)),
                      gen)
    t0 = time.perf_counter()
    done = engine.run(params)
    dt = time.perf_counter() - t0
    stats = latency_stats(done)
    n_tok = stats["n_tokens"]
    return 1e6 * dt / max(n_tok, 1), stats


def serve_prefix(cfg, mesh, dims, *, max_batch, n_requests, gen,
                 prefix_cache, seed=0):
    """Shared-system-prompt trace: every request repeats a 33-token
    prefix plus a short private tail, so the paged pool's prefix cache
    (PR 7) can skip the bulk of every prefill after the first."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, mesh, dims, max_batch=max_batch, max_len=64,
                    prefix_cache=prefix_cache)
    rng = np.random.RandomState(seed)
    sysp = list(rng.randint(0, cfg.vocab_size, 33))
    engine.submit(sysp + [1], 2)       # warmup compiles + primes cache
    engine.run(params)
    import time
    for _ in range(n_requests):
        tail = list(rng.randint(0, cfg.vocab_size, rng.randint(2, 7)))
        engine.submit(sysp + tail, gen)
    t0 = time.perf_counter()
    done = engine.run(params)
    dt = time.perf_counter() - t0
    stats = latency_stats(done)
    return 1e6 * dt / max(stats["n_tokens"], 1), stats, engine.stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one tiny grid cell per axis")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=8)
    args, _ = ap.parse_known_args()

    n_dev = jax.device_count()
    d = max(1, n_dev // 2) if n_dev > 1 else 1
    mesh = make_mesh((d, max(n_dev // d, 1)), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    cfg = get_config(ARCH).reduced()

    # decode batch must put >= n_mp tokens on every shard for the real
    # decode-schedule path; below that the replicated fallback serves
    min_batch = max(2 * d, 2)
    if args.smoke:
        grid = [(min_batch, "auto", "f32"), (min_batch, "s1d", "bf16")]
        args.requests, args.gen = 6, 4
    else:
        grid = [(b, s, w)
                for b in (min_batch, 2 * min_batch)
                for s in ("auto", "s1d", "s2")
                for w in ("f32", "bf16")]

    for max_batch, schedule, wire in grid:
        us_tok, stats = serve_once(
            cfg, mesh, dims, max_batch=max_batch, schedule=schedule,
            wire=wire, n_requests=args.requests, gen=args.gen)
        emit(f"serve_{ARCH}_b{max_batch}_{schedule}_{wire}", us_tok,
             f"tok_per_s={stats['tok_per_s']:.1f};"
             f"p50_ms={stats['p50_ms']:.0f};"
             f"p95_ms={stats['p95_ms']:.0f};"
             f"p99_ms={stats['p99_ms']:.0f};"
             f"ttft_p50_ms={stats['ttft_p50_ms']:.0f}")

    # paged-KV prefix reuse: same shared-prefix trace, cache off vs on
    for on in (False, True):
        us_tok, stats, es = serve_prefix(
            cfg, mesh, dims, max_batch=min_batch,
            n_requests=args.requests, gen=args.gen, prefix_cache=on)
        emit(f"serve_{ARCH}_prefix_{'on' if on else 'off'}", us_tok,
             f"tok_per_s={stats['tok_per_s']:.1f};"
             f"prefix_hits={es['prefix_hits']};"
             f"prefix_tokens={es['prefix_tokens']};"
             f"prefill_tokens={es['prefill_tokens']};"
             f"peak_blocks={es['peak_blocks']}")
    if args.smoke:
        print("# bench_serve smoke ok")


if __name__ == "__main__":
    main()
