"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
artifacts/dryrun/*.json.  Usage:

    PYTHONPATH=src:. python -m benchmarks.make_experiments_tables
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh=None, schedule_suffix=""):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if schedule_suffix and (len(parts) < 4 or parts[3] != schedule_suffix):
            continue
        if not schedule_suffix and len(parts) != 3:
            continue
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        recs.append(rec)
    key = {s: i for i, s in enumerate(SHAPE_ORDER)}
    recs.sort(key=lambda r: (r["arch"], key.get(r["shape"], 9), r["mesh"]))
    return recs


def fmt_bytes(n):
    for unit in ["B", "KB", "MB", "GB", "TB"]:
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table():
    print("| arch | shape | mesh | sched | compile s | HLO flops/chip |"
          " coll B/chip | collective mix | arg+tmp mem/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in load():
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                  f" — | skipped: {r['skipped']} | — |")
            continue
        mem = r.get("memory_analysis") or {}
        memsum = sum(v for k, v in mem.items()
                     if v and k in ("argument_size_in_bytes",
                                    "temp_size_in_bytes"))
        mix = ",".join(f"{k.split('-')[-1]}:{v}"
                       for k, v in sorted(
                           r["collectives"]["counts"].items()))
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
              f" {r['schedule']} | {r['compile_s']:.0f} |"
              f" {rl['hlo_flops'] / r['chips']:.2e} |"
              f" {fmt_bytes(rl['collective_bytes_per_chip'])} |"
              f" {mix} | {fmt_bytes(memsum) if memsum else 'n/a'} |")


def roofline_table(mesh="single"):
    print("| arch | shape | variant | sched | t_comp s | t_mem s |"
          " t_coll s | bottleneck | useful flops | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in load(mesh=mesh):
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — |"
                  f" — | {r['skipped']} |")
            continue
        rl = r["roofline"]
        terms = {"compute": rl["t_compute_s"], "memory": rl["t_memory_s"],
                 "collective": rl["t_collective_s"]}
        dom = rl["bottleneck"]
        sub = sorted(terms.values())[-2]
        note = f"dom/2nd={terms[dom] / max(sub, 1e-12):.1f}x"
        print(f"| {r['arch']} | {r['shape']} | {r.get('variant', '')} |"
              f" {r['schedule']} | {rl['t_compute_s']:.3e} |"
              f" {rl['t_memory_s']:.3e} | {rl['t_collective_s']:.3e} |"
              f" **{dom}** | {rl['useful_flops_ratio']:.2f} | {note} |")


def main():
    print("### §Dry-run (both meshes)\n")
    dryrun_table()
    print("\n### §Roofline (single-pod 16x16 = 256 chips)\n")
    roofline_table()


if __name__ == "__main__":
    main()
