"""Table IV reproduction: average speedups of S1 / S2 / Parm over the
baseline schedule on the Table III configuration grid, grouped by
(N_MP, N_ESP) — analytic alpha-beta model with TPU v5e constants.

The paper reports 1.13x-5.77x (avg 2.1x-5.77x per group) on GPU PCIe
clusters; the structure (monotone in N_MP/N_ESP, Parm >= max(S1, S2))
must reproduce on any fabric.
"""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import emit, table3_grid
from repro.core.perfmodel import MoELayerShape, speedup_table, tpu_v5e_model


def main():
    groups = defaultdict(list)
    n_total = 0
    all_speedups = []
    for c in table3_grid():
        if c["n_mp"] == 1:          # Table IV groups have N_MP in {2, 4}
            continue
        m = tpu_v5e_model(c["n_ep"], c["n_esp"], c["n_mp"],
                          inter_pod=c["P"] > 256)
        s = MoELayerShape(B=c["B"], L=c["L"], M=c["M"], H=c["H"],
                          E=c["E"], k=c["k"], f=c["f"], n_mp=c["n_mp"],
                          n_esp=c["n_esp"], n_ep=c["n_ep"])
        row = speedup_table(s, m)
        groups[(c["n_mp"], c["n_esp"])].append(row)
        all_speedups.append(row["speedup_parm"])
        n_total += 1

    emit("table4/configs", 0.0, f"n={n_total}")
    for (n_mp, n_esp), rows in sorted(groups.items()):
        for key in ("speedup_s1", "speedup_s2", "speedup_parm"):
            avg = sum(r[key] for r in rows) / len(rows)
            emit(f"table4/mp{n_mp}_esp{n_esp}_{key}", 0.0, f"{avg:.3f}x")
        # paper invariant: Parm picks the better of S1/S2 per config
        for r in rows:
            assert (r["speedup_parm"]
                    >= max(r["speedup_s1"], r["speedup_s2"]) - 1e-9)
            # Eq. (6)/(10) claim S1/S2 always beat the baseline.  That holds
            # for S1 everywhere; for S2 a handful of alpha-dominated tiny-T
            # configs dip to ~0.99x because Eq. (10)'s derivation ignores
            # per-collective startup terms (recorded in EXPERIMENTS.md).
            assert r["speedup_s1"] > 1.0, r
            assert r["speedup_s2"] > 0.95, r
            assert r["speedup_parm"] > 1.0, r

    lo, hi = min(all_speedups), max(all_speedups)
    emit("table4/range", 0.0, f"{lo:.2f}x..{hi:.2f}x (paper: 1.13x..5.77x)")

    # monotonicity in N_MP (paper: larger N_MP -> larger improvement)
    m2 = sum(r["speedup_parm"] for r in groups[(2, 2)]) / len(groups[(2, 2)])
    m4 = sum(r["speedup_parm"] for r in groups[(4, 2)]) / len(groups[(4, 2)])
    assert m4 > m2, (m2, m4)
    e2 = sum(r["speedup_parm"] for r in groups[(4, 2)]) / len(groups[(4, 2)])
    e4 = sum(r["speedup_parm"] for r in groups[(4, 4)]) / len(groups[(4, 4)])
    assert e4 > e2, (e2, e4)


if __name__ == "__main__":
    main()
