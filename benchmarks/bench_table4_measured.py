"""Table IV (measured companion): wall-clock MoE-layer iteration time for
baseline vs S1 vs S2 vs Parm(auto) on a real 8-device (4x2) mesh — actual
execution of the three schedules, CPU fabric.  Subset of the Table III
grid scaled to CPU-feasible sizes.

Run via subprocess with 8 fake devices (benchmarks/run.py handles it).
"""

from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from benchmarks.common import emit, time_fn             # noqa: E402
from repro.core.moe import (MoEConfig, apply_moe,       # noqa: E402
                            init_moe_params)
from repro.parallel.mesh import ParallelDims, make_mesh  # noqa: E402

CASES = [
    # (B, L, M, H, E, k, f)
    (8, 256, 256, 512, 8, 2, 1.2),
    (8, 256, 256, 512, 8, 2, 2.4),
    (4, 512, 512, 1024, 8, 2, 1.2),
    (8, 512, 256, 1024, 8, 1, 1.2),
    (4, 256, 512, 512, 8, 4, 1.2),
    (2, 1024, 256, 512, 8, 2, 1.2),
]


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    wins = 0
    for (B, L, M, H, E, k, f) in CASES:
        cfg = MoEConfig(d_model=M, d_ff=H, n_experts=E, top_k=k,
                        capacity_factor=f)
        params = init_moe_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, L, M))
        times = {}
        for sched in ["baseline", "s1", "s2", "auto"]:
            fn = jax.jit(lambda x, p, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=cfg, schedule=s)[0])
            fn(x, params).block_until_ready()
            times[sched] = time_fn(
                lambda: fn(x, params).block_until_ready(), iters=7)
        name = f"B{B}_L{L}_M{M}_H{H}_E{E}_k{k}_f{f}"
        sp1 = times["baseline"] / times["s1"]
        sp2 = times["baseline"] / times["s2"]
        spa = times["baseline"] / times["auto"]
        emit(f"table4m/{name}", times["baseline"] * 1e6,
             f"s1={sp1:.2f}x s2={sp2:.2f}x parm={spa:.2f}x")
        wins += spa > 1.0
    emit("table4m/parm_wins", 0.0, f"{wins}/{len(CASES)}")


if __name__ == "__main__":
    main()
