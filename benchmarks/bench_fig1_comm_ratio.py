"""Fig. 1 reproduction: communication-time ratio of MoE layers across the
Table III configuration grid, from the alpha-beta analytic model with TPU
v5e fabric constants (the paper measured 67.9%-96.0% on 32x RTX2080Ti).

Communication = baseline-schedule collectives (Eq. 1); compute = expert
FFN + gate FLOPs at v5e peak.
"""

from __future__ import annotations

from benchmarks.common import emit, table3_grid
from repro.core.perfmodel import (MoELayerShape, PEAK_FLOPS_BF16,
                                  tpu_v5e_model)


def comm_ratio(c) -> float:
    m = tpu_v5e_model(c["n_ep"], c["n_esp"], c["n_mp"])
    s = MoELayerShape(B=c["B"], L=c["L"], M=c["M"], H=c["H"], E=c["E"],
                      k=c["k"], f=c["f"], n_mp=c["n_mp"],
                      n_esp=c["n_esp"], n_ep=c["n_ep"])
    t_comm = m.t_baseline(s)
    # expert compute (baseline: each shard computes N_ESP*N_MP-duplicated
    # tokens; 2 matmuls of M*H/N_ESP per token) + gate
    tokens = s.E * s.T * s.n_esp                  # per EP rank, duplicated
    flops = tokens * 4 * s.M * s.H / s.n_esp + s.B * s.L * s.M * s.E * 2
    t_comp = flops / PEAK_FLOPS_BF16
    return t_comm / (t_comm + t_comp)


def main():
    ratios = [(comm_ratio(c), c) for c in table3_grid()]
    vals = sorted(r for r, _ in ratios)
    n = len(vals)
    emit("fig1/configs", 0.0, f"n={n}")
    emit("fig1/comm_ratio_min", 0.0, f"{vals[0]:.4f}")
    emit("fig1/comm_ratio_p50", 0.0, f"{vals[n // 2]:.4f}")
    emit("fig1/comm_ratio_max", 0.0, f"{vals[-1]:.4f}")
    frac_dominant = sum(v > 0.5 for v in vals) / n
    emit("fig1/frac_comm_dominant", 0.0, f"{frac_dominant:.4f}")
    # paper: 67.92%..96.02% ratio on PCIe GPUs; v5e ICI is faster relative
    # to compute, but communication still dominates the MoE layer:
    assert vals[-1] > 0.5, "communication should dominate somewhere"


if __name__ == "__main__":
    main()
