"""Telemetry overhead benchmark: metrics-on vs metrics-off train step.

The obs layer (PR 10) promises that a live JSONL sink plus the per-step
runtime emitters cost < 2% on the guarded train step — the acceptance
bar for leaving ``--metrics-dir`` on in production runs.  Both variants
drive the SAME guarded MoE step through the donated ping-pong loop of
``bench_guards``; the metrics-on side additionally (a) traces its
program while the obs sink is configured (so the ``trace_tag`` /
``named_scope`` hooks are live at trace time) and (b) emits the
Trainer's per-step events (``set_context`` + ``train_step`` +
``expert_load``) inside the timed region, buffered exactly as the
production sink buffers them.

The two loops interleave sample-by-sample (``_time_pair``) so
machine-load drift cancels; a sequential A-then-B comparison at this
granularity reads multi-percent phantom overhead from drift alone.

Run under 8 fake CPU devices (benchmarks/run.py does this):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_obs_overhead [--smoke]
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_guards import _median, _setup, _time_pair
from benchmarks.common import emit
from repro import obs
from repro.optim.adamw import AdamWConfig
from repro.train.loop import make_guarded_train_step


def _step_loop(fn, params, opt, batch, extra=(), metrics=False):
    """Donated ping-pong step closure (as in bench_guards), optionally
    emitting the Trainer's per-step telemetry inside the timed region."""
    jitted = jax.jit(fn, donate_argnums=(0, 1))
    st = {"p": jax.tree.map(jnp.copy, params),
          "o": jax.tree.map(jnp.copy, opt), "i": 0}

    def once():
        t0 = time.perf_counter()
        st["p"], st["o"], m = jitted(st["p"], st["o"], batch, *extra)
        jax.block_until_ready(m["loss"])
        if metrics:
            obs.set_context(step=st["i"])
            obs.emit("train_step", loss=float(m["loss"]),
                     grad_norm=float(m.get("grad_norm", 0.0)))
            obs.emit("expert_load", load=[0.25, 0.25, 0.25, 0.25])
        st["i"] += 1
        return time.perf_counter() - t0

    return once


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args, _ = ap.parse_known_args()
    iters = 5 if args.smoke else 9

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    model, mesh, dims, params, opt, batch = _setup()
    extra = (jnp.float32(1.0), jnp.float32(0.0))

    # metrics-off: obs unconfigured, trace and run on the plain path
    guarded_off = make_guarded_train_step(model, mesh, dims, opt_cfg, "s1")
    loop_off = _step_loop(guarded_off, params, opt, batch, extra)

    with tempfile.TemporaryDirectory() as td:
        # metrics-on: the sink is live BEFORE tracing, so the program is
        # built exactly as a --metrics-dir run builds it
        obs.configure(td, meta={"kind": "bench"})
        try:
            guarded_on = make_guarded_train_step(model, mesh, dims,
                                                 opt_cfg, "s1")
            loop_on = _step_loop(guarded_on, params, opt, batch, extra,
                                 metrics=True)
            t_off, t_on = _time_pair(loop_off, loop_on, iters=iters)
            obs.flush()
        finally:
            obs.close()

    ratio = t_on / max(t_off, 1e-12)
    emit("obs_off_step", 1e6 * t_off, "guarded step, no telemetry")
    emit("obs_on_step", 1e6 * t_on,
         "guarded step + live JSONL sink + per-step emitters")
    emit("obs_overhead", 1e6 * (t_on - t_off),
         f"ratio {ratio:.4f} (accept < 1.02)")
    if args.smoke:
        assert ratio < 1.02, \
            f"obs overhead {ratio:.4f} exceeds the 2% acceptance bar"
        print("OBS SMOKE OK")


if __name__ == "__main__":
    main()
