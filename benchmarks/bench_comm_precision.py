"""Sweep the wire-precision subsystem: (schedule x wire_dtype) wall time
and error for one MoE layer, plus the extended analytic autosched pick.

    PYTHONPATH=src python benchmarks/bench_comm_precision.py
    PYTHONPATH=src python benchmarks/bench_comm_precision.py \
        --mesh distinct --wire f32 bf16 fp8_e4m3 --tokens 2048

Runs anywhere (fake CPU devices by default; honours a pre-set XLA_FLAGS
device count).  On CPU the collectives are memcpys, so the wire encode /
decode shows up as pure *overhead* — the bytes-on-fabric win needs real
ICI/NVLink; what this sweep validates everywhere is that every
(schedule x wire) combination lowers, runs, keeps routing bit-identical
(drop_frac), and stays within the dtype's error envelope.  The same
sweep on a TPU slice is the measured counterpart of
``PerfModel.t_pipelined(..., wire_dtype=...)``.

Emits ``name,us_per_call,derived`` CSV rows (the ``benchmarks/run.py``
contract); ``#`` comment lines are comma-free so the runner skips them.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from benchmarks.common import time_fn                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="merged",
                    choices=["merged", "distinct"],
                    help="merged: (ep=4, model=2) with MP==ESP; distinct: "
                         "(ep=2, esp=2, mp=2)")
    ap.add_argument("--schedules", nargs="+",
                    default=["baseline", "s1", "s2"])
    ap.add_argument("--wire", nargs="+",
                    default=["f32", "bf16", "fp8_e4m3"])
    ap.add_argument("--pipeline-chunks", type=int, default=1,
                    help="also chunk-pipeline each schedule body")
    ap.add_argument("--tokens", type=int, default=1024)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--n-experts", type=int, default=8)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--backend", default=None, choices=["ref", "pallas"],
                    help="pin the kernel backend (pallas = interpret "
                         "mode off-TPU; the CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny preset for CI: one schedule, small layer")
    args = ap.parse_args()
    if args.smoke:
        args.schedules = args.schedules[:1]
        args.tokens = min(args.tokens, 256)
        args.d_model = min(args.d_model, 32)
        args.d_ff = min(args.d_ff, 64)
        args.iters = min(args.iters, 2)

    from dataclasses import replace

    from repro.core import autosched
    from repro.core.collectives import CommConfig
    from repro.core.moe import MoEConfig, apply_moe, init_moe_params
    from repro.core.perfmodel import MoELayerShape, tpu_v5e_model
    from repro.kernels.registry import KernelConfig
    from repro.parallel.mesh import ParallelDims, make_mesh

    if args.mesh == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
    sizes = dims.sizes(mesh)

    kernel = (KernelConfig(backend=args.backend) if args.backend
              else KernelConfig())
    cfg0 = MoEConfig(d_model=args.d_model, d_ff=args.d_ff,
                     n_experts=args.n_experts, top_k=args.top_k,
                     capacity_factor=2.0, schedule="baseline",
                     pipeline_chunks=args.pipeline_chunks, kernel=kernel)
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, args.tokens, args.d_model))

    print(f"# mesh={args.mesh} sizes={sizes} tokens={args.tokens} "
          f"M={args.d_model} H={args.d_ff} E={args.n_experts} "
          f"k={args.top_k} chunks={args.pipeline_chunks}")
    for sched in args.schedules:
        ref_y = ref_us = ref_drop = None
        for wire in args.wire:
            cfg = replace(cfg0, comm=CommConfig(wire_dtype=wire))
            fn = jax.jit(lambda x, p, c=cfg, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=c, schedule=s))
            y, aux = fn(x, params)
            y = np.asarray(y)
            drop = float(aux["drop_frac"])
            if ref_y is None:
                ref_y, ref_drop = y, drop
            err = float(np.max(np.abs(y - ref_y)))
            routing = "same" if drop == ref_drop else "CHANGED"
            dt = time_fn(lambda: fn(x, params)[0].block_until_ready(),
                         iters=args.iters)
            us = dt * 1e6
            ref_us = ref_us or us
            print(f"comm_precision/{sched}/{wire},{us:.3f},"
                  f"maxerr={err:.2e};drop={routing};"
                  f"vs_f32={ref_us / us:.2f}x")

    shape = MoELayerShape(
        B=1, L=args.tokens, M=args.d_model, H=args.d_ff,
        E=args.n_experts, k=args.top_k, f=2.0,
        n_mp=sizes["mp"], n_esp=sizes["esp"], n_ep=sizes["ep"])
    pm = tpu_v5e_model(sizes["ep"], sizes["esp"], sizes["mp"])
    d = autosched.decide(shape, perf_model=pm,
                         wire_candidates=autosched.AUTO_WIRE)
    print(f"# analytic joint pick (tpu_v5e model): "
          f"{d.schedule} x {d.n_chunks} chunks @ wire {d.wire_dtype}")
    for sched in args.schedules:
        for wire in ("f32", "bf16"):
            t = pm.t_pipelined(shape, sched, 1, wire_dtype=wire)
            print(f"#   predicted {sched:8s} @ {wire}: {t * 1e6:.2f} us")


if __name__ == "__main__":
    main()
