"""Roofline report: read artifacts/dryrun/*.json (the baseline dry-runs)
and emit one row per (arch x shape x mesh) with the three roofline terms
and the dominant bottleneck (§Roofline deliverable)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def main():
    files = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not files:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all --mesh both` first")
        return
    n = 0
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            emit(f"roofline/{rec['arch']}__{rec['shape']}__{rec['mesh']}",
                 0.0, "skipped: " + rec["skipped"])
            continue
        rl = rec["roofline"]
        emit(f"roofline/{rec['arch']}__{rec['shape']}__{rec['mesh']}",
             rl["t_compute_s"] * 1e6,
             f"mem={rl['t_memory_s'] * 1e6:.1f}us "
             f"coll={rl['t_collective_s'] * 1e6:.1f}us "
             f"bound={rl['bottleneck']} "
             f"useful={rl['useful_flops_ratio']:.2f} "
             f"sched={rec.get('schedule')}")
        n += 1
    emit("roofline/rows", 0.0, f"n={n}")


if __name__ == "__main__":
    main()
