"""Shared benchmark utilities: the paper's Table III configuration grid,
timing helpers, and CSV emission."""

from __future__ import annotations

import itertools
import statistics
import time

# Paper Table III: candidate values (32-GPU testbed analog).  The paper
# runs the 1296 valid combinations of these.
TABLE3 = {
    "P": [8, 16, 32],
    "n_mp": [1, 2, 4],
    "n_esp": [1, 2, 4],
    "B": [2, 4, 8],
    "L": [512, 1024, 2048],
    "MH": [1024, 2048, 4096],   # H/N_ES and M/N_ES candidates
    "f": [1.2, 2.4],
}


def table3_grid():
    """Yield valid MoE-layer configs from the Table III grid."""
    for P, n_mp, n_esp, B, L, MH, f in itertools.product(
            TABLE3["P"], TABLE3["n_mp"], TABLE3["n_esp"], TABLE3["B"],
            TABLE3["L"], TABLE3["MH"], TABLE3["f"]):
        n_ep = P // (n_mp * n_esp) if P % (n_mp * n_esp) == 0 else 0
        if n_ep < 1:
            continue
        M = MH * n_esp
        H = MH * n_esp
        E = n_ep                      # one expert per EP rank (paper setup)
        yield dict(P=P, n_mp=n_mp, n_esp=n_esp, n_ep=n_ep, B=B, L=L,
                   M=M, H=H, E=E, k=2, f=f)


def time_fn(fn, *args, iters=10, warmup=3):
    """Median wall time per call in seconds (after warmup)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")
