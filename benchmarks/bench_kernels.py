"""Kernel-backend comparison harness: ref vs pallas timings per registry op.

For each hot-path op the same workload runs under both backends through
``repro.kernels.registry.get_op`` and the median wall time is emitted as
CSV (``op,backend,shape,us_per_call``).  On TPU this measures the real
compiled kernels; off-TPU the pallas backend runs in interpret mode, so
the ref numbers are the meaningful ones and the pallas column only proves
the path executes (pass ``--skip-interpret`` to drop it).

Run:  PYTHONPATH=src python benchmarks/bench_kernels.py [--iters 10]
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from benchmarks.common import emit, time_fn             # noqa: E402
from repro.core.gating import GateConfig, capacity, topk_gate  # noqa: E402
from repro.kernels.registry import BACKENDS, get_op     # noqa: E402


def _moe_routing(S, M, E, k, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (S, M))
    wg = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, E)) * 0.3
    cfg = GateConfig(n_experts=E, top_k=k, capacity_factor=1.25)
    cap = capacity(S, cfg)
    eidx, slot, w, _ = topk_gate(x, wg, cfg, cap)
    flat = jnp.where(slot < cap, eidx * cap + slot, E * cap).astype(jnp.int32)
    return x, flat, w, E * cap


def workloads(sizes: str):
    """(op, shape-tag, static kwargs, arg-builder) per benchmarked op."""
    if sizes == "small":          # CI / interpret-friendly
        E, T, M, F = 4, 256, 256, 512
        S, k = 1024, 2
        B, L, H, K, hd = 1, 512, 8, 2, 64
        R, D = 4096, 1024
    else:                         # "paper": closer to Table III scale
        E, T, M, F = 8, 1024, 1024, 4096
        S, k = 8192, 2
        B, L, H, K, hd = 4, 2048, 16, 4, 128
        R, D = 32768, 4096

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xe = jax.random.normal(ks[0], (E, T, M))
    w1 = jax.random.normal(ks[1], (E, M, F)) * 0.05
    w3 = jax.random.normal(ks[2], (E, M, F)) * 0.05
    w2 = jax.random.normal(ks[3], (E, F, M)) * 0.05
    xs, flat, w, n_slots = _moe_routing(S, M, E, k)
    cap = n_slots // E
    buf = jax.random.normal(ks[0], (n_slots, M))
    q = jax.random.normal(ks[1], (B, L, H, hd))
    kv_k = jax.random.normal(ks[2], (B, L, K, hd))
    kv_v = jax.random.normal(ks[3], (B, L, K, hd))
    xr = jax.random.normal(ks[0], (R, D))
    sc = jnp.ones((D,))
    # ragged view of the expert pool: half-full groups, the dropless
    # kernel's typical training load
    counts = jnp.full((E, 1), T // 2, jnp.int32)

    return [
        ("expert_ffn", f"E{E}xT{T}xM{M}xF{F}", {"act": "silu"},
         (xe, w1, w3, w2)),
        ("expert_ffn_ragged", f"E{E}xG1xc{T}xM{M}xF{F}", {"act": "silu"},
         (xe[:, None], counts, w1, w3, w2)),
        ("expert_ffn_grouped", f"S{S}xM{M}xE{E}k{k}c{cap}",
         {"act": "silu", "cap": cap, "wire": "f32"},
         (xs, flat, w, w1, w3, w2)),
        ("moe_dispatch", f"S{S}xM{M}xE{E}k{k}", {"n_slots": n_slots},
         (xs, flat)),
        ("moe_combine", f"S{S}xM{M}xE{E}k{k}", {}, (buf, flat, w)),
        ("rmsnorm", f"R{R}xD{D}", {"eps": 1e-5}, (xr, sc)),
        ("flash_attention", f"B{B}xL{L}xH{H}/{K}xhd{hd}", {"causal": True},
         (q, kv_k, kv_v)),
    ]


def grouped_vs_pool(iters: int, sizes: str, on_tpu: bool,
                    skip_interpret: bool):
    """Grouped-vs-pool rows across an expert-load skew sweep.

    The pool path multiplies every capacity slot (FLOPs fixed at
    E * cap); the ragged kernel multiplies only the routed rows, so its
    FLOPs column shrinks as skew concentrates load (empty experts cost
    nothing).  On TPU the us column tracks the FLOPs column; off-TPU the
    pallas numbers are interpret-mode and the analytic ``gflop`` field
    in ``derived`` is the datapoint BENCH_pr6.json diffs.
    """
    E, T, M, F = (4, 256, 256, 512) if sizes == "small" \
        else (8, 1024, 1024, 4096)
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xb = jax.random.normal(ks[0], (E, 1, T, M))
    w1 = jax.random.normal(ks[1], (E, M, F)) * 0.05
    w3 = jax.random.normal(ks[2], (E, M, F)) * 0.05
    w2 = jax.random.normal(ks[3], (E, F, M)) * 0.05
    routed_total = E * T // 2            # f=2 equivalent demand
    gflop_row = 3 * 2 * M * F / 1e9      # SwiGLU: 3 GEMMs per row

    for skew in (0.0, 0.5, 1.0):
        # expert e's share: uniform blended toward all-on-expert-0
        share = [(1.0 - skew) / E + (skew if e == 0 else 0.0)
                 for e in range(E)]
        cnt = jnp.array([[min(T, round(routed_total * s))]
                         for s in share], jnp.int32)
        routed = int(cnt.sum())
        for kind, backend_grid in (("pool", ("ref", "pallas")),
                                   ("ragged", ("ref", "pallas"))):
            rows = E * T if kind == "pool" else routed
            for backend in backend_grid:
                if backend == "pallas" and not on_tpu and skip_interpret:
                    continue
                if kind == "pool":
                    fn = get_op("expert_ffn", backend=backend, act="silu")
                    run = lambda: jax.block_until_ready(       # noqa: E731
                        fn(xb[:, 0], w1, w3, w2))
                else:
                    fn = get_op("expert_ffn_ragged", backend=backend,
                                act="silu")
                    run = lambda: jax.block_until_ready(       # noqa: E731
                        fn(xb, cnt, w1, w3, w2))
                n = iters if (backend == "ref" or on_tpu) else \
                    max(2, iters // 5)
                t = time_fn(run, iters=n, warmup=2)
                emit(f"kernels/grouped_vs_pool/{kind}/{backend}",
                     t * 1e6,
                     f"skew={skew} routed={routed}/{E * T} "
                     f"gflop={rows * gflop_row:.3f}")


def main(argv=None):
    # programmatic callers (benchmarks/run.py) get the defaults; only the
    # __main__ entry below reads the process argv
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--sizes", choices=("small", "paper"), default="small")
    ap.add_argument("--ops", nargs="*", default=None,
                    help="subset of ops to run (default: all)")
    ap.add_argument("--skip-interpret", action="store_true",
                    help="skip the pallas backend off-TPU (interpret mode "
                         "is emulation-speed, not a perf datapoint)")
    args = ap.parse_args([] if argv is None else argv)

    known = [w[0] for w in workloads(args.sizes)] + ["grouped_vs_pool"]
    bad = set(args.ops or ()) - set(known)
    if bad:
        ap.error(f"unknown op(s) {sorted(bad)}; choose from {known}")

    on_tpu = jax.default_backend() == "tpu"
    print(f"# backend={jax.default_backend()} "
          f"pallas={'compiled' if on_tpu else 'interpret'}", file=sys.stderr)

    for op_name, tag, static, op_args in workloads(args.sizes):
        if args.ops and op_name not in args.ops:
            continue
        for backend in BACKENDS:
            if backend == "pallas" and not on_tpu and args.skip_interpret:
                continue
            fn = get_op(op_name, backend=backend, **static)
            run = lambda: jax.block_until_ready(fn(*op_args))  # noqa: E731
            iters = args.iters if (backend == "ref" or on_tpu) else \
                max(2, args.iters // 5)
            t = time_fn(run, iters=iters, warmup=2)
            emit(f"kernels/{op_name}/{backend}", t * 1e6, tag)

    if not args.ops or "grouped_vs_pool" in args.ops:
        grouped_vs_pool(args.iters, args.sizes, on_tpu,
                        args.skip_interpret)


if __name__ == "__main__":
    main(sys.argv[1:])
