"""Load-adaptive expert placement benchmark: skewed routing, uniform vs
replicated placement.

The traffic is hot-skewed (a router bias sends ~4x the mean load to
expert 0 — the regime Megatron/MegaScale load-balancing reports target).
A *uniform* placement must inflate the capacity factor until the hot
expert fits drop-free, padding every cold expert's capacity slots with
zeros: the dispatch/combine A2A payloads and the pooled FFN all pay for
the inflation.  The *auto* placement replicates the hot expert across EP
ranks (``placement_from_loads`` on the measured load vector) and shrinks
the per-slot capacity (``cap_frac``), serving the same traffic drop-free
on a ~3x smaller capacity pool.

Rows (``name,us_per_call,derived``):
  loadbalance/uniform   — forward step time, uniform placement at the
                          drop-free capacity factor
  loadbalance/auto      — same traffic under the load-derived placement
                          (derived: cap_frac, physical slots, speedup)

Both cells must be drop-free (asserted) and auto must beat uniform
(asserted — the PR's acceptance gate).  Run under 8 fake CPU devices
(benchmarks/run.py does this):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_loadbalance [--smoke]
"""

from __future__ import annotations

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.moe import MoEConfig, apply_moe, init_moe_params
from repro.core.placement import placement_from_loads
from repro.parallel.mesh import ParallelDims, make_mesh

E = 8
TOP_K = 2
F_UNIFORM = 5.0      # drop-free capacity factor for the ~4x-hot expert
SCHED = "s1"         # forced schedule: both cells time the same plan


def make_layer(smoke: bool):
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    d_model, d_ff = (64, 128) if smoke else (128, 512)
    B, L = (32, 32) if smoke else (64, 64)
    cfg = MoEConfig(d_model=d_model, d_ff=d_ff, n_experts=E, top_k=TOP_K,
                    capacity_factor=F_UNIFORM, schedule=SCHED)
    params = init_moe_params(jax.random.PRNGKey(0), cfg)
    # route ~4x the mean load to expert 0 through feature 0 (pinned 1.0)
    bias = jnp.zeros((E,)).at[0].set(8.0)
    params = dict(params, wg=params["wg"] * 0.05
                  + jnp.zeros_like(params["wg"]).at[0, :].set(bias))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, L, d_model))
    return mesh, dims, cfg, params, x.at[..., 0].set(1.0)


def run_cell(mesh, dims, cfg, params, x, iters):
    fn = jax.jit(lambda x, p: apply_moe(x, p, mesh=mesh, dims=dims,
                                        cfg=cfg, schedule=SCHED))
    (_, aux) = fn(x, params)                       # compile + aux probe
    sec = time_fn(lambda: jax.block_until_ready(fn(x, params)[0]),
                  iters=iters, warmup=2)
    return 1e6 * sec, jax.device_get(aux)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny shapes, few iters, assert the "
                         "placed cell wins")
    args = ap.parse_args()
    iters = 5 if args.smoke else 20

    mesh, dims, cfg, params, x = make_layer(args.smoke)
    us_uni, aux_uni = run_cell(mesh, dims, cfg, params, x, iters)
    loads = np.asarray(aux_uni["expert_load"], np.float64)
    skew = float(loads.max() / max(loads.mean(), 1e-9))
    assert skew >= 4.0, f"traffic not hot enough for the bench: {skew:.2f}x"
    assert float(aux_uni["drop_frac"]) == 0.0, \
        f"uniform cell must be drop-free at f={F_UNIFORM}"

    pl = placement_from_loads(loads, dims.sizes(mesh)["ep"],
                              capacity_factor=F_UNIFORM, top_k=TOP_K)
    assert not pl.is_identity, "hot traffic must produce a replication"
    us_auto, aux_auto = run_cell(mesh, dims, replace(cfg, placement=pl),
                                 params, x, iters)
    assert float(aux_auto["drop_frac"]) == 0.0, \
        "placed cell must serve the same traffic drop-free"

    speedup = us_uni / max(us_auto, 1e-9)
    emit("loadbalance/uniform", us_uni,
         f"f={F_UNIFORM} skew={skew:.1f}x drop_frac=0")
    emit("loadbalance/auto", us_auto,
         f"R={pl.n_phys} cap_frac={pl.cap_frac:.2f} drop_frac=0 "
         f"speedup={speedup:.2f}x")
    assert us_auto < us_uni, \
        (f"auto placement must beat uniform under skew: "
         f"{us_auto:.1f}us vs {us_uni:.1f}us")
    if args.smoke:
        print("# LOADBALANCE SMOKE OK")


if __name__ == "__main__":
    main()
