"""Table V reproduction: end-to-end iteration time of the paper's two
real-world models (BERT-Base-MoE, GPT-2-MoE) under the baseline schedule
vs Parm (auto), measured on a real 8-device mesh at reduced width, plus
the full-size analytic projection with N_MP = N_ESP = 4 (paper setting).

Paper: Parm trains them 2.98x-3.15x faster than DeepSpeed-MoE.
"""

from __future__ import annotations

import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax                                              # noqa: E402

from benchmarks.common import emit, time_fn             # noqa: E402
from repro.configs import get_config                    # noqa: E402
from repro.core.perfmodel import (MoELayerShape,        # noqa: E402
                                  speedup_table, tpu_v5e_model)
from repro.data import DataConfig, SyntheticLM          # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.optim import AdamWConfig, adamw_init         # noqa: E402
from repro.parallel.mesh import ParallelDims, make_mesh  # noqa: E402
from repro.train import make_train_step                 # noqa: E402


def measured(name):
    cfg = get_config(name).reduced(n_layers=4, d_model=256)
    mesh = make_mesh((4, 2), ("data", "model"))
    dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                  global_batch=8))
    batch = data.sharded_batch(0, mesh, dims.batch_axes)
    out = {}
    for sched in ["baseline", "auto"]:
        step = jax.jit(make_train_step(model, mesh, dims, AdamWConfig(),
                                       schedule=sched))
        jax.block_until_ready(step(params, opt, batch))
        out[sched] = time_fn(
            lambda: jax.block_until_ready(step(params, opt, batch)),
            iters=5, warmup=2)
    return cfg, out


def analytic_full(name):
    """Full-size MoE-layer speedup at N_MP=N_ESP=4 on 32 chips (paper)."""
    cfg = get_config(name)
    moe = cfg.moe
    m = tpu_v5e_model(n_ep=2, n_esp=4, n_mp=4)
    s = MoELayerShape(B=8, L=512, M=cfg.d_model, H=moe.d_ff,
                      E=moe.n_experts, k=moe.top_k, f=moe.capacity_factor,
                      n_mp=4, n_esp=4, n_ep=2)
    return speedup_table(s, m)


def main():
    for name in ["bert-moe", "gpt2-moe"]:
        cfg, t = measured(name)
        sp = t["baseline"] / t["auto"]
        emit(f"table5/{name}_measured_iter", t["baseline"] * 1e6,
             f"parm_speedup={sp:.2f}x (reduced, 8 CPU devices)")
        row = analytic_full(name)
        emit(f"table5/{name}_analytic_layer", 0.0,
             f"parm={row['speedup_parm']:.2f}x pick={row['pick']} "
             f"(paper: ~3x end-to-end)")


if __name__ == "__main__":
    main()
