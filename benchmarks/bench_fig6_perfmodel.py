"""Fig. 6 reproduction: measure collective latencies over message sizes on
a real (8 fake CPU device) mesh, least-squares fit alpha/beta per collective
(paper §V-A / §VI-B), and report the fit quality (R^2).

Run via a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(benchmarks/run.py does this automatically).
"""

from __future__ import annotations

import os


def _ensure_devices():
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 "
            + os.environ.get("XLA_FLAGS", ""))


_ensure_devices()

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
from jax.sharding import PartitionSpec as P             # noqa: E402

from benchmarks.common import emit, time_fn             # noqa: E402
from repro.core import collectives as coll              # noqa: E402
from repro.core.perfmodel import fit_alpha_beta         # noqa: E402
from repro import compat                                # noqa: E402
from repro.parallel.mesh import make_mesh               # noqa: E402

SIZES = [2 ** i for i in range(12, 21)]   # elements


def measure(mesh, make_fn, sizes=SIZES):
    times = []
    for n in sizes:
        x = jnp.zeros((64, max(n // 64, 1)), jnp.float32)
        f = jax.jit(make_fn)
        f(x).block_until_ready()
        times.append(time_fn(lambda: f(x).block_until_ready(), iters=7))
    return times


def r_squared(sizes, times, fit):
    mean = sum(times) / len(times)
    ss_tot = sum((t - mean) ** 2 for t in times)
    ss_res = sum((t - fit(x)) ** 2 for x, t in zip(sizes, times))
    return 1 - ss_res / ss_tot if ss_tot else 1.0


def main():
    mesh = make_mesh((4, 2), ("data", "model"))

    def ag_mp(x):
        return compat.shard_map(
            lambda v: coll.mp_all_gather(v, ("model",), 2, axis=0),
            mesh=mesh, in_specs=P(("data", "model"), None),
            out_specs=P(("data",), None), check_vma=False)(x)

    def a2a_ep_esp(x):
        return compat.shard_map(
            lambda v: coll.ep_esp_all_to_all(v, ("data",), ("model",)),
            mesh=mesh, in_specs=P(("data", "model"), None),
            out_specs=P(("data", "model"), None), check_vma=False)(x)

    def a2a_ep(x):
        return compat.shard_map(
            lambda v: coll.ep_all_to_all(v, ("data",)),
            mesh=mesh, in_specs=P(("data",), None),
            out_specs=P(("data",), None), check_vma=False)(x)

    for name, fn in [("ag_mp", ag_mp), ("a2a_ep_esp", a2a_ep_esp),
                     ("a2a_ep", a2a_ep)]:
        times = measure(mesh, fn)
        fit = fit_alpha_beta(SIZES, times)
        r2 = r_squared(SIZES, times, fit)
        emit(f"fig6/{name}_alpha_us", fit.alpha * 1e6, f"r2={r2:.4f}")
        emit(f"fig6/{name}_beta_ns_per_el", fit.beta * 1e9,
             f"n_sizes={len(SIZES)}")
        # the paper's claim: the linear model fits collectives well
        assert r2 > 0.8, (name, r2, times)


if __name__ == "__main__":
    main()
