"""Sweep the chunk-pipelined schedule bodies: (schedule x n_chunks) wall
time for one MoE layer, plus the analytic autoscheduler's pick.

    PYTHONPATH=src python benchmarks/bench_pipeline.py
    PYTHONPATH=src python benchmarks/bench_pipeline.py \
        --mesh distinct --chunks 1 2 4 8 --tokens 2048 --d-model 256

Runs anywhere (fake CPU devices by default; honours a pre-set XLA_FLAGS
device count).  On CPU the collectives are memcpys, so the absolute
numbers only validate that the pipelined bodies lower, run, and parity-
match — the overlap win needs real ICI/NVLink.  The same sweep on a TPU
slice is the measured counterpart of ``PerfModel.t_pipelined``; compare
the two tables to calibrate ``flops_per_s`` and the alpha-beta fits.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from benchmarks.common import time_fn                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="merged",
                    choices=["merged", "distinct"],
                    help="merged: (ep=4, model=2) with MP==ESP; distinct: "
                         "(ep=2, esp=2, mp=2)")
    ap.add_argument("--schedules", nargs="+",
                    default=["baseline", "s1", "s2", "s2h"])
    ap.add_argument("--chunks", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--tokens", type=int, default=1024)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--n-experts", type=int, default=8)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()

    from dataclasses import replace

    from repro.core import autosched
    from repro.core.moe import MoEConfig, apply_moe, init_moe_params
    from repro.core.perfmodel import MoELayerShape, tpu_v5e_model
    from repro.parallel.mesh import ParallelDims, make_mesh

    if args.mesh == "merged":
        mesh = make_mesh((4, 2), ("data", "model"))
        dims = ParallelDims(ep=("data",), esp=("model",), mp=("model",))
    else:
        mesh = make_mesh((2, 2, 2), ("ep", "esp", "mp"))
        dims = ParallelDims(ep=("ep",), esp=("esp",), mp=("mp",))
    sizes = dims.sizes(mesh)

    cfg0 = MoEConfig(d_model=args.d_model, d_ff=args.d_ff,
                     n_experts=args.n_experts, top_k=args.top_k,
                     capacity_factor=2.0, schedule="baseline")
    params = init_moe_params(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, args.tokens, args.d_model))

    print(f"# mesh={args.mesh} sizes={sizes} tokens={args.tokens} "
          f"M={args.d_model} H={args.d_ff} E={args.n_experts} "
          f"k={args.top_k}")
    print(f"{'schedule':10s} {'n_chunks':>8s} {'ms/call':>9s} "
          f"{'vs nc=1':>8s} {'max|dy|':>10s}")
    ref = {}
    for sched in args.schedules:
        base_ms = None
        for nc in args.chunks:
            cfg = replace(cfg0, pipeline_chunks=nc)
            fn = jax.jit(lambda x, p, c=cfg, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=c, schedule=s)[0])
            y = np.asarray(fn(x, params))
            err = (0.0 if sched not in ref
                   else float(np.max(np.abs(y - ref[sched]))))
            ref.setdefault(sched, y)
            dt = time_fn(lambda: fn(x, params).block_until_ready(),
                         iters=args.iters)
            ms = dt * 1e3
            base_ms = base_ms or ms
            print(f"{sched:10s} {nc:8d} {ms:9.2f} {base_ms / ms:8.2f}x "
                  f"{err:10.2e}")

    shape = MoELayerShape(
        B=1, L=args.tokens, M=args.d_model, H=args.d_ff,
        E=args.n_experts, k=args.top_k, f=2.0,
        n_mp=sizes["mp"], n_esp=sizes["esp"], n_ep=sizes["ep"])
    pm = tpu_v5e_model(sizes["ep"], sizes["esp"], sizes["mp"])
    d = autosched.decide(shape, perf_model=pm,
                         chunk_candidates=tuple(args.chunks))
    print(f"# analytic autosched pick (tpu_v5e model): "
          f"{d.schedule} x {d.n_chunks} chunks")
    for (s, n), t in d.times[:4]:
        print(f"#   predicted {s:3s} x{n}: {t * 1e3:.3f} ms")
    from repro.core.plan import plan_for_shape
    for s in args.schedules:
        # score via the plan-graph walker (t_plan) so IR-only schedules
        # like s2h are pickable too (pick_chunks knows only the legacy
        # closed forms)
        best = min(args.chunks, key=lambda n: pm.t_plan(
            plan_for_shape(s, shape, n), shape))
        print(f"#   best chunk count for {s}: {best}")


if __name__ == "__main__":
    main()
