"""Cross-version JAX API shims (jax 0.4.x <-> 0.5+/0.7+).

The repo is written against the modern spellings — ``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)`` and ``jax.shard_map(..., check_vma=...)``
— none of which exist on jax 0.4.37.  Everything that needs one of those APIs
goes through this module instead of feature-testing inline:

  * ``AxisType``  — the enum when available, else ``None``.
  * ``make_mesh`` — pins Auto axis types when the installed jax supports them
    (required for the GSPMD + shard_map mix), plain ``jax.make_mesh`` otherwise
    (0.4.x meshes are implicitly Auto, so the semantics match).
  * ``shard_map`` — resolves the top-level ``jax.shard_map`` alias, falling
    back to ``jax.experimental.shard_map.shard_map``, and translates the
    ``check_vma`` flag to the old ``check_rep`` spelling when needed.
"""

from __future__ import annotations

import inspect

import jax

try:
    from jax.sharding import AxisType          # jax >= 0.5
except ImportError:                            # pragma: no cover - jax 0.4.x
    AxisType = None


def make_mesh(shape, names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` pinned to Auto axis types where supported."""
    shape = tuple(int(s) for s in shape)
    names = tuple(names)
    if AxisType is not None:
        return jax.make_mesh(shape, names,
                             axis_types=(AxisType.Auto,) * len(names))
    return jax.make_mesh(shape, names)


_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:                         # pragma: no cover - jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with ``check_vma`` translated for older jax."""
    kwargs = {}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
