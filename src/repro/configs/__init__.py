from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    input_specs,
)
from repro.configs.registry import ASSIGNED, all_configs, get_config  # noqa: F401
