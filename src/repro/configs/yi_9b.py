"""Yi-9B: llama-arch dense GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", arch_type="dense", n_layers=48, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64000,
    rope_theta=5e6, source="arXiv:2403.04652",
    # SWA variant (window 8192) enables the long_500k shape; flagged `swa`
    # in the roofline table.  Full attention is the faithful default.
    attn_window=None)
