"""Command-R-35B: dense GQA, parallel attn||FFN blocks, no-bias LayerNorm,
tied embeddings [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", arch_type="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22528, vocab_size=256000,
    rope_theta=8e6, norm_type="layernorm", parallel_block=True,
    tie_embeddings=True, logit_scale=0.0625,
    source="hf:CohereForAI/c4ai-command-r-v01")
