"""xLSTM-350M: mLSTM + sLSTM blocks (7:1), attention-free
[arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    use_rope=False, slstm_every=8, tie_embeddings=True,
    source="arXiv:2405.04517")
