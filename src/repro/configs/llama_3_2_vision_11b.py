"""Llama-3.2-11B-Vision: language tower with gated cross-attention layers
every 5th layer; ViT frontend is a stub (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", arch_type="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256,
    rope_theta=5e5, cross_every=5, n_ctx_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision")
