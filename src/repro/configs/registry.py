"""Architecture registry: --arch <id> resolution for every launcher."""
from importlib import import_module

_MODULES = {
    "yi-9b": "repro.configs.yi_9b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0p5b",
    # paper §VI-D real-world models
    "bert-moe": "repro.configs.bert_moe",
    "gpt2-moe": "repro.configs.gpt2_moe",
}

ASSIGNED = tuple(_MODULES)[:10]


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(_MODULES[name]).CONFIG


def all_configs():
    return {name: get_config(name) for name in _MODULES}
