"""Hymba-1.5B: parallel attention + mamba heads per layer, SWA
[arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch_type="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=2.0, attn_window=1024,
    source="arXiv:2411.13676")
