"""GPT-2-MoE: the paper's §VI-D real-world model — GPT-2 (124M base) with
every other FFN replaced by an MoE layer (E=8) [paper Table V]."""
from repro.configs.base import ModelConfig
from repro.core.moe import MoEConfig

CONFIG = ModelConfig(
    name="gpt2-moe", arch_type="moe", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=50257,
    use_rope=False, norm_type="layernorm", glu=False, ffn_act="gelu",
    ffn_bias=True, qkv_bias=True, tie_embeddings=True,
    moe=MoEConfig(d_model=768, d_ff=3072, n_experts=8, top_k=2,
                  capacity_factor=1.2, glu=False, schedule="auto"),
    moe_period=2, source="paper §VI-D / Radford et al. 2019")
