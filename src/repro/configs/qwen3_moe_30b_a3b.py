"""Qwen3-30B-A3B: fine-grained MoE, 128 experts top-8, norm_topk_prob
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig
from repro.core.moe import MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=128, d_ff=768, vocab_size=151936,
    rope_theta=1e6,
    moe=MoEConfig(d_model=2048, d_ff=768, n_experts=128, top_k=8,
                  capacity_factor=1.25, normalize_topk=True,
                  schedule="auto"),
    moe_period=1, source="hf:Qwen/Qwen3-30B-A3B")
