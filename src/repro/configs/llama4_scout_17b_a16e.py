"""Llama-4-Scout-17B-16E: MoE top-1, 16 experts + 1 shared, chunked local
attention with NoPE full-attn every 4th layer (iRoPE)
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig
from repro.core.moe import MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=202048,
    rope_theta=5e5, attn_chunk=8192, chunk_every=4,
    moe=MoEConfig(d_model=5120, d_ff=8192, n_experts=16, top_k=1,
                  capacity_factor=1.25, n_shared_experts=1, schedule="auto"),
    moe_period=1, source="hf:meta-llama/Llama-4-Scout-17B-16E")
