"""Architecture config schema + input shape definitions.

Every assigned architecture is a ``ModelConfig`` instance in its own
module (src/repro/configs/<id>.py), registered in configs.registry.
The Parm-specific knobs live on the nested ``MoEConfig`` (``schedule``,
``saa_chunks``, ``pipeline_chunks``, ``autosched``, ``kernel``) and
thread from here through ``apply_moe`` into the shard_map schedule
bodies — see docs/architecture.md for the full path.
``input_specs`` builds the ShapeDtypeStruct stand-ins for the dry-run
(no device allocation), per input shape:

  train_4k     seq 4,096    global_batch 256   -> train_step
  prefill_32k  seq 32,768   global_batch 32    -> prefill (forward)
  decode_32k   seq 32,768   global_batch 128   -> serve_step (1 token + cache)
  long_500k    seq 524,288  global_batch 1     -> serve_step, sub-quadratic only
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.moe import MoEConfig
from repro.kernels.registry import KernelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    rope_theta: float = 1e6
    use_rope: bool = True
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-5
    qkv_bias: bool = False
    ffn_bias: bool = False
    ffn_act: str = "silu"
    glu: bool = True
    tie_embeddings: bool = False
    parallel_block: bool = False      # command-r: attn || ffn
    logit_scale: float = 1.0
    # attention variants
    attn_window: Optional[int] = None     # sliding-window (SWA)
    attn_chunk: Optional[int] = None      # llama4 chunked local attention
    chunk_every: int = 0                  # every k-th layer full attn (iRoPE)
    # MoE (Parm's domain)
    moe: Optional[MoEConfig] = None
    moe_period: int = 1                   # every k-th layer is MoE
    # SSM / xLSTM / hybrid
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: float = 2.0
    slstm_every: int = 0                  # xLSTM: every k-th layer is sLSTM
    # VLM
    cross_every: int = 0                  # every k-th layer cross-attends
    n_ctx_tokens: int = 0                 # image/audio context length
    # audio enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0
    # execution
    dtype: str = "float32"
    remat: bool = True
    use_pallas: bool = False              # legacy: force the pallas backend
    kernel: KernelConfig = KernelConfig()  # hot-path op backend + tiles
    cache_masked_update: bool = False   # elementwise KV write (§Perf C2 opt)
    seq_parallel: bool = False          # Megatron-SP residual stream (§Perf B2)
    context_parallel_decode: bool = False  # shard decode scores on cache dim
    source: str = ""                      # citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def kernel_cfg(self) -> KernelConfig:
        """Effective kernel config: the legacy ``use_pallas`` flag pins the
        backend when the config itself is still on ``auto``."""
        if self.use_pallas and self.kernel.backend == "auto":
            return replace(self.kernel, backend="pallas")
        return self.kernel

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid or windowed/chunked attention)."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None or self.attn_chunk is not None

    def layer_kinds(self) -> list:
        """Per-layer block kind, driving run-partitioned layer scans."""
        kinds = []
        for i in range(self.n_layers):
            if self.arch_type == "ssm":
                k = "slstm" if (self.slstm_every
                                and i % self.slstm_every == self.slstm_every - 1) \
                    else "mlstm"
            elif self.arch_type == "hybrid":
                k = "hymba"
            elif self.arch_type == "audio":
                k = "xdec"  # whisper decoder: self-attn + cross-attn + FFN
            elif self.cross_every and i % self.cross_every == self.cross_every - 1:
                k = "cross"
            elif self.moe is not None and i % self.moe_period == 0:
                k = "moe"
            else:
                k = "dense"
            # llama4 iRoPE: every chunk_every-th layer uses full (NoPE) attn
            if (self.attn_chunk and self.chunk_every
                    and i % self.chunk_every == self.chunk_every - 1
                    and k in ("dense", "moe")):
                k += "_full"
            kinds.append(k)
        return kinds

    def runs(self) -> list:
        """Consecutive same-kind layer runs: [(kind, count), ...]."""
        out = []
        for k in self.layer_kinds():
            if out and out[-1][0] == k:
                out[-1][1] += 1
            else:
                out.append([k, 1])
        return [(k, n) for k, n in out]

    def reduced(self, n_layers=2, d_model=None, n_experts=None) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, d_model or 256)
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = max(16, d // heads)
        moe = self.moe
        if moe is not None:
            e = min(moe.n_experts, n_experts or 4)
            moe = replace(moe, d_model=d, d_ff=max(32, moe.d_ff // 16),
                          n_experts=e, top_k=min(moe.top_k, e))
        return replace(
            self, name=self.name + "-smoke", n_layers=n_layers, d_model=d,
            n_heads=heads, n_kv_heads=kv, head_dim=hd,
            d_ff=max(64, min(self.d_ff, 4 * d)) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512), moe=moe,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            n_ctx_tokens=min(self.n_ctx_tokens, 16) if self.n_ctx_tokens else 0,
            attn_window=min(self.attn_window, 64) if self.attn_window else None,
            attn_chunk=min(self.attn_chunk, 64) if self.attn_chunk else None,
            cross_every=min(self.cross_every, 2) if self.cross_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            remat=False)


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    Modality frontends are stubs per the assignment carve-out: VLM image
    patches and audio frames arrive as precomputed embeddings of the
    right shape.
    """
    B, L = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, L), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, L), jnp.int32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, L), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if cfg.arch_type == "vlm":
        specs["ctx_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_ctx_tokens, cfg.d_model), f32)
    if cfg.arch_type == "audio":
        specs["ctx_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), f32)
    return specs
