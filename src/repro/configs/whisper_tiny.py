"""Whisper-tiny: encoder-decoder; mel+conv frontend is a stub (precomputed
frame embeddings, 1500 frames) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
    use_rope=False, norm_type="layernorm", glu=False, ffn_act="gelu",
    ffn_bias=True, qkv_bias=True, encoder_layers=4, encoder_seq=1500,
    tie_embeddings=True, source="arXiv:2212.04356")
