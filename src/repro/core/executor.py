"""Lower a schedule :class:`~repro.core.plan.Plan` to jax, stage by stage.

``execute`` walks the validated plan graph inside a shard_map body and
emits, for each stage kind, the exact collective / registry-kernel call
sequence the hand-written schedule bodies used — so a plan-built
schedule is numerically the legacy body it replaced (asserted per
(schedule x n_chunks x wire_dtype) against the golden copies in
``tests/helpers/legacy_bodies.py``).  All schedule-specific knowledge
lives in the plans; this module knows only how to emit one stage of
each kind.

The stage vocabulary and its lowering:

  gate          topk_gate over the stage input's token pool
  dispatch      registry ``moe_dispatch`` scatter into (E, cap, M)
  mp_split      take this rank's slice (free fwd, AllGather bwd)
  dispatch_a2a  EP AlltoAll (baseline layout) or fused EP&ESP AlltoAll
                (expert-major dump, §Perf A2); ``hier=...`` decomposes
                it into intra- + inter-group hops (s2h)
  expert_ffn    registry ``expert_ffn`` on the local expert batch
  allreduce     in-network psum over ESP (baseline partial sums)
  combine_a2a   the return AlltoAll; fused variant reduces ESP partials
                locally; ``saa=True`` runs the chunked SAA combine +
                MP-AllGather overlap; ``stack_ag=True`` appends the
                per-chunk stacked AllGather (s2/s2h capacity restore)
  ag_mp         AllGather over ESP (baseline entry, wire-exempt) or MP
                (S1 exit, wire)
  combine       registry ``moe_combine`` gather + gate-weight mix
  rs_mp         exit split (the baseline's ESP-Split)
  slice/merge   micro-chunk bookkeeping inserted by ``split_capacity``

Wire precision: stages with ``wire=True`` get the plan's stamped
``CommConfig`` and call the ``wire_*`` collective twins; everything else
calls the raw collectives (f32), reproducing the legacy bodies' exempt
set (the pre-gate AllGather feeds the router — rounding it would change
routing — and the ESP-AllReduce sums in-network with no decode point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.gating import combine, dispatch, topk_gate
from repro.core.plan import INPUT, Plan, validate
from repro.kernels.registry import get_op


def expert_ffn(xb, w1, w3, w2, info):
    """Per-expert FFN on this device's (El, t, M) batch.

    Weights are the local ESP shard (hidden dim sliced N_ESP ways), so the
    output is a *partial sum* that the caller reduces across the ESP group
    (psum in the baseline, the combine-AlltoAll's local reduction in S1/S2).
    Compute is the registry's ``expert_ffn`` op under ``info.kernel``.
    """
    op = get_op("expert_ffn", cfg=info.kernel, act=info.act)
    return op(xb, w1, w3 if info.glu else None, w2)


def _aux_mean(aux, info):
    axes = tuple(dict.fromkeys(info.ep_axes + info.esp_axes + info.mp_axes))
    return {k: (lax.pmean(v, axes) if v.ndim == 0 else v)
            for k, v in aux.items()}


def _group(info, key):
    """Resolve a logical axis key to (mesh axis names, group size)."""
    return {"ep": (info.ep_axes, info.n_ep),
            "esp": (info.esp_axes, info.n_esp),
            "mp": (info.mp_axes, info.n_mp)}[key]


def _gate_cap(info, spec: str) -> int:
    """Per-expert capacity for the token pool a gate stage sees."""
    if spec == "pool":           # the unsplit s_local pool (s2, seqpar)
        return info.cap
    if spec == "esp_pool":       # post-ESP-AllGather pool (baseline)
        return info.cap * info.n_esp
    if spec == "mp_shard":       # this MP rank's 1/N_MP slice (s1)
        return info.cap // info.n_mp
    raise ValueError(f"unknown gate cap spec {spec!r}")


class _PlacedTables:
    """Trace-time constant lookup tables for an ``ExpertPlacement``
    (tiny int32 arrays; the placement itself never enters jit)."""

    __slots__ = ("n_phys", "assign", "rep_count", "rep_index", "rep_table")

    def __init__(self, pl):
        self.n_phys = pl.n_phys
        self.assign = jnp.asarray(pl.assignments, jnp.int32)     # (R,)
        self.rep_count = jnp.asarray(pl.rep_count)               # (E,)
        self.rep_index = jnp.asarray(pl.replica_index)           # (R,)
        self.rep_table = jnp.asarray(pl.rep_table)               # (E, r*)


def _placed_flat(ctx, g, cap: int):
    """Flat physical-buffer index per (token, choice) under a placement:
    logical slot ``s`` of expert ``e`` maps round-robin to replica
    ``s % r_e`` at physical slot ``s // r_e`` — the replica-fractional
    dispatch split.  ``n_phys * cap`` is the drop sentinel.  Memoized on
    the GateResult like :meth:`GateResult.flat`."""
    key = ("placed", cap)
    if key not in g._flat:
        t = ctx.placed
        r = t.rep_count[g.expert_idx]                            # (S, k)
        phys = t.rep_table[g.expert_idx, g.slot_idx % r]
        pslot = g.slot_idx // r
        g._flat[key] = jnp.where(pslot < cap, phys * cap + pslot,
                                 t.n_phys * cap).astype(jnp.int32)
    return g._flat[key]


class _Ctx:
    __slots__ = ("info", "wg", "w1", "w3", "w2", "comm", "gate", "dtype",
                 "placement", "placed")

    def __init__(self, info, wg, w1, w3, w2, comm, dtype, placement=None):
        self.info, self.comm = info, comm
        self.wg, self.w1, self.w3, self.w2 = wg, w1, w3, w2
        self.gate = None     # (GateResult, cap) once the gate stage ran
        self.dtype = dtype   # layer-input dtype (raw-wire decode target)
        self.placement = placement
        self.placed = _PlacedTables(placement) \
            if placement is not None else None


def _emit(st, vals, ctx):
    """Lower one stage; ``vals`` are its deps' values in order."""
    info = ctx.info
    E = info.gate.n_experts
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    comm = ctx.comm if st.wire else None
    kind = st.kind

    if kind == "gate":
        cap = _gate_cap(info, st.p("cap", "pool"))
        if ctx.placed is not None:
            # placed: cap becomes the per-*physical*-slot capacity; the
            # gate keeps r_e * cap slots per logical expert (effective
            # capacity vector) so a replicated hot expert drops less
            cap = st.p("placed_cap") or ctx.placement.scaled_cap(cap)
            eff = ctx.placed.rep_count * cap                     # (E,)
            g = topk_gate(vals[0], ctx.wg, info.gate, eff)
        else:
            g = topk_gate(vals[0], ctx.wg, info.gate, cap)
        ctx.gate = (g, cap)
        return ctx.gate

    if kind == "dispatch":
        tokens, (g, cap) = vals
        if ctx.placed is not None:
            return dispatch(tokens, g.expert_idx, g.slot_idx, cap,
                            ctx.placed.n_phys, info.kernel,
                            flat=_placed_flat(ctx, g, cap))
        return dispatch(tokens, g.expert_idx, g.slot_idx, cap, E,
                        info.kernel, flat=g.flat(cap, E))

    if kind in ("mp_split", "rs_mp"):
        axes, n = _group(info, st.axes[0])
        return coll.mp_split(vals[0], axes, n, axis=st.p("axis", 0))

    if kind == "ag_mp":
        axes, n = _group(info, st.axes[0])
        axis = st.p("axis", 0)
        if st.wire:
            return coll.wire_mp_all_gather(vals[0], axes, n, comm,
                                           axis=axis)
        return coll.mp_all_gather(vals[0], axes, n, axis=axis)

    if kind == "dispatch_a2a":
        d = vals[0]
        if not st.p("fused"):
            # baseline layout: (E, c, M) -> (Ne, El, c, M) EP blocks
            # (first dim may be R physical slots under a placement)
            sb = d.reshape(Ne, d.shape[0] // Ne, d.shape[1], -1)
            rb = coll.wire_ep_all_to_all(sb, info.ep_axes, comm)
            return coll.to_expert_batch(rb)
        sb = coll.dump_em(d, Ne, Ns)                    # (El, G, c, M)
        hier = st.p("hier")
        if hier:
            rb = coll.wire_hier_ep_esp_all_to_all(
                sb, info.ep_axes, info.esp_axes, Ne, Ns, comm,
                axis=1, order=hier)
        elif st.p("raw") and coll.wire_raw_ok(comm):
            # grouped-megakernel consumer: leave the payload *encoded*
            # (f32/bf16 are plain casts) — the ragged kernel's f32 upcast
            # is the decode, so the full-buffer codec pass is elided
            rb = coll.ep_esp_all_to_all(
                coll.wire_encode(sb, comm), info.ep_axes, info.esp_axes,
                split_axis=1, concat_axis=1)
        else:
            rb = coll.wire_ep_esp_all_to_all(
                sb, info.ep_axes, info.esp_axes, comm,
                split_axis=1, concat_axis=1)
        return coll.to_expert_batch_em(rb)              # (El, G*c, M)

    if kind == "expert_ffn":
        return expert_ffn(vals[0], ctx.w1, ctx.w3, ctx.w2, info)

    if kind == "expert_ffn_grouped":
        return _emit_grouped(st, vals, ctx)

    if kind == "allreduce":
        axes, _ = _group(info, st.axes[0])
        return lax.psum(vals[0], axes)

    if kind == "combine_a2a":
        h = vals[0]
        if not st.p("fused"):
            back = coll.wire_ep_all_to_all(
                coll.from_expert_batch(h, Ne), info.ep_axes, comm)
            return back.reshape(back.shape[0] * back.shape[1],
                                back.shape[2], -1)      # (E|R, c, M)
        y4 = coll.from_expert_batch_em(h, info.combined_group)
        if st.p("saa"):
            return coll.saa_combine_allgather(
                y4, info.ep_axes, info.esp_axes, info.mp_axes,
                n_ep=Ne, n_esp=Ns, n_mp=Nm,
                n_chunks=st.p("saa_chunks", info.saa_chunks),
                comm=comm)                              # (E, c*Nm, M)
        hier = st.p("hier")
        if hier:
            back = coll.wire_hier_ep_esp_all_to_all(
                y4, info.ep_axes, info.esp_axes, Ne, Ns, comm,
                axis=1, order=hier)
        elif st.p("raw") and coll.wire_raw_ok(comm):
            # grouped-megakernel producer: the ragged kernel already cast
            # its output to the wire dtype (the encode half of the fused
            # codec); move it raw, decode once, then reduce in f32
            back = coll.wire_decode(
                coll.ep_esp_all_to_all(y4, info.ep_axes, info.esp_axes,
                                       split_axis=1, concat_axis=1),
                comm, ctx.dtype)
        else:
            back = coll.wire_ep_esp_all_to_all(
                y4, info.ep_axes, info.esp_axes, comm,
                split_axis=1, concat_axis=1)
        mine = coll.undump_reduce_em(back, Ne, Ns)      # (E|R, c, M)
        if not st.p("stack_ag"):
            return mine
        if Nm == 1:
            part = mine[:, None]                        # (E, 1, c, M)
        else:
            part = coll.wire_all_gather_stacked(
                mine, tuple(info.mp_axes), Nm, comm, axis=1)
        return part.reshape(mine.shape[0], -1, part.shape[-1])

    if kind == "combine":
        buf, (g, cap) = vals
        flat = _placed_flat(ctx, g, cap) if ctx.placed is not None \
            else g.flat(cap, E)
        return combine(buf, g.expert_idx, g.slot_idx, g.weights, cap,
                       info.kernel, flat=flat)

    if kind == "slice":
        i, n = st.p("index"), st.p("n")
        axis = st.p("axis", 1)
        cs = vals[0].shape[axis] // n
        return lax.slice_in_dim(vals[0], i * cs, (i + 1) * cs, axis=axis)

    if kind == "merge":
        axis = st.p("axis", 1)
        if st.p("mode", "concat") == "concat":
            return (vals[0] if len(vals) == 1
                    else jnp.concatenate(vals, axis=axis))
        # stack_mp: parts are (E|R, Nm*cs, M); restore the legacy
        # (mp_rank, chunk, slot) capacity order of the pre-split buffer.
        parts = [p.reshape(p.shape[0], Nm, -1, p.shape[-1]) for p in vals]
        stacked = jnp.stack(parts, axis=2)       # (E, Nm, n, cs, M)
        return stacked.reshape(stacked.shape[0], -1, stacked.shape[-1])

    raise ValueError(f"executor: unknown stage kind {kind!r}")


def _emit_grouped(st, vals, ctx):
    """Lower an ``expert_ffn_grouped`` stage (``plan.fuse_grouped``).

    Pool form (deps: the dispatch-A2A receive buffer): exchange the
    per-(expert, sender) routed-row counts over the same combined group
    — a tiny (El, G) int32 AlltoAll — and run the ragged grouped-GEMM
    kernel: token tiles beyond a group's routed count never reach the
    MXU, so compute scales with routed tokens, not capacity.  When the
    surrounding AlltoAlls run ``raw`` the buffer arrives in the wire
    dtype; the kernel's f32 upcast and output cast are the fused codec.

    Local form (``local=True``; deps: token slice + gate): one fused
    megakernel doing dispatch gather -> ragged FFN -> combine scatter +
    gate-weight mix, with the wire round-trip applied at the two pool
    boundaries.  fp8's scale-tail codec cannot fuse, so it composes the
    unfused ops around explicit :func:`collectives.wire_roundtrip`.
    """
    info = ctx.info
    E = info.gate.n_experts
    Ne, Ns = info.n_ep, info.n_esp
    comm = ctx.comm if st.wire else None

    if st.p("local"):
        tokens, (g, cap) = vals
        wd = getattr(comm, "wire_dtype", "f32") if comm is not None \
            else "f32"
        if coll.wire_raw_ok(comm):
            op = get_op("expert_ffn_grouped", cfg=info.kernel,
                        act=info.act, cap=cap, wire=wd)
            return op(tokens, g.flat(cap, E), g.weights, ctx.w1,
                      ctx.w3 if info.glu else None, ctx.w2)
        # fp8 wire: compose the unfused ops around the codec round-trip
        # (bit-identical to the pool path's encode/decode at both
        # boundaries; the FFN itself stays ragged/dropless)
        d = dispatch(tokens, g.expert_idx, g.slot_idx, cap, E,
                     info.kernel, flat=g.flat(cap, E))   # (E, cap, M)
        d = coll.wire_roundtrip(d, comm)
        cnt = jnp.minimum(g.aux["load"], cap).astype(jnp.int32)[:, None]
        op = get_op("expert_ffn_ragged", cfg=info.kernel, act=info.act)
        h = op(d.reshape(E, 1, cap, -1), cnt, ctx.w1,
               ctx.w3 if info.glu else None, ctx.w2)
        h = coll.wire_roundtrip(h.reshape(E, cap, -1), comm)
        return combine(h, g.expert_idx, g.slot_idx, g.weights, cap,
                       info.kernel, flat=g.flat(cap, E))

    h = vals[0]                                  # (El, G*c, M), maybe raw
    g, cap = ctx.gate
    G = info.combined_group
    El, Gc, M = h.shape
    c = Gc // G
    # This chunk covers capacity slots [ci*c, (ci+1)*c) of every expert;
    # GShard slots are contiguous from 0, so the chunk's routed rows per
    # expert are clip(routed - ci*c, 0, c).
    ci = st.p("chunk_index", 0)
    if ctx.placed is not None:
        # placed: rows of logical expert e land round-robin on its
        # replicas, so physical slot p (replica j of expert a_p) holds
        # ceil((routed_a - j) / r_a) rows, contiguous from 0
        t = ctx.placed
        eff = (t.rep_count * cap).astype(jnp.float32)
        routed = jnp.minimum(g.aux["load"], eff).astype(jnp.int32)
        r = t.rep_count[t.assign]
        cnt_p = jnp.clip((routed[t.assign] - t.rep_index + r - 1) // r,
                         0, cap)                                 # (R,)
        cnt = jnp.clip(cnt_p - ci * c, 0, c)
        nl = t.n_phys // Ne                      # local phys slots/rank
    else:
        routed = jnp.minimum(g.aux["load"], float(cap)).astype(jnp.int32)
        cnt = jnp.clip(routed - ci * c, 0, c)                    # (E,)
        nl = E // Ne
    # Receive-side ragged metadata: sender g' = (i', j') delivered its
    # rows for OUR local expert el, so the valid-row count of block
    # rb[el, g'] is g''s routed count for global expert i*El + el —
    # exchanged with the dump_em-layout (El, G) counts AlltoAll.
    snd = jnp.broadcast_to(cnt.reshape(Ne, nl).T[:, :, None],
                           (nl, Ne, Ns)).reshape(nl, G)
    rcv = coll.ep_esp_all_to_all(snd, info.ep_axes, info.esp_axes,
                                 split_axis=1, concat_axis=1)   # (El, G)
    op = get_op("expert_ffn_ragged", cfg=info.kernel, act=info.act)
    out = op(h.reshape(El, G, c, M), rcv, ctx.w1,
             ctx.w3 if info.glu else None, ctx.w2)
    return out.reshape(El, Gc, M)


def execute(plan: Plan, x, wg, w1, w3, w2, info):
    """Run one MoE layer under ``plan`` (shard_map side).

    Same contract as the legacy schedule bodies: ``x`` is this device's
    (S, M) token slice, returns ``(y, aux)`` with aux scalars pmean-ed
    over the full device group.  Under a placed plan
    (``plan.placement``) the expert weights must already be the placed
    physical gather ``w[placement.assignments]`` — ``apply_moe`` does
    this outside the shard_map, and its take-VJP sums replica weight
    gradients (the placement's "summed combine").
    """
    order = validate(plan)
    ctx = _Ctx(info, wg, w1, w3, w2, getattr(plan, "comm", None), x.dtype,
               placement=getattr(plan, "placement", None))
    env = {INPUT: x}
    for st in order:
        # named_scope is trace-time metadata only (names the HLO ops for
        # profilers / dumped modules); the lowered program is unchanged.
        with jax.named_scope(f"{plan.name}.{st.name}"):
            env[st.name] = _emit(st, [env[d] for d in st.deps], ctx)
    if ctx.gate is None:
        raise ValueError(f"plan {plan.name!r} has no gate stage")
    g, _ = ctx.gate
    return env[plan.output], _aux_mean(g.aux, info)


def _probe(v):
    """DCE-proof scalar fingerprint of one stage value."""
    if isinstance(v, tuple):             # gate stage: (GateResult, cap)
        return jnp.sum(v[0].weights.astype(jnp.float32))
    return jnp.sum(v.astype(jnp.float32))


def execute_prefix(plan: Plan, x, wg, w1, w3, w2, info, n_stages: int):
    """Run only the first ``n_stages`` stages of ``plan`` (topo order)
    and return a replicated scalar folding a probe of every stage
    output (so no stage is dead code).

    The obs stage-timing harness (``repro.obs.trace``) times the jitted
    prefix programs for k = 0..n and attributes ``t[k] - t[k-1]`` to
    stage k.  Validated topo order lists every stage after its deps, so
    any prefix is a closed subgraph; stateful context (the gate result)
    is always populated before a consumer runs.
    """
    order = validate(plan)
    ctx = _Ctx(info, wg, w1, w3, w2, getattr(plan, "comm", None), x.dtype,
               placement=getattr(plan, "placement", None))
    env = {INPUT: x}
    acc = jnp.sum(x.astype(jnp.float32))
    for st in order[:n_stages]:
        with jax.named_scope(f"{plan.name}.{st.name}"):
            env[st.name] = _emit(st, [env[d] for d in st.deps], ctx)
        acc = acc + _probe(env[st.name])
    axes = tuple(dict.fromkeys(info.ep_axes + info.esp_axes
                               + info.mp_axes))
    return lax.psum(acc, axes)
