"""The Parm MP+EP+ESP communication schedules, as declarative plans.

Each schedule is a ~20-line *plan builder* (see ``repro.core.plan``)
returning a stage graph; ``repro.core.executor`` lowers it inside a
shard_map body.  All schedules compute the same mathematical function
(verified against the golden legacy bodies by
``tests/test_plan_executor.py``); they differ only in where
communication happens and how much of it there is:

  baseline (Fig. 3a):  ESP-AllGather -> Gate -> EP-AlltoAll -> Experts
                       -> ESP-AllReduce -> EP-AlltoAll -> ESP-Split
  S1       (Fig. 3b):  MP-Split -> Gate -> EP&ESP-AlltoAll -> Experts
                       -> EP&ESP-AlltoAll(+Combine) -> MP-AllGather(BLM)
  S2       (Fig. 3c):  Gate -> MP-Split -> EP&ESP-AlltoAll -> Experts
                       -> SAA{EP&ESP-AlltoAll + MP-AllGather(ETM)} -> Un-dispatch
  S2H      (beyond paper, MegaScale-style): S2 with each fused AlltoAll
           decomposed into an intra-group (ESP, fast links) and an
           inter-group (EP, slow links) hop; successive capacity chunks
           run the hops in opposite orders, so one chunk's intra-node
           A2A rides in the shadow of another's inter-node A2A (Parm
           §IV's intra/inter overlap).
  S1D      (decode-dedicated, serving engine): S1 without PauseMP —
           every MP rank redundantly computes the tiny decode pool,
           trading n_mp x compute for one fewer collective.  Only ever
           scored for the inference shape class (``decode_only``).

Plus the beyond-paper ``s1_seqpar`` variant: under a sequence-parallel
activation contract the MoE boundary is already MP-split, so S1's entry
split and exit MP-AllGather disappear entirely (see EXPERIMENTS.md §Perf).

The chunk-pipelined ``*_pipe`` family and the wire-precision variants
are *generated* from these same builders by the ``split_capacity`` and
``apply_wire`` graph transforms — there is one definition per schedule,
not one per (schedule x chunking x wire) combination.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.collectives import CommConfig
from repro.core.executor import _aux_mean, execute, expert_ffn  # noqa: F401
from repro.core.gating import GateConfig
from repro.core.plan import (Plan, build_plan, fuse_grouped, register_plan,
                             stage)
from repro.kernels.registry import KernelConfig

SCHEDULES = ("baseline", "s1", "s2", "s1_seqpar", "s2h", "s1d", "s1g",
             "baseline_pipe", "s1_pipe", "s2_pipe", "s1_seqpar_pipe",
             "s2h_pipe", "s1g_pipe", "auto")


@dataclass(frozen=True)
class MoEShardInfo:
    """Static shard_map-side description of the MoE parallel layout."""
    ep_axes: tuple
    esp_axes: tuple
    mp_axes: tuple
    n_ep: int
    n_esp: int
    n_mp: int
    tokens: int          # S: tokens per device at the MoE boundary
    cap: int             # T: per-expert capacity for an S-token pool
    gate: GateConfig
    act: str = "silu"    # expert activation (registry op static)
    glu: bool = True     # SwiGLU experts (w1 gate + w3 up) vs 2-layer GELU
    saa_chunks: int = 4  # SAA pipeline depth (1 = no overlap, AAS)
    pipeline_chunks: int = 1  # micro-chunk count for the *_pipe bodies
    kernel: KernelConfig = KernelConfig()  # hot-path op backend + tiles
    comm: CommConfig = CommConfig()  # wire dtype for the collectives
    placement: object = None  # ExpertPlacement (build_plan applies it)

    @property
    def combined_group(self):
        return self.n_ep * self.n_esp


# --- plan builders -----------------------------------------------------------

@register_plan("baseline", analytic=False)   # measured-only: §IV-B
def plan_baseline(info) -> Plan:
    """DeepSpeed-MoE's schedule. In the merged (MP==ESP) production mapping
    the ESP-AllGather materializes N_MP identical token copies, and every
    expert shard then computes them all — the redundancy Parm removes.
    The pre-gate AllGather and the in-network AllReduce are wire-exempt
    (routing bit-invariance / no decode point)."""
    return Plan("baseline", base="baseline", stages=(
        stage("ag_in", "ag_mp", deps=("x",), axes=("esp",), axis=0,
              size="blm*esp"),
        stage("gate", "gate", deps=("ag_in",), cap="esp_pool"),
        stage("disp", "dispatch", deps=("ag_in", "gate")),
        stage("a2a_d", "dispatch_a2a", deps=("disp",), axes=("ep",),
              wire=True, size="etm*esp", chunk=True),
        stage("ffn", "expert_ffn", deps=("a2a_d",), chunk=True),
        stage("ar", "allreduce", deps=("ffn",), axes=("esp",),
              size="etm*esp", chunk=True),
        stage("a2a_c", "combine_a2a", deps=("ar",), axes=("ep",),
              wire=True, size="etm*esp", chunk=True),
        stage("comb", "combine", deps=("a2a_c", "gate")),
        stage("out", "rs_mp", deps=("comb",), axes=("esp",), axis=0),
    ), output="out", chunk_input="disp", chunk_output="a2a_c",
        chunk_axis=1, chunk_size=info.cap * info.n_esp)


def _plan_s1(info, *, seqpar: bool) -> Plan:
    name = "s1_seqpar" if seqpar else "s1"
    src = "x" if seqpar else "split"
    pre = () if seqpar else (
        stage("split", "mp_split", deps=("x",), axes=("mp",), axis=0),)
    post = () if seqpar else (
        stage("ag_out", "ag_mp", deps=("comb",), axes=("mp",), axis=0,
              wire=True, size="blm"),)
    return Plan(name, base=name, stages=pre + (
        stage("gate", "gate", deps=(src,),
              cap="pool" if seqpar else "mp_shard"),
        stage("disp", "dispatch", deps=(src, "gate")),
        stage("a2a_d", "dispatch_a2a", deps=("disp",), axes=("ep", "esp"),
              wire=True, size="etm*esp/mp", chunk=True, fused=True),
        stage("ffn", "expert_ffn", deps=("a2a_d",), chunk=True),
        stage("a2a_c", "combine_a2a", deps=("ffn",), axes=("ep", "esp"),
              wire=True, size="etm*esp/mp", chunk=True, fused=True),
        stage("comb", "combine", deps=("a2a_c", "gate")),
    ) + post, output="comb" if seqpar else "ag_out",
        chunk_input="disp", chunk_output="a2a_c", chunk_axis=1,
        chunk_size=info.cap if seqpar else info.cap // max(info.n_mp, 1))


@register_plan("s1")
def plan_s1(info) -> Plan:
    """PauseMP before the gate; restore with MP-AllGather(BLM) after the
    combine.  Both AlltoAlls are fused over the combined EP x ESP group."""
    return _plan_s1(info, seqpar=False)


@register_plan("s1_seqpar", analytic=False, measured=False)  # forced-only
def plan_s1_seqpar(info) -> Plan:
    """S1 under a sequence-parallel activation contract: the boundary is
    already MP-split, so the entry split and exit gather vanish."""
    return _plan_s1(info, seqpar=True)


@register_plan("s1g")
def plan_s1g(info) -> Plan:
    """S1 with the dropless ragged grouped-GEMM megakernel: the same
    stage graph as ``s1``, transformed by ``plan.fuse_grouped`` — the
    expert FFN becomes an ``expert_ffn_grouped`` stage whose compute is
    proportional to *routed* tokens (capacity padding tiles never reach
    the MXU), with the dispatch gather / combine scatter and the wire
    codec of the adjacent AlltoAlls fused into the kernel boundaries.
    On a single-member combined group with ``n_mp == 1`` the whole
    dispatch -> A2A -> FFN -> A2A -> combine chain collapses into one
    fused megakernel stage.  ``base="s1"`` keeps the cost model's
    compute term shared; ``t_plan`` adds the ragged occupancy factor."""
    local = info.combined_group == 1 and info.n_mp == 1
    p = fuse_grouped(_plan_s1(info, seqpar=False), local=local)
    return dataclasses.replace(p, name="s1g", base="s1")


def _plan_s2_like(info, name: str, a2a_extra: dict,
                  combine_extra: dict) -> Plan:
    return Plan(name, base=name, stages=(
        stage("gate", "gate", deps=("x",), cap="pool"),
        stage("disp", "dispatch", deps=("x", "gate")),
        stage("split", "mp_split", deps=("disp",), axes=("mp",), axis=1),
        stage("a2a_d", "dispatch_a2a", deps=("split",),
              axes=("ep", "esp"), wire=True, size="etm*esp/mp",
              chunk=True, fused=True, **a2a_extra),
        stage("ffn", "expert_ffn", deps=("a2a_d",), chunk=True),
        stage("a2a_c", "combine_a2a", deps=("ffn",),
              axes=("ep", "esp", "mp"), wire=True, size="etm*esp/mp",
              chunk=True, fused=True, **combine_extra),
        stage("comb", "combine", deps=("a2a_c", "gate")),
    ), output="comb", chunk_input="split", chunk_output="a2a_c",
        chunk_axis=1, chunk_size=info.cap // max(info.n_mp, 1),
        merge="stack_mp")


@register_plan("s2")
def plan_s2(info) -> Plan:
    """Gate on the full input, PauseMP on the capacity dim, and overlap
    the combine EP&ESP-AlltoAll with the MP-AllGather(ETM) via SAA.
    Under ``split_capacity`` the SAA stage collapses to depth 1 per
    chunk — the chunk itself becomes the SAA unit (the legacy
    ``s2_pipe`` decomposition)."""
    return _plan_s2_like(info, "s2", {},
                         {"saa": True, "saa_chunks": info.saa_chunks})


@register_plan("s2h")
def plan_s2h(info) -> Plan:
    """Hierarchical S2: each fused EP&ESP-AlltoAll decomposes into an
    intra-group hop over ESP (fast, intra-node links) and an inter-group
    hop over EP (slow, inter-node links) — bitwise the same data
    movement as the fused collective.  ``alt`` makes ``split_capacity``
    alternate the hop order per capacity chunk, so chunk i's intra-node
    A2A overlaps chunk i+1's inter-node A2A (MegaScale-MoE's
    bidirectional hierarchical AlltoAll; run with ``pipeline_chunks >= 2``
    to engage the overlap).  Expressible only in the IR: no legacy body
    ever carried an intra/inter decomposition."""
    hier = {"hier": "esp_first", "alt": ("esp_first", "ep_first")}
    return _plan_s2_like(info, "s2h", dict(hier),
                         dict(hier, stack_ag=True))


@register_plan("s1d", decode_only=True)
def plan_s1d(info) -> Plan:
    """Decode-dedicated schedule (serving engine): S1 with PauseMP *not*
    engaged.  A decode pool is a handful of tokens, so every MP rank
    gates the full (replicated) pool, dispatches the full capacity
    buffer through one fused EP&ESP-AlltoAll, and redundantly computes
    the expert FFN — no entry split, no exit MP-AllGather.  At training
    sizes the ``n_mp``x comm/compute blow-up makes this strictly worse
    than S1/S2 (hence ``decode_only``); at decode sizes every collective
    is alpha-dominated and dropping the AllGather wins outright — the
    regime-dependent-schedule point of the paper, cashed in for serving.
    No stage touches the MP axes, so MP ranks stay bitwise replicated;
    with ``n_mp == 1`` the graph is exactly S1's.  No chunk region:
    decode pools are too small for capacity pipelining to pay for its
    per-chunk startup (``split_capacity`` is a no-op on this plan)."""
    return Plan("s1d", base="s1d", stages=(
        stage("gate", "gate", deps=("x",), cap="pool"),
        stage("disp", "dispatch", deps=("x", "gate")),
        stage("a2a_d", "dispatch_a2a", deps=("disp",), axes=("ep", "esp"),
              wire=True, size="etm*esp", fused=True),
        stage("ffn", "expert_ffn", deps=("a2a_d",)),
        stage("a2a_c", "combine_a2a", deps=("ffn",), axes=("ep", "esp"),
              wire=True, size="etm*esp", fused=True),
        stage("comb", "combine", deps=("a2a_c", "gate")),
    ), output="comb")


# --- thin body aliases (the public schedule API) -----------------------------
# External callers keep seeing the classic ``*_body(x, wg, w1, w3, w2,
# info)`` functions and the BODY registry; each is now a plan build +
# execute.  The unchunked aliases pin n_chunks=1 (the pipelined family in
# ``repro.core.pipeline`` reads ``info.pipeline_chunks``), matching the
# legacy bodies they replaced.

def _plan_body(name, n_chunks):
    def body(x, wg, w1, w3, w2, info: MoEShardInfo):
        return execute(build_plan(name, info, n_chunks=n_chunks),
                       x, wg, w1, w3, w2, info)
    body.__name__ = f"{name}_body"
    body.__qualname__ = body.__name__
    body.__doc__ = (f"Plan-built ``{name}`` schedule body "
                    f"(see ``plan_{name}``).")
    return body


baseline_body = _plan_body("baseline", 1)
s1_body = _plan_body("s1", 1)
s2_body = _plan_body("s2", 1)
s1_seqpar_body = _plan_body("s1_seqpar", 1)
s2h_body = _plan_body("s2h", 1)
s1d_body = _plan_body("s1d", 1)
s1g_body = _plan_body("s1g", 1)

BODY = {
    "baseline": baseline_body,
    "s1": s1_body,
    "s2": s2_body,
    "s1_seqpar": s1_seqpar_body,
    "s2h": s2h_body,
    "s1d": s1d_body,
    "s1g": s1g_body,
}

# Register the chunk-pipelined variants (*_pipe) into BODY.  The import
# sits at the bottom to break the schedules <-> pipeline cycle: pipeline
# needs MoEShardInfo from this module.
from repro.core import pipeline as _pipeline  # noqa: E402,F401
