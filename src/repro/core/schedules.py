"""The three MP+EP+ESP communication schedules of the Parm paper.

Each schedule is a shard_map body operating on this device's local slice
of the MoE-layer input tokens.  All three compute the same mathematical
function (verified by tests/test_moe_schedules.py); they differ only in
where communication happens and how much of it there is:

  baseline (Fig. 3a):  ESP-AllGather -> Gate -> EP-AlltoAll -> Experts
                       -> ESP-AllReduce -> EP-AlltoAll -> ESP-Split
  S1       (Fig. 3b):  MP-Split -> Gate -> EP&ESP-AlltoAll -> Experts
                       -> EP&ESP-AlltoAll(+Combine) -> MP-AllGather(BLM)
  S2       (Fig. 3c):  Gate -> MP-Split -> EP&ESP-AlltoAll -> Experts
                       -> SAA{EP&ESP-AlltoAll + MP-AllGather(ETM)} -> Un-dispatch

Plus a beyond-paper ``s1_seqpar`` variant: under a sequence-parallel
activation contract the MoE boundary is already MP-split, so S1's final
MP-AllGather disappears entirely (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from dataclasses import dataclass

from jax import lax

from repro.core import collectives as coll
from repro.core.collectives import CommConfig
from repro.core.gating import GateConfig, combine, dispatch, topk_gate
from repro.kernels.registry import KernelConfig, get_op

SCHEDULES = ("baseline", "s1", "s2", "s1_seqpar",
             "baseline_pipe", "s1_pipe", "s2_pipe", "s1_seqpar_pipe",
             "auto")


@dataclass(frozen=True)
class MoEShardInfo:
    """Static shard_map-side description of the MoE parallel layout."""
    ep_axes: tuple
    esp_axes: tuple
    mp_axes: tuple
    n_ep: int
    n_esp: int
    n_mp: int
    tokens: int          # S: tokens per device at the MoE boundary
    cap: int             # T: per-expert capacity for an S-token pool
    gate: GateConfig
    act: str = "silu"    # expert activation (registry op static)
    glu: bool = True     # SwiGLU experts (w1 gate + w3 up) vs 2-layer GELU
    saa_chunks: int = 4  # SAA pipeline depth (1 = no overlap, AAS)
    pipeline_chunks: int = 1  # micro-chunk count for the *_pipe bodies
    kernel: KernelConfig = KernelConfig()  # hot-path op backend + tiles
    comm: CommConfig = CommConfig()  # wire dtype for the collectives

    @property
    def combined_group(self):
        return self.n_ep * self.n_esp


def expert_ffn(xb, w1, w3, w2, info: MoEShardInfo):
    """Per-expert FFN on this device's (El, t, M) batch.

    Weights are the local ESP shard (hidden dim sliced N_ESP ways), so the
    output is a *partial sum* that the caller reduces across the ESP group
    (psum in the baseline, the combine-AlltoAll's local reduction in S1/S2).
    Compute is the registry's ``expert_ffn`` op under ``info.kernel``.
    """
    op = get_op("expert_ffn", cfg=info.kernel, act=info.act)
    return op(xb, w1, w3 if info.glu else None, w2)


def _aux_mean(aux, info):
    axes = tuple(dict.fromkeys(info.ep_axes + info.esp_axes + info.mp_axes))
    return {k: (lax.pmean(v, axes) if v.ndim == 0 else v)
            for k, v in aux.items()}


# --- baseline ----------------------------------------------------------------

def baseline_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    """DeepSpeed-MoE's schedule. In the merged (MP==ESP) production mapping
    the ESP-AllGather materializes N_MP identical token copies, and every
    expert shard then computes them all — the redundancy Parm removes."""
    Ne, Ns = info.n_ep, info.n_esp
    E = info.gate.n_experts
    # ESP-AllGather of the raw input (cost AG(B*L*M*N_ESP), Eq. 1).
    # Deliberately NOT wire-compressed: it feeds the gate, and wire
    # rounding pre-gate tokens would change routing decisions.
    g = coll.mp_all_gather(x, info.esp_axes, Ns, axis=0)       # (S*Ns, M)
    cap_g = info.cap * Ns
    gate = topk_gate(g, wg, info.gate, cap_g)
    eidx, slot, w, aux = gate
    d = dispatch(g, eidx, slot, cap_g, E, info.kernel,
                 flat=gate.flat(cap_g, E))                     # (E, T*Ns, M)
    # EP-AlltoAll dispatch (cost A2A(E*T*M*N_ESP), wire dtype).
    sb = d.reshape(Ne, E // Ne, cap_g, -1)
    rb = coll.wire_ep_all_to_all(sb, info.ep_axes, info.comm)  # (Ne, El, T*Ns, M)
    xb = coll.to_expert_batch(rb)                              # (El, Ne*T*Ns, M)
    h = expert_ffn(xb, w1, w3, w2, info)
    # ESP-AllReduce of partial sums (cost AR(E*T*M*N_ESP)).  In-network
    # arithmetic: no decode point, so it stays at compute width.
    h = lax.psum(h, info.esp_axes)
    # EP-AlltoAll combine (cost A2A(E*T*M*N_ESP), wire dtype).
    back = coll.wire_ep_all_to_all(coll.from_expert_batch(h, Ne),
                                   info.ep_axes, info.comm)
    out = combine(back.reshape(E, cap_g, -1), eidx, slot, w, cap_g,
                  info.kernel, flat=gate.flat(cap_g, E))
    # ESP-Split: free forward, AllGather in backward (paper Fig. 3 note).
    y = coll.mp_split(out, info.esp_axes, Ns, axis=0)          # (S, M)
    return y, _aux_mean(aux, info)


# --- S1 ----------------------------------------------------------------------

def s1_body(x, wg, w1, w3, w2, info: MoEShardInfo, *, seqpar: bool = False):
    """PauseMP before the gate; restore with MP-AllGather(B*L*M) after the
    combine.  With ``seqpar=True`` the boundary contract is already
    MP-split, so both the entry split and the exit gather vanish."""
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    xs = x if seqpar else coll.mp_split(x, info.mp_axes, Nm, axis=0)
    # Under the seqpar contract info.tokens/info.cap already describe the
    # MP-split pool; otherwise the per-shard capacity is T / N_MP.
    c1 = info.cap if seqpar else info.cap // Nm
    gate = topk_gate(xs, wg, info.gate, c1)
    eidx, slot, w, aux = gate
    d = dispatch(xs, eidx, slot, c1, E, info.kernel,
                 flat=gate.flat(c1, E))                        # (E, T/Nm, M)
    # EP&ESP-AlltoAll dispatch (Dump + fused AlltoAll; cost A2A(ETM*Ns/Nm),
    # wire dtype).  Expert-major (El, G, c, M) buffers: the expert-batch
    # view is a free reshape instead of a full-buffer relayout (§Perf A2).
    sb = coll.dump_em(d, Ne, Ns)                               # (El, G, c1, M)
    rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                     info.comm, split_axis=1,
                                     concat_axis=1)
    xb = coll.to_expert_batch_em(rb)                           # (El, G*c1, M)
    h = expert_ffn(xb, w1, w3, w2, info)
    # EP&ESP-AlltoAll combine + local ESP reduction (cost A2A(ETM*Ns/Nm),
    # wire dtype; the ESP partial-sum reduction happens after decode).
    back = coll.wire_ep_esp_all_to_all(
        coll.from_expert_batch_em(h, info.combined_group),
        info.ep_axes, info.esp_axes, info.comm, split_axis=1,
        concat_axis=1)
    mine = coll.undump_reduce_em(back, Ne, Ns)                 # (E, c1, M)
    y = combine(mine, eidx, slot, w, c1, info.kernel,
                flat=gate.flat(c1, E))                         # (S/Nm, M)
    if not seqpar:
        # MP-AllGather to restore the replicated contract (cost AG(BLM),
        # wire dtype — post-combine outputs, routing already done).
        y = coll.wire_mp_all_gather(y, info.mp_axes, Nm, info.comm, axis=0)
    return y, _aux_mean(aux, info)


# --- S2 ----------------------------------------------------------------------

def s2_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    """Gate on the full input, PauseMP on the capacity dim, and overlap the
    combine EP&ESP-AlltoAll with the MP-AllGather(ETM) via SAA."""
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    gate = topk_gate(x, wg, info.gate, info.cap)
    eidx, slot, w, aux = gate
    d = dispatch(x, eidx, slot, info.cap, E, info.kernel,
                 flat=gate.flat(info.cap, E))                  # (E, T, M)
    ds = coll.mp_split(d, info.mp_axes, Nm, axis=1)            # (E, T/Nm, M)
    sb = coll.dump_em(ds, Ne, Ns)                              # (El, G, c, M)
    rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                     info.comm, split_axis=1,
                                     concat_axis=1)
    xb = coll.to_expert_batch_em(rb)
    h = expert_ffn(xb, w1, w3, w2, info)
    y4 = coll.from_expert_batch_em(h, info.combined_group)     # (El, G, T/Nm, M)
    # SAA: combine-AlltoAll chunks overlapped with MP-AllGather (Fig. 5),
    # every chunk of both collectives in the wire dtype.
    full = coll.saa_combine_allgather(
        y4, info.ep_axes, info.esp_axes, info.mp_axes,
        n_ep=Ne, n_esp=Ns, n_mp=Nm, n_chunks=info.saa_chunks,
        comm=info.comm)                                        # (E, T, M)
    y = combine(full, eidx, slot, w, info.cap, info.kernel,
                flat=gate.flat(info.cap, E))                   # (S, M)
    return y, _aux_mean(aux, info)


BODY = {
    "baseline": baseline_body,
    "s1": s1_body,
    "s2": s2_body,
    "s1_seqpar": lambda *a, **k: s1_body(*a, seqpar=True, **k),
}

# Register the chunk-pipelined variants (*_pipe) into BODY.  The import
# sits at the bottom to break the schedules <-> pipeline cycle: pipeline
# needs MoEShardInfo/expert_ffn/_aux_mean from this module.
from repro.core import pipeline as _pipeline  # noqa: E402,F401
