"""The schedule-plan IR: Parm's schedule space as *data*, not code.

PR 2 and PR 3 multiplied the hand-written schedule bodies: four base
schedules x {unchunked, pipelined} x wire dtypes, each separately
threading ``flat_slots`` caching, ``CommConfig`` encoding and aux-loss
plumbing.  FSMoE (arXiv:2501.10714) models an MoE layer as a graph of
schedulable comm/compute *tasks* precisely because that makes new
schedules cheap; this module is that graph.

A :class:`Plan` is a tuple of :class:`Stage` nodes — ``gate``,
``dispatch_a2a``, ``ag_mp``, ``expert_ffn``, ``combine_a2a``,
``allreduce``, ... — with explicit data deps (stage names), logical axis
groups (``"ep"``/``"esp"``/``"mp"``, resolved to mesh axis names at
execution), and wire annotations.  Three consumers walk the same graph:

  * ``repro.core.executor`` lowers a plan to jax inside a shard_map
    body, emitting the identical ``wire_*`` collectives and registry
    kernels the hand-written bodies used (exact-parity-tested against
    the golden legacy bodies in ``tests/helpers/legacy_bodies.py``);
  * ``PerfModel.t_plan`` walks it to predict the layer time (one cost
    model source of truth — no per-schedule closed form to keep in sync);
  * ``launch/dryrun.py --dump-plan`` serializes it for debugging.

Axes of the schedule space are *graph transforms*, not new bodies:
:func:`split_capacity` turns any plan into its chunk-pipelined variant
(PR 2's ``*_pipe`` family, generated), :func:`apply_wire` stamps the
collective payload dtype (PR 3's wire family, generated).  New schedules
register a ~20-line builder with :func:`register_plan` and are
automatically part of the autoscheduler's candidate grid.

The doctest examples run under
``python -m doctest src/repro/core/plan.py``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

#: Stage kinds the executor and the cost model understand.
KINDS = (
    "gate",          # top-k routing over a token pool -> GateResult
    "dispatch",      # local scatter into the (E, cap, M) capacity buffer
    "mp_split",      # take this rank's 1/N slice (free fwd, AG bwd)
    "dispatch_a2a",  # EP (plain) or EP&ESP (fused) AlltoAll, token-bound
    "expert_ffn",    # per-expert FFN through the kernel registry
    "expert_ffn_grouped",  # ragged grouped-GEMM megakernel (fuse_grouped)
    "allreduce",     # in-network partial-sum reduction (baseline ESP)
    "combine_a2a",   # return AlltoAll (+ local ESP reduce / SAA / hier)
    "ag_mp",         # AllGather over an MP-like group
    "combine",       # local gather + gate-weight mix back to token order
    "rs_mp",         # exit split (reduce-scatter-shaped: free fwd, AG bwd)
    "slice",         # capacity-dim micro-chunk slice (split_capacity)
    "merge",         # chunk reassembly (split_capacity)
)

#: Logical axis groups a stage may communicate over.
AXIS_KEYS = ("ep", "esp", "mp")

#: Payload-size symbols (paper Table I terms) for ``PerfModel.t_plan``.
SIZES = ("blm", "etm", "blm*esp", "etm*esp", "etm*esp/mp")

#: Reserved environment name for the layer input.
INPUT = "x"


@dataclass(frozen=True)
class Stage:
    """One node of a schedule plan.

    ``deps`` name producer stages (``"x"`` is the layer input); ``axes``
    are logical group keys from :data:`AXIS_KEYS` (the executor resolves
    them to mesh axis names via ``MoEShardInfo``); ``wire=True`` lets
    :func:`apply_wire` put this stage's payload on the fabric in the
    plan's wire dtype; ``size`` is the payload symbol ``t_plan`` charges;
    ``chunk=True`` marks the stage as part of the :func:`split_capacity`
    region.  ``params`` holds static kind-specific knobs as a sorted
    tuple of pairs (kept hashable); read them with :meth:`p`.
    """

    name: str
    kind: str
    deps: tuple = ()
    axes: tuple = ()
    wire: bool = False
    size: str = ""
    chunk: bool = False
    params: tuple = ()

    def p(self, key: str, default=None):
        """Kind-specific param lookup.

        >>> stage("s", "gate", deps=("x",), cap="pool").p("cap")
        'pool'
        """
        for k, v in self.params:
            if k == key:
                return v
        return default

    def with_params(self, **kw) -> "Stage":
        """Copy of this stage with ``kw`` merged into ``params``."""
        d = dict(self.params)
        d.update(kw)
        return dataclasses.replace(self, params=tuple(sorted(d.items())))


def stage(name: str, kind: str, deps=(), *, axes=(), wire=False, size="",
          chunk=False, **params) -> Stage:
    """Convenience constructor packing ``**params`` into the sorted
    tuple form :class:`Stage` stores.

    >>> stage("g", "gate", deps=("x",), cap="pool").kind
    'gate'
    """
    return Stage(name=name, kind=kind, deps=tuple(deps), axes=tuple(axes),
                 wire=wire, size=size, chunk=chunk,
                 params=tuple(sorted(params.items())))


@dataclass(frozen=True)
class Plan:
    """A full schedule as a stage graph plus its transform metadata.

    ``base`` is the underlying paper schedule (for the cost model's
    compute term — the baseline redundantly computes all MP copies);
    ``output`` names the stage whose value is the layer output.
    ``chunk_input``/``chunk_output``/``chunk_axis``/``chunk_size``/
    ``merge`` describe the :func:`split_capacity` region; ``n_chunks``,
    ``comm`` and ``placement`` record what transforms have been applied.
    """

    name: str
    stages: tuple
    output: str
    base: str = ""
    n_chunks: int = 1
    comm: object = None          # CommConfig once apply_wire has run
    chunk_input: str = ""        # stage whose output the region slices
    chunk_output: str = ""       # region stage feeding the merge
    chunk_axis: int = 1
    chunk_size: int = 0          # capacity-dim size (for chunk clamping)
    merge: str = "concat"        # "concat" | "stack_mp"
    placement: object = None     # ExpertPlacement once apply_placement ran

    def stage_names(self):
        return tuple(s.name for s in self.stages)

    def find(self, name: str) -> Stage:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(name)


class PlanError(ValueError):
    """A malformed plan: cycle, dangling dep, bad kind/axis/param."""


def validate(plan: Plan):
    """Check a plan and return its stages in a stable topological order.

    Rejects duplicate or reserved stage names, unknown kinds, axis keys
    outside :data:`AXIS_KEYS`, dangling deps, a missing output stage,
    and dependency cycles (Kahn's algorithm; ties resolve in listed
    order, which is also the order the executor emits ops in).

    >>> p = Plan("t", (stage("a", "gate", deps=("x",)),), output="a")
    >>> [s.name for s in validate(p)]
    ['a']
    >>> bad = Plan("t", (stage("a", "gate", deps=("b",)),
    ...                  stage("b", "dispatch", deps=("a",))), output="a")
    >>> try:
    ...     validate(bad)
    ... except PlanError as e:
    ...     print(e)
    plan 't': dependency cycle through ['a', 'b']
    """
    names = [s.name for s in plan.stages]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise PlanError(f"plan {plan.name!r}: duplicate stage names {dupes}")
    if INPUT in names:
        raise PlanError(f"plan {plan.name!r}: stage name {INPUT!r} is "
                        "reserved for the layer input")
    known = set(names)
    for s in plan.stages:
        if s.kind not in KINDS:
            raise PlanError(f"plan {plan.name!r}: stage {s.name!r} has "
                            f"unknown kind {s.kind!r} (want one of {KINDS})")
        for ax in s.axes:
            if ax not in AXIS_KEYS:
                raise PlanError(
                    f"plan {plan.name!r}: stage {s.name!r} names bad axis "
                    f"{ax!r} (want one of {AXIS_KEYS})")
        if s.size and s.size not in SIZES:
            # an unknown symbol would silently price the collective at
            # zero bandwidth in PerfModel.t_plan, skewing autosched
            raise PlanError(
                f"plan {plan.name!r}: stage {s.name!r} has unknown size "
                f"symbol {s.size!r} (want one of {SIZES})")
        for d in s.deps:
            if d != INPUT and d not in known:
                raise PlanError(f"plan {plan.name!r}: stage {s.name!r} "
                                f"depends on undefined stage {d!r}")
    if plan.output not in known:
        raise PlanError(f"plan {plan.name!r}: output stage "
                        f"{plan.output!r} is not defined")
    # Kahn's algorithm, preferring listed order among ready stages so the
    # executor's op order is deterministic and matches the builders'.
    by_name = {s.name: s for s in plan.stages}
    indeg = {n: sum(1 for d in by_name[n].deps if d != INPUT)
             for n in names}
    dependents: dict = {n: [] for n in names}
    for s in plan.stages:
        for d in s.deps:
            if d != INPUT:
                dependents[d].append(s.name)
    order, ready = [], [n for n in names if indeg[n] == 0]
    while ready:
        n = ready.pop(0)
        order.append(n)
        for m in dependents[n]:
            indeg[m] -= 1
            if indeg[m] == 0:
                ready.append(m)
        ready.sort(key=names.index)
    if len(order) != len(names):
        cyc = sorted(set(names) - set(order), key=names.index)
        raise PlanError(f"plan {plan.name!r}: dependency cycle through "
                        f"{cyc}")
    return tuple(by_name[n] for n in order)


# --- graph transforms --------------------------------------------------------

def clamp_chunks(cap: int, want: int) -> int:
    """Largest divisor of ``cap`` that is <= ``want`` (and >= 1).

    >>> clamp_chunks(16, 5), clamp_chunks(7, 2), clamp_chunks(12, 0)
    (4, 1, 1)
    """
    n = max(1, min(want, cap))
    while cap % n:
        n -= 1
    return n


def split_capacity(plan: Plan, n_chunks: int, *, clamp: bool = True) -> Plan:
    """Chunk-pipeline transform: replicate the plan's chunkable region
    ``n_chunks`` times over capacity-dim micro-chunks.

    Each clone gets its own ``slice`` entry node and a remapped dep set,
    so the chunks are independent subgraphs in HLO — XLA's async
    collective scheduler overlaps chunk i+1's communication with chunk
    i's FFN, which is exactly what the hand-written ``*_pipe`` bodies
    used to spell out.  A ``merge`` node reassembles the parts
    (``plan.merge`` mode).  Stages may declare chunk-dependent params:

      * ``alt=(v0, v1, ...)`` alternates the stage's ``hier`` hop order
        per chunk (the s2h intra/inter overlap);
      * an SAA combine collapses to depth 1 inside a chunk (the chunk
        itself *is* the SAA unit — same decomposition, one level up).

    ``n_chunks`` clamps to the largest divisor of ``plan.chunk_size``
    unless ``clamp=False`` (the cost model scores unclamped grids, same
    as the legacy ``t_pipelined``).  ``n_chunks <= 1`` or a plan with no
    chunk region returns the plan unchanged.
    """
    chunked = [s for s in plan.stages if s.chunk]
    n = max(1, n_chunks)
    if clamp and plan.chunk_size:
        n = clamp_chunks(plan.chunk_size, n)
    if n <= 1 or not chunked:
        return dataclasses.replace(plan, n_chunks=1)
    if not plan.chunk_input or not plan.chunk_output:
        raise PlanError(f"plan {plan.name!r}: chunk stages but no "
                        "chunk_input/chunk_output region declared")
    names = [s.name for s in plan.stages]
    first = min(names.index(s.name) for s in chunked)
    last = max(names.index(s.name) for s in chunked)
    if any(not s.chunk for s in plan.stages[first:last + 1]):
        raise PlanError(f"plan {plan.name!r}: chunk region must be "
                        "contiguous in stage order")
    region = {s.name for s in chunked}
    pre, post = plan.stages[:first], plan.stages[last + 1:]
    for s in post:
        bad = [d for d in s.deps if d in region and d != plan.chunk_output]
        if bad:
            raise PlanError(
                f"plan {plan.name!r}: stage {s.name!r} depends on chunk-"
                f"internal stage(s) {bad}; only {plan.chunk_output!r} is "
                "visible after the merge")

    out = list(pre)
    for i in range(n):
        out.append(stage(f"chunk{i}/slice", "slice",
                         deps=(plan.chunk_input,), chunk=True,
                         index=i, n=n, axis=plan.chunk_axis,
                         chunk_index=i))
        for s in chunked:
            deps = tuple(
                f"chunk{i}/slice" if d == plan.chunk_input
                else (f"{d}@{i}" if d in region else d)
                for d in s.deps)
            c = dataclasses.replace(s, name=f"{s.name}@{i}", deps=deps)
            c = c.with_params(chunk_index=i)
            alt = s.p("alt")
            if alt:
                c = c.with_params(hier=alt[i % len(alt)])
            if s.kind == "combine_a2a" and s.p("saa"):
                c = c.with_params(saa_chunks=1)
            out.append(c)
    out.append(stage("merge", "merge",
                     deps=tuple(f"{plan.chunk_output}@{i}"
                                for i in range(n)),
                     mode=plan.merge, axis=plan.chunk_axis))
    for s in post:
        deps = tuple("merge" if d == plan.chunk_output else d
                     for d in s.deps)
        out.append(dataclasses.replace(s, deps=deps))
    output = "merge" if plan.output == plan.chunk_output else plan.output
    return dataclasses.replace(plan, stages=tuple(out), n_chunks=n,
                               output=output)


def apply_wire(plan: Plan, comm) -> Plan:
    """Wire-precision transform: stamp the collective payload format.

    Stages with ``wire=True`` will ship their payload in
    ``comm.wire_dtype`` (the executor passes ``comm`` to the ``wire_*``
    collective twins); wire-exempt stages (the baseline's pre-gate
    AllGather and in-network AllReduce) are untouched.  ``comm`` must be
    concrete — ``"auto"`` is resolved by ``autosched.decide`` before any
    plan executes.
    """
    if comm is not None and getattr(comm, "wire_dtype", "f32") == "auto":
        raise PlanError("apply_wire needs a concrete wire dtype; resolve "
                        "CommConfig.wire_dtype='auto' via autosched first")
    return dataclasses.replace(plan, comm=comm)


def apply_placement(plan: Plan, placement, *, info=None) -> Plan:
    """Expert-placement transform: remap the dispatch/combine A2A stages
    onto a (possibly replicated) physical expert layout and stamp the
    shrunk per-rank capacity.

    ``placement`` is an ``ExpertPlacement`` (``None`` returns the plan
    unchanged).  The transform

      * stamps the gate stage with ``placed_cap`` — the per-physical-slot
        capacity derived from this plan's gate-pool spec via
        ``placement.scaled_cap`` (aligned to ``lcm(8, n_mp)`` when an
        ``mp_split`` on the capacity dim follows, so the s2 family's
        1/N_MP slices stay exact);
      * marks the dispatch/combine and A2A stages ``placed=True`` (the
        executor derives buffer geometry from the physical slot count,
        splits each logical expert's traffic across its replicas
        round-robin by capacity slot, and gathers each token back from
        the one replica that computed it — the replica-fractional
        dispatch / summed combine);
      * rescales ``chunk_size`` so :func:`split_capacity` keeps slicing
        the placed buffer exactly.

    Composes with :func:`split_capacity` (apply placement *first*: the
    chunk clones inherit the stamped params), :func:`apply_wire`, and
    the pool form of :func:`fuse_grouped`.  The local fused megakernel
    (single-rank EP) has nothing to remap and is rejected.
    """
    if placement is None:
        return plan
    gate = next((s for s in plan.stages if s.kind == "gate"), None)
    if gate is None:
        raise PlanError(f"plan {plan.name!r}: apply_placement needs a "
                        "gate stage")
    if any(s.p("local") for s in plan.stages
           if s.kind == "expert_ffn_grouped"):
        raise PlanError(
            f"plan {plan.name!r}: placement does not compose with the "
            "local fused megakernel (single-rank EP has nothing to remap)")
    n_mp = max(int(getattr(info, "n_mp", 1) or 1), 1) if info else 1
    n_esp = max(int(getattr(info, "n_esp", 1) or 1), 1) if info else 1
    cap = int(getattr(info, "cap", 0) or 0) if info else 0
    spec = gate.p("cap", "pool")
    logical = {"pool": cap, "esp_pool": cap * n_esp,
               "mp_shard": cap // n_mp}[spec]
    # s2-family plans mp_split the dispatch buffer's capacity dim *after*
    # the gate: the placed pool cap must stay divisible by n_mp and the
    # chunk region slices the 1/N_MP shard.
    pool_split = any(s.kind == "mp_split" and s.p("axis", 0) == 1
                     for s in plan.stages)
    align = (8 * n_mp // math.gcd(8, n_mp)) if pool_split else 8
    placed_cap = placement.scaled_cap(logical, align=align) if logical \
        else 0
    stages = []
    for s in plan.stages:
        if s.kind == "gate":
            s = s.with_params(placed_cap=placed_cap)
        elif s.kind in ("dispatch", "combine", "dispatch_a2a",
                        "combine_a2a", "expert_ffn_grouped"):
            s = s.with_params(placed=True)
        stages.append(s)
    chunk_size = plan.chunk_size
    if chunk_size and placed_cap:
        chunk_size = placed_cap // n_mp if pool_split else placed_cap
    return dataclasses.replace(plan, stages=tuple(stages),
                               placement=placement, chunk_size=chunk_size)


def fuse_grouped(plan: Plan, *, local: bool = False) -> Plan:
    """Grouped-megakernel transform: route the plan's expert FFN through
    the dropless ragged grouped-GEMM kernel, absorbing the adjacent
    dispatch/combine/wire work into the kernel's prologue/epilogue.

    ``local=False`` (the multi-device pool form) swaps the ``expert_ffn``
    stage's kind to ``expert_ffn_grouped`` — the executor feeds it the
    dispatch-AlltoAll receive buffer plus exchanged per-(expert, sender)
    routed-row counts, so capacity padding tiles are predicated off the
    MXU — and stamps ``raw=True`` on the adjacent fused AlltoAll stages:
    for plain-cast wire dtypes (f32/bf16) the payload stays *encoded*
    across the kernel boundary (the kernel's f32 upcast is the decode,
    its output cast the encode), eliding two full-buffer codec passes.
    fp8's scale-tail payload cannot cross the boundary raw; the executor
    falls back to the decoded path at run time (``raw`` is advisory).

    ``local=True`` (single-member combined group, ``n_mp == 1``)
    collapses dispatch -> AlltoAll -> FFN -> AlltoAll -> combine into
    ONE ``expert_ffn_grouped`` stage: the fused megakernel gathers
    routed token rows in its prologue and scatter-adds the gate-weighted
    outputs in its epilogue — no (E*cap, M) intermediates in HBM.  The
    fused stage reuses the combine stage's name so downstream deps need
    no rewiring, and the chunk region is dissolved (``split_capacity``
    becomes a no-op: there is no standalone AlltoAll left to overlap).
    """
    ffn = next((s for s in plan.stages if s.kind == "expert_ffn"), None)
    if ffn is None:
        raise PlanError(f"plan {plan.name!r}: fuse_grouped needs an "
                        "expert_ffn stage")
    if not local:
        out = []
        for s in plan.stages:
            if s.name == ffn.name:
                s = dataclasses.replace(s, kind="expert_ffn_grouped")
            elif (s.kind in ("dispatch_a2a", "combine_a2a")
                    and s.p("fused") and not s.p("saa")
                    and not s.p("hier")
                    and (ffn.name in s.deps or s.name in ffn.deps)):
                s = s.with_params(raw=True)
            out.append(s)
        return dataclasses.replace(plan, stages=tuple(out))
    gate = next(s for s in plan.stages if s.kind == "gate")
    disp = next(s for s in plan.stages if s.kind == "dispatch")
    comb = next(s for s in plan.stages if s.kind == "combine")
    region = {disp.name, comb.name} | {
        s.name for s in plan.stages
        if s.kind in ("dispatch_a2a", "expert_ffn", "combine_a2a")}
    token_src = next(d for d in disp.deps if d != gate.name)
    fused = stage(comb.name, "expert_ffn_grouped",
                  deps=(token_src, gate.name), wire=True, local=True)
    out = tuple(fused if s.name == comb.name else s
                for s in plan.stages
                if s.name not in region - {comb.name})
    return dataclasses.replace(plan, stages=out, chunk_input="",
                               chunk_output="", chunk_size=0)


# --- the plan registry -------------------------------------------------------

@dataclass(frozen=True)
class PlanEntry:
    """One registered schedule: its builder plus autosched eligibility.

    ``analytic``/``measured`` gate which decision grids enumerate it
    (``s1_seqpar`` is neither: it needs the sequence-parallel activation
    contract, so it is only ever forced; ``baseline`` is measured-only —
    Algorithm 1 proves S1/S2 dominate it analytically, §IV-B).
    ``decode_only`` marks decode-dedicated schedules (``s1d``): they are
    enumerated only for the *inference* shape class — decode pools are a
    handful of tokens, where trading redundant MP compute for one fewer
    collective wins, which is never true at training sizes.
    """

    builder: Callable
    analytic: bool = True
    measured: bool = True
    decode_only: bool = False


PLANS: dict = {}


def register_plan(name: str, builder: Optional[Callable] = None, *,
                  analytic: bool = True, measured: bool = True,
                  decode_only: bool = False):
    """Register a schedule plan builder (usable as a decorator).

    ``builder(info) -> Plan`` takes the ``MoEShardInfo`` (or any object
    with the same static fields) and returns the *unchunked, unwired*
    base plan.  Registration makes the schedule selectable by name and —
    per its flags — part of the autoscheduler's candidate grids
    (``decode_only=True`` restricts it to the decode grids).
    """
    def deco(fn):
        PLANS[name] = PlanEntry(builder=fn, analytic=analytic,
                                measured=measured, decode_only=decode_only)
        return fn
    return deco if builder is None else deco(builder)


def analytic_schedules(infer: bool = False) -> tuple:
    """Registered schedules the analytic decision grid enumerates.
    ``infer=True`` is the decode grid: it adds the decode-dedicated
    plans the training grid never scores."""
    return tuple(n for n, e in PLANS.items()
                 if e.analytic and (infer or not e.decode_only))


def measured_schedules(infer: bool = False) -> tuple:
    """Registered schedules the measured decision grid enumerates
    (``infer=True``: the decode grid, incl. decode-only plans)."""
    return tuple(n for n, e in PLANS.items()
                 if e.measured and (infer or not e.decode_only))


def build_plan(name: str, info, n_chunks: Optional[int] = None) -> Plan:
    """Build the executable plan for one schedule on one layer layout:
    base plan -> :func:`apply_placement` (from ``info.placement``) ->
    :func:`split_capacity` (clamped) -> :func:`apply_wire`.

    ``n_chunks`` defaults to ``info.pipeline_chunks``; pass ``1`` for
    the always-unchunked public body aliases.
    """
    if name not in PLANS:
        raise KeyError(f"no plan registered for schedule {name!r} "
                       f"(have {sorted(PLANS)})")
    base = PLANS[name].builder(info)
    pl = getattr(info, "placement", None)
    if pl is not None:
        base = apply_placement(base, pl, info=info)
    want = info.pipeline_chunks if n_chunks is None else n_chunks
    p = split_capacity(base, want)
    return apply_wire(p, getattr(info, "comm", None))


def plan_for_shape(name: str, shape, n_chunks: int = 1,
                   placement=None) -> Plan:
    """Build a plan from a ``MoELayerShape`` alone (cost-model scoring).

    Constructs a minimal stand-in layout (dummy axis names, capacity
    from the shape's ``T``) and expands the chunk region *unclamped*, so
    scored grids match the requested candidates exactly — the runtime
    clamps real chunk counts before asking for a decision.  Passing an
    ``ExpertPlacement`` scores its placed variant (``t_plan`` prices the
    shrunk pool and the rank-load skew).
    """
    from repro.core.gating import GateConfig
    from repro.core.schedules import MoEShardInfo

    cap = max(int(shape.T), 1)
    info = MoEShardInfo(
        ep_axes=("ep",), esp_axes=("esp",), mp_axes=("mp",),
        n_ep=shape.n_ep, n_esp=shape.n_esp, n_mp=shape.n_mp,
        tokens=shape.B * shape.L, cap=cap,
        gate=GateConfig(n_experts=shape.E, top_k=shape.k,
                        capacity_factor=shape.f))
    base = PLANS[name].builder(info)
    if placement is not None:
        base = apply_placement(base, placement, info=info)
    return split_capacity(base, n_chunks, clamp=False)


def plan_summary(plan: Plan) -> dict:
    """JSON-ready description of a plan's stage graph (the
    ``launch/dryrun.py --dump-plan`` artifact payload)."""
    wd = getattr(plan.comm, "wire_dtype", "f32") if plan.comm else "f32"
    pl = plan.placement
    return {
        "name": plan.name,
        "base": plan.base or plan.name,
        "n_chunks": plan.n_chunks,
        "wire_dtype": wd,
        "merge": plan.merge if plan.n_chunks > 1 else None,
        "placement": pl.summary() if pl is not None else None,
        "output": plan.output,
        "stages": [
            {"name": s.name, "kind": s.kind, "deps": list(s.deps),
             "axes": list(s.axes),
             "wire": (wd if s.wire else None),
             "chunk": s.p("chunk_index") if s.chunk else None,
             **({"hier": s.p("hier")} if s.p("hier") else {})}
            for s in plan.stages],
    }


def format_plan(plan: Plan) -> str:
    """One line per stage, for run logs and ``--dump-plan`` printouts."""
    wd = getattr(plan.comm, "wire_dtype", "f32") if plan.comm else "f32"
    head = (f"plan {plan.name} (base={plan.base or plan.name}, "
            f"n_chunks={plan.n_chunks}, wire={wd})")
    if plan.placement is not None:
        pl = plan.placement
        head += (f" placed[R={pl.n_phys} cap_frac={pl.cap_frac:.2f} "
                 f"epoch={pl.epoch}]")
    lines = [head]
    for s in plan.stages:
        bits = [s.kind]
        if s.axes:
            bits.append("axes=" + "x".join(s.axes))
        if s.wire:
            bits.append(f"wire={wd}")
        if s.p("hier"):
            bits.append(f"hier={s.p('hier')}")
        deps = ", ".join(s.deps) or "-"
        lines.append(f"  {s.name:18s} {' '.join(bits):34s} <- {deps}")
    return "\n".join(lines)
