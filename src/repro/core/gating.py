"""Top-k gating with expert capacity (GShard-style), plus the scatter
dispatch / gather combine that move tokens in and out of the per-expert
capacity buffer.

``dispatch``/``combine`` compute the flat slot indices here (pure jnp) and
route the actual scatter/gather through the kernel-backend registry
(``repro.kernels.registry``): backend ``"ref"`` is the pure-jnp oracle the
schedule bodies historically inlined, ``"pallas"`` the TPU kernel.
Capacity semantics follow the paper: T = k * f * tokens / E, and each
schedule applies it to the token set it gates (S1 gates each MP shard
independently, so its per-shard capacity is T / N_MP — see DESIGN.md
fidelity notes).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.registry import KernelConfig, get_op


@dataclass(frozen=True)
class GateConfig:
    n_experts: int
    top_k: int = 1
    capacity_factor: float = 1.25
    normalize_topk: bool = False   # qwen3 norm_topk_prob
    aux_loss_weight: float = 1e-2
    z_loss_weight: float = 1e-3
    gate_dtype: jnp.dtype = jnp.float32
    # slot assignment implementation: "sort" is O(S*k log S*k) and avoids
    # materializing the (S*k, E) one-hot cumsum (which dominated the memory
    # roofline term for fine-grained MoE — see EXPERIMENTS.md §Perf A1);
    # "cumsum" is the GShard-style reference.  Identical outputs.
    impl: str = "sort"


def capacity(tokens: int, cfg: GateConfig, align: int = 8) -> int:
    """Per-expert capacity T for a pool of ``tokens`` tokens."""
    c = int(-(-cfg.top_k * cfg.capacity_factor * tokens // cfg.n_experts))
    return max(align, -(-c // align) * align)


class GateResult:
    """One token pool's routing decision, unpackable as the classic
    ``(expert_idx, slot_idx, weights, aux)`` 4-tuple.

    Also memoizes :func:`flat_slots` per ``(cap, n_experts)`` so the
    dispatch scatter and combine gather of the same layer share a single
    flat-index computation instead of each re-deriving it (they always
    ask for the same key, so this halves the index math per MoE layer).
    """

    __slots__ = ("expert_idx", "slot_idx", "weights", "aux", "_flat")

    def __init__(self, expert_idx, slot_idx, weights, aux):
        self.expert_idx = expert_idx
        self.slot_idx = slot_idx
        self.weights = weights
        self.aux = aux
        self._flat = {}

    def __iter__(self):
        return iter((self.expert_idx, self.slot_idx, self.weights,
                     self.aux))

    def __getitem__(self, i):
        return (self.expert_idx, self.slot_idx, self.weights, self.aux)[i]

    def __len__(self):
        return 4

    def flat(self, cap: int, n_experts: int):
        """Cached :func:`flat_slots` for this routing decision."""
        key = (cap, n_experts)
        if key not in self._flat:
            self._flat[key] = flat_slots(self.expert_idx, self.slot_idx,
                                         cap, n_experts)
        return self._flat[key]


def topk_gate(x, wg, cfg: GateConfig, cap) -> "GateResult":
    """Route tokens to experts.

    Args:
      x: (S, M) tokens.
      wg: (M, E) gate weights.
      cap: per-expert capacity for this token pool — a python int, or an
           (E,) int array of per-expert *effective* capacities (an
           expert replicated r times under an ``ExpertPlacement`` keeps
           ``r * placed_cap`` slots; the scalar path is bitwise
           unchanged).

    Returns a :class:`GateResult` (unpacks as a 4-tuple):
      expert_idx: (S, k) int32 — chosen expert per (token, choice).
      slot_idx:   (S, k) int32 — position in the expert's capacity buffer;
                  >= cap means the token was dropped for that choice.
      weights:    (S, k) f32   — combine weights (0 for dropped).
      aux:        dict with load-balance loss, z-loss and per-expert load.
    """
    S, _ = x.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.asarray(x, cfg.gate_dtype) @ jnp.asarray(wg, cfg.gate_dtype)
    probs = jax.nn.softmax(logits, axis=-1)                      # (S, E)
    gate_w, expert_idx = lax.top_k(probs, k)                     # (S, k)
    expert_idx = expert_idx.astype(jnp.int32)
    if cfg.normalize_topk:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Capacity assignment with choice-major priority (all 1st choices win
    # slots before any 2nd choice), GShard semantics.
    flat_e = expert_idx.T.reshape(-1)                            # (k*S,) choice-major
    if cfg.impl == "sort":
        # sort-based: stable argsort groups entries by expert while
        # preserving the choice-major priority; the slot is the rank
        # within the expert's run.  O(S*k log S*k) memory/compute — no
        # (S*k, E) one-hot materialization.
        order = jnp.argsort(flat_e, stable=True)                 # (k*S,)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        slot_sorted = jnp.arange(k * S, dtype=jnp.int32) - first[sorted_e]
        slot_flat = jnp.zeros((k * S,), jnp.int32).at[order].set(
            slot_sorted.astype(jnp.int32))
        load = jnp.zeros((E,), jnp.float32).at[flat_e].add(
            1.0, mode="drop")
    else:
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (k*S, E)
        pos = jnp.cumsum(onehot, axis=0) - 1                     # slot per entry
        slot_flat = jnp.take_along_axis(pos, flat_e[:, None],
                                        axis=1)[:, 0]
        load = jnp.sum(onehot, axis=0).astype(jnp.float32)
    slot_idx = slot_flat.reshape(k, S).T.astype(jnp.int32)       # (S, k)
    if isinstance(cap, int):
        kept = slot_idx < cap
        cap_f = float(cap)
    else:                       # (E,) per-expert effective capacities
        cap_e = jnp.asarray(cap, jnp.int32)
        kept = slot_idx < cap_e[expert_idx]
        cap_f = cap_e.astype(jnp.float32)
    weights = jnp.where(kept, gate_w, 0.0).astype(jnp.float32)

    # Aux losses (Switch/GShard load balancing + router z-loss).
    me = jnp.mean(probs, axis=0)                                 # mean prob/expert
    first_choice = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(first_choice, axis=0)                          # frac tokens/expert
    aux_loss = cfg.aux_loss_weight * E * jnp.sum(me * ce)
    z_loss = cfg.z_loss_weight * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"aux_loss": aux_loss, "z_loss": z_loss, "load": load,
           # per-expert rows that actually won a slot (= the ragged
           # grouped kernel's group sizes; load is the unclamped demand)
           "routed": jnp.minimum(load, cap_f),
           "drop_frac": 1.0 - jnp.mean(kept.astype(jnp.float32))}
    return GateResult(expert_idx, slot_idx, weights, aux)


def flat_slots(expert_idx, slot_idx, cap: int, n_experts: int):
    """Flat capacity-buffer index per (token, choice); ``n_experts * cap``
    marks a dropped choice (the registry ops' drop sentinel)."""
    return jnp.where(slot_idx < cap, expert_idx * cap + slot_idx,
                     n_experts * cap).astype(jnp.int32)


def dispatch(x, expert_idx, slot_idx, cap: int, n_experts: int,
             kernel: Optional[KernelConfig] = None, *, flat=None):
    """Scatter tokens into the (E, cap, M) capacity buffer.

    Dropped tokens (slot >= cap) are discarded.  The scatter itself is a
    registry op (``moe_dispatch``), so the backend follows ``kernel``.
    ``flat`` short-circuits the index computation with a precomputed
    :func:`flat_slots` (see :meth:`GateResult.flat`).
    """
    M = x.shape[-1]
    if flat is None:
        flat = flat_slots(expert_idx, slot_idx, cap, n_experts)  # (S, k)
    op = get_op("moe_dispatch", cfg=kernel, n_slots=n_experts * cap)
    return op(x, flat).reshape(n_experts, cap, M)


def combine(buf, expert_idx, slot_idx, weights, cap: int,
            kernel: Optional[KernelConfig] = None, *, flat=None):
    """Gather expert outputs back to token order and mix with gate weights
    (registry op ``moe_combine``; dropped choices contribute zero).
    ``flat`` reuses a precomputed :func:`flat_slots` like :func:`dispatch`."""
    E = buf.shape[0]
    M = buf.shape[-1]
    if flat is None:
        flat = flat_slots(expert_idx, slot_idx, cap, E)          # (S, k)
    op = get_op("moe_combine", cfg=kernel)
    return op(buf.reshape(E * cap, M), flat, weights)
