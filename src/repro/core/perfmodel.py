"""The alpha-beta collective performance model and Algorithm 1 (§V).

``t = alpha + beta * x`` per collective, with (alpha, beta) either fitted
by least squares from measured latencies (paper §VI-B / Fig. 6) or derived
analytically from fabric constants (TPU v5e: ~50 GB/s/link ICI).

The closed forms reproduce Eq. (1), (13), (14) and the schedule selector
reproduces Algorithm 1 line-by-line.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class AlphaBeta:
    alpha: float  # startup seconds
    beta: float   # seconds per element

    def __call__(self, n_elements: float) -> float:
        return self.alpha + self.beta * max(n_elements, 0.0)


@dataclass(frozen=True)
class MoELayerShape:
    """Notation of Table I: per-rank quantities."""
    B: int           # samples per rank
    L: int           # tokens per sample
    M: int           # embedding size
    H: int           # expert hidden size
    E: int           # total experts
    k: int = 1
    f: float = 1.2
    n_mp: int = 1
    n_esp: int = 1
    n_ep: int = 1

    @property
    def T(self) -> float:
        return self.k * self.f * self.B * self.L / self.E

    @property
    def blm(self) -> float:
        return self.B * self.L * self.M

    @property
    def etm(self) -> float:
        return self.E * self.T * self.M


@dataclass(frozen=True)
class PerfModel:
    a2a_ep_esp: AlphaBeta        # fused EP&ESP-AlltoAll
    a2a_ep: AlphaBeta            # plain EP-AlltoAll (baseline)
    ag_esp: AlphaBeta            # ESP-AllGather (baseline)
    ar_esp: AlphaBeta            # ESP-AllReduce (baseline)
    ag_mp: AlphaBeta             # MP-AllGather
    overlap: AlphaBeta           # overlapped EP&ESP-A2A + MP-AG (SAA phase)

    # --- closed forms ------------------------------------------------------
    def t_baseline(self, s: MoELayerShape) -> float:
        """Eq. (1)."""
        return (self.ag_esp(s.blm * s.n_esp)
                + self.ar_esp(s.etm * s.n_esp)
                + 2 * self.a2a_ep(s.etm * s.n_esp))

    def t_s1(self, s: MoELayerShape) -> float:
        """Eq. (11)/(13)."""
        return (2 * self.a2a_ep_esp(s.etm * s.n_esp / s.n_mp)
                + self.ag_mp(s.blm))

    def t_s2(self, s: MoELayerShape) -> float:
        """Eq. (14)."""
        return (self.a2a_ep_esp(s.etm * s.n_esp / s.n_mp)
                + self.overlap(s.etm * s.n_esp / s.n_mp)
                + self.ag_mp(s.etm))

    # --- Algorithm 1 --------------------------------------------------------
    def algorithm1(self, s: MoELayerShape) -> str:
        """Faithful transcription of Algorithm 1 (lines 1-9)."""
        x = s.B * s.L * s.M                                  # line 1
        T = s.k * s.f * s.B * s.L / s.E                      # line 2 (T)
        y = s.E * T * s.M * s.n_esp                          # line 3
        t_d1 = (2 * (self.a2a_ep_esp.alpha
                     + self.a2a_ep_esp.beta * y / s.n_mp)
                + self.ag_mp.alpha + self.ag_mp.beta * x)    # line 4
        t_d2 = (self.a2a_ep_esp.alpha
                + self.a2a_ep_esp.beta * y / s.n_mp
                + self.overlap.alpha + self.overlap.beta * y / s.n_mp
                + self.ag_mp.alpha + self.ag_mp.beta * T * s.E * s.M)  # line 5 + AG_MP(ETM) of Eq. 14
        return "s1" if t_d1 <= t_d2 else "s2"                # lines 6-9

    def pick(self, s: MoELayerShape) -> str:
        return self.algorithm1(s)


def fit_alpha_beta(sizes, times) -> AlphaBeta:
    """Least-squares fit of t = alpha + beta*x (paper §V-A)."""
    n = len(sizes)
    sx = sum(sizes)
    sy = sum(times)
    sxx = sum(x * x for x in sizes)
    sxy = sum(x * y for x, y in zip(sizes, times))
    denom = n * sxx - sx * sx
    if denom == 0:
        return AlphaBeta(alpha=sy / max(n, 1), beta=0.0)
    beta = (n * sxy - sx * sy) / denom
    alpha = (sy - beta * sx) / n
    return AlphaBeta(alpha=max(alpha, 0.0), beta=max(beta, 0.0))


# --- analytic TPU v5e fabric model ------------------------------------------

ICI_LINK_BW = 50e9        # bytes/s per link (v5e)
HBM_BW = 819e9            # bytes/s
PEAK_FLOPS_BF16 = 197e12  # per chip
ALPHA_ICI = 1e-6          # per-collective startup, seconds
DCI_BW = 6.25e9           # inter-pod data-center interconnect per chip (est.)


def tpu_v5e_model(n_ep: int, n_esp: int, n_mp: int, bytes_per_el: int = 2,
                  inter_pod: bool = False) -> PerfModel:
    """Analytic alpha-beta constants for a v5e mesh.

    MP/ESP map to the innermost mesh axis (fastest, all-ICI); EP spans the
    outer axis (and the DCI when ``inter_pod``).  Ring/bidirectional
    collectives move (g-1)/g of the payload through a chip's ~link_bw.
    """
    def coll(bw, g):
        frac = (g - 1) / g if g > 1 else 0.0
        return AlphaBeta(ALPHA_ICI * max(g, 1), bytes_per_el * frac / bw)

    bw_outer = DCI_BW if inter_pod else ICI_LINK_BW
    a2a_combined = coll(min(ICI_LINK_BW, bw_outer), n_ep * n_esp)
    return PerfModel(
        a2a_ep_esp=a2a_combined,
        a2a_ep=coll(bw_outer, n_ep),
        ag_esp=coll(ICI_LINK_BW, n_esp),
        ar_esp=AlphaBeta(2 * ALPHA_ICI * n_esp,
                         2 * bytes_per_el * (n_esp - 1) / max(n_esp, 1)
                         / ICI_LINK_BW),
        ag_mp=coll(ICI_LINK_BW, n_mp),
        # SAA hides the faster of the two transfers; model the overlapped
        # phase as the a2a beta alone (AllGather rides in its shadow).
        overlap=a2a_combined,
    )


def speedup_table(shape: MoELayerShape, model: PerfModel) -> dict:
    """Analytic reproduction row: baseline vs S1 vs S2 vs Parm (auto)."""
    tb = model.t_baseline(shape)
    t1 = model.t_s1(shape)
    t2 = model.t_s2(shape)
    pick = model.algorithm1(shape)
    tp = t1 if pick == "s1" else t2
    return {"t_baseline": tb, "t_s1": t1, "t_s2": t2, "pick": pick,
            "speedup_s1": tb / t1, "speedup_s2": tb / t2,
            "speedup_parm": tb / tp}
