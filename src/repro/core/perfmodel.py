"""The alpha-beta collective performance model and Algorithm 1 (paper §V).

Every collective is modelled as ``t = alpha + beta * x`` (startup plus
per-element time), with ``(alpha, beta)`` either fitted by least squares
from measured latencies (paper §VI-B / Fig. 6, :func:`fit_alpha_beta`) or
derived analytically from fabric constants (:func:`tpu_v5e_model`).

The closed forms reproduce the paper's Eq. (1), (13), (14); the schedule
selector :meth:`PerfModel.algorithm1` reproduces Algorithm 1
line-by-line; and :meth:`PerfModel.t_pipelined` extends the model to the
chunk-pipelined bodies of ``repro.core.pipeline`` (fill/drain pipeline
over per-chunk communication and expert-FFN compute).  The
``schedule="auto"`` runtime (``repro.core.autosched``) consults these
methods — or a live measurement — per MoE layer shape.

Run the examples with ``python -m doctest src/repro/core/perfmodel.py``.
"""

from __future__ import annotations

from dataclasses import dataclass

# --- fabric constants (analytic TPU v5e model) -------------------------------

ICI_LINK_BW = 50e9        # bytes/s per ICI link (v5e)
HBM_BW = 819e9            # bytes/s
PEAK_FLOPS_BF16 = 197e12  # per chip
ALPHA_ICI = 1e-6          # per-collective startup, seconds
DCI_BW = 6.25e9           # inter-pod data-center interconnect per chip (est.)

# --- wire formats (repro.core.collectives.CommConfig) ------------------------
# Bytes per element each wire dtype puts on the fabric.  The schedules can
# compress the payload of every *bit-moving* collective (AlltoAlls,
# output AllGathers) to one of these; the per-chunk fp8 scale piggyback
# adds 4 bytes per M-row, negligible for production M and ignored here.

WIRE_DTYPES = ("f32", "bf16", "fp8_e4m3")
WIRE_BYTES = {"f32": 4.0, "bf16": 2.0, "fp8_e4m3": 1.0}

#: Schedules whose expert FFN is NOT MP-split: every MP rank computes the
#: full expert batch (the baseline's redundancy — and, deliberately, the
#: decode-dedicated ``s1d``, where the pool is tiny and the redundant
#: compute is cheaper than the extra collective).
REDUNDANT_COMPUTE = ("baseline", "s1d")


@dataclass(frozen=True)
class AlphaBeta:
    """One collective's latency model: ``t(x) = alpha + beta * x``.

    ``alpha`` is the startup cost in seconds, ``beta`` the marginal
    seconds per element.  Calling the instance evaluates it:

    >>> AlphaBeta(alpha=1.0, beta=0.5)(4)
    3.0
    >>> AlphaBeta(alpha=1.0, beta=0.5)(-8)   # sizes clamp at zero
    1.0
    """

    alpha: float  # startup seconds
    beta: float   # seconds per element

    def __call__(self, n_elements: float) -> float:
        """Predicted seconds for a collective over ``n_elements``."""
        return self.alpha + self.beta * max(n_elements, 0.0)


@dataclass(frozen=True)
class MoELayerShape:
    """One MoE layer's shape in the notation of the paper's Table I.

    All quantities are *per rank*: ``B`` samples of ``L`` tokens with
    embedding size ``M``, ``E`` total experts of hidden size ``H``,
    top-``k`` routing with capacity factor ``f``, on an
    ``n_mp`` x ``n_esp`` x ``n_ep`` parallel layout.

    >>> s = MoELayerShape(B=4, L=256, M=8, H=32, E=8, k=2, f=1.0)
    >>> s.T          # per-expert capacity: k * f * B * L / E
    256.0
    >>> s.blm        # tokens x embedding elements per rank
    8192
    >>> s.etm == s.E * s.T * s.M
    True
    """

    B: int           # samples per rank
    L: int           # tokens per sample
    M: int           # embedding size
    H: int           # expert hidden size
    E: int           # total experts
    k: int = 1
    f: float = 1.2
    n_mp: int = 1
    n_esp: int = 1
    n_ep: int = 1
    # Shape *class*, not a size: True for decode-time (inference) pools.
    # It is part of the autosched cache key, so a decode decision can
    # never evict a training/prefill decision for a coinciding size, and
    # it widens the schedule grid to the decode-dedicated plans (s1d).
    infer: bool = False

    @property
    def T(self) -> float:
        """Per-expert capacity ``k * f * B * L / E`` (Table I)."""
        return self.k * self.f * self.B * self.L / self.E

    @property
    def blm(self) -> float:
        """``B * L * M``: input-activation elements per rank."""
        return self.B * self.L * self.M

    @property
    def etm(self) -> float:
        """``E * T * M``: dispatch-buffer elements per rank."""
        return self.E * self.T * self.M


@dataclass(frozen=True)
class PerfModel:
    """Alpha-beta models for every collective the schedules issue.

    The six fields cover the baseline's collectives (plain EP-AlltoAll,
    ESP-AllGather/AllReduce), the fused EP&ESP-AlltoAll of S1/S2, the
    MP-AllGather, and the SAA overlapped phase of S2.  ``flops_per_s``
    adds a coarse compute term so the pipelined variants (which overlap
    communication with the expert FFN) can be scored too.
    """

    a2a_ep_esp: AlphaBeta        # fused EP&ESP-AlltoAll
    a2a_ep: AlphaBeta            # plain EP-AlltoAll (baseline)
    ag_esp: AlphaBeta            # ESP-AllGather (baseline)
    ar_esp: AlphaBeta            # ESP-AllReduce (baseline)
    ag_mp: AlphaBeta             # MP-AllGather
    overlap: AlphaBeta           # overlapped EP&ESP-A2A + MP-AG (SAA phase)
    flops_per_s: float = PEAK_FLOPS_BF16  # per-chip dense compute rate
    wire_bytes_ref: float = 2.0  # bytes/element the betas were fitted at
    # hierarchical (s2h) A2A hops; None falls back to the fused model so
    # pre-existing PerfModel constructions keep scoring every schedule
    a2a_intra: "AlphaBeta | None" = None  # intra-group hop (ESP links)
    a2a_inter: "AlphaBeta | None" = None  # inter-group hop (EP links)

    @property
    def hier_intra(self) -> AlphaBeta:
        return self.a2a_intra or self.a2a_ep_esp

    @property
    def hier_inter(self) -> AlphaBeta:
        return self.a2a_inter or self.a2a_ep_esp

    # --- wire-precision extension ------------------------------------------
    def wire_factor(self, wire_dtype=None) -> float:
        """Element-count multiplier for a wire dtype.

        The betas are seconds per element *at* ``wire_bytes_ref`` bytes;
        shipping a collective at a different width scales only the
        bandwidth term (``alpha`` is payload-independent), which the
        closed forms below apply by scaling the element count:

        >>> ab = AlphaBeta(0.0, 1.0)
        >>> m = PerfModel(ab, ab, ab, ab, ab, ab, wire_bytes_ref=2.0)
        >>> m.wire_factor("bf16"), m.wire_factor("f32"), m.wire_factor()
        (1.0, 2.0, 1.0)
        """
        if wire_dtype is None:
            return 1.0
        return WIRE_BYTES[wire_dtype] / self.wire_bytes_ref

    # --- closed forms ------------------------------------------------------
    def t_baseline(self, s: MoELayerShape, wire_dtype=None) -> float:
        """Eq. (1): ESP-AllGather + ESP-AllReduce + 2 EP-AlltoAlls.

        Only the AlltoAlls compress: the ESP-AllGather precedes the gate
        (wire-rounding it would change routing) and the AllReduce does
        its arithmetic in-network at compute width.
        """
        wf = self.wire_factor(wire_dtype)
        return (self.ag_esp(s.blm * s.n_esp)
                + self.ar_esp(s.etm * s.n_esp)
                + 2 * self.a2a_ep(s.etm * s.n_esp * wf))

    def t_s1(self, s: MoELayerShape, wire_dtype=None) -> float:
        """Eq. (11)/(13): two fused AlltoAlls + MP-AllGather(BLM).
        All three move post-gate payload, so all three compress."""
        wf = self.wire_factor(wire_dtype)
        return (2 * self.a2a_ep_esp(s.etm * s.n_esp / s.n_mp * wf)
                + self.ag_mp(s.blm * wf))

    def t_s2(self, s: MoELayerShape, wire_dtype=None) -> float:
        """Eq. (14): fused AlltoAll + SAA phase + MP-AllGather(ETM)."""
        wf = self.wire_factor(wire_dtype)
        return (self.a2a_ep_esp(s.etm * s.n_esp / s.n_mp * wf)
                + self.overlap(s.etm * s.n_esp / s.n_mp * wf)
                + self.ag_mp(s.etm * wf))

    # --- compute + pipeline extension (repro.core.pipeline) ----------------
    def t_ffn(self, s: MoELayerShape, schedule: str = "s1") -> float:
        """Per-device expert-FFN seconds (coarse dense-roofline estimate).

        A GLU expert runs three ``M x H`` matmuls per token slot, i.e.
        ``6 * M * H`` FLOPs with multiply-adds counted as two.  S1/S2
        process ``E * T * n_esp / n_mp`` slots per device; the baseline
        skips the MP split and redundantly computes all ``n_mp`` copies
        — the very redundancy Parm removes (paper Fig. 3a).
        """
        slots = s.E * s.T * s.n_esp
        if schedule not in REDUNDANT_COMPUTE:
            slots /= s.n_mp
        return 6.0 * slots * s.M * s.H / s.n_esp / self.flops_per_s

    def _chain(self, s: MoELayerShape, schedule: str, wire_dtype=None):
        """(fixed, chain_alpha, chain_beta_time) for one schedule body.

        ``fixed`` is the serial time outside the chunkable AlltoAll/FFN
        chain; the chain's startup (``alpha``, charged once per chunk)
        and bandwidth time (split across chunks) are returned separately.
        ``wire_dtype`` scales the bandwidth terms of the compressible
        collectives (AlltoAlls + output AllGathers; the baseline's
        pre-gate AllGather and in-network AllReduce stay at full width —
        see :meth:`t_baseline`).
        """
        wf = self.wire_factor(wire_dtype)
        y = s.etm * s.n_esp
        if schedule == "baseline":
            return (self.ag_esp(s.blm * s.n_esp),
                    2 * self.a2a_ep.alpha + self.ar_esp.alpha,
                    2 * self.a2a_ep.beta * y * wf + self.ar_esp.beta * y)
        y /= s.n_mp
        if schedule in ("s1", "s1_seqpar"):
            fixed = 0.0 if schedule == "s1_seqpar" \
                else self.ag_mp(s.blm * wf)
            return (fixed, 2 * self.a2a_ep_esp.alpha,
                    2 * self.a2a_ep_esp.beta * y * wf)
        if schedule == "s2":
            return (0.0,
                    (self.a2a_ep_esp.alpha + self.overlap.alpha
                     + self.ag_mp.alpha),
                    (self.a2a_ep_esp.beta * y * wf
                     + self.overlap.beta * y * wf
                     + self.ag_mp.beta * s.etm * wf))
        raise ValueError(f"unknown schedule {schedule!r}")

    def t_pipelined(self, s: MoELayerShape, schedule: str = "s1",
                    n_chunks: int = 1, wire_dtype=None) -> float:
        """Fill/drain pipeline time for a chunked schedule body.

        With ``n`` chunks, each chunk's communication costs
        ``tc = chain_beta / n + chain_alpha`` and its FFN
        ``tf = t_ffn / n``; chunk ``i+1``'s communication overlaps chunk
        ``i``'s compute, so the chain totals
        ``tc + (n - 1) * max(tc, tf) + tf`` plus the un-chunkable fixed
        part.  ``n_chunks=1`` degenerates to the serial closed form plus
        the compute term, so pipelining only wins when overlap beats the
        extra per-chunk startup:

        >>> ab = AlphaBeta(1e-6, 1e-9)
        >>> m = PerfModel(ab, ab, ab, ab, ab, ab, flops_per_s=1e12)
        >>> s = MoELayerShape(B=8, L=1024, M=1024, H=4096, E=8, k=2,
        ...                   f=1.0, n_mp=2, n_esp=2, n_ep=2)
        >>> m.t_pipelined(s, "s1", 4) < m.t_pipelined(s, "s1", 1)
        True

        A narrower wire dtype shrinks the chain's bandwidth term (never
        the alphas or the FFN), so it can only help:

        >>> m.t_pipelined(s, "s1", 4, "bf16") <= m.t_pipelined(s, "s1", 4)
        True
        """
        n = max(1, n_chunks)
        fixed, c_alpha, c_beta = self._chain(s, schedule, wire_dtype)
        tc = c_beta / n + c_alpha
        tf = self.t_ffn(s, schedule) / n
        return fixed + tc + (n - 1) * max(tc, tf) + tf

    def pick_chunks(self, s: MoELayerShape, schedule: str = "s1",
                    candidates=(1, 2, 4, 8), wire_dtype=None) -> int:
        """Chunk count minimizing :meth:`t_pipelined` for one schedule."""
        return min(candidates, key=lambda n: self.t_pipelined(
            s, schedule, n, wire_dtype))

    # --- plan-IR cost model (repro.core.plan) ------------------------------
    def _t_stage_comm(self, st, s: MoELayerShape, wf: float, n: int,
                      overlap_hier: bool, etm_scale: float = 1.0) -> float:
        """Seconds one plan stage spends on the fabric (1/n of its
        payload for a chunk clone; local stages cost zero).
        ``etm_scale`` multiplies every capacity-pool (etm-sized) payload
        — placement pool shrink and/or max-rank load skew."""
        etm = s.etm * etm_scale
        size = {"blm": s.blm, "etm": etm,
                "blm*esp": s.blm * s.n_esp,
                "etm*esp": etm * s.n_esp,
                "etm*esp/mp": etm * s.n_esp / s.n_mp}.get(st.size, 0.0)
        f = (wf if st.wire else 1.0) / n
        if st.kind == "ag_mp":
            ab = self.ag_esp if st.axes and st.axes[0] == "esp" \
                else self.ag_mp
            return ab(size * f)
        if st.kind == "allreduce":
            return self.ar_esp(size / n)   # in-network: never wire-scaled
        if st.kind in ("dispatch_a2a", "combine_a2a"):
            if st.p("hier"):
                ti = self.hier_intra(size * f)
                tx = self.hier_inter(size * f)
                # alternating chunk orders run one chunk's intra-group
                # hop in the shadow of another's inter-group hop
                t = max(ti, tx) if overlap_hier else ti + tx
            elif st.p("saa"):
                t = self.overlap(size * f)
            elif st.p("fused"):
                t = self.a2a_ep_esp(size * f)
            else:
                t = self.a2a_ep(size * f)
            if st.p("saa") or st.p("stack_ag"):
                t += self.ag_mp(etm * (wf if st.wire else 1.0) / n)
            return t
        return 0.0   # gate/dispatch/combine/splits/slice/merge: local

    def t_plan(self, plan, s: MoELayerShape, wire_dtype=None,
               loads=None) -> float:
        """Predicted layer seconds for a schedule plan — the graph the
        executor runs is the graph this walks (one cost-model source of
        truth; the ``autosched`` grids score registry plans through it).

        Non-chunk stages are serial (``fixed``); each chunk's comm
        stages sum to its ``tc`` and overlap the other chunks' FFN
        slices exactly as in :meth:`t_pipelined`'s fill/drain model, so
        for the four paper schedules ``t_plan`` reproduces
        ``t_pipelined`` (asserted by ``tests/test_plan_executor.py``).
        ``wire_dtype=None`` keeps the pre-wire scoring (factor 1.0).

        Skew-aware pricing: with a per-expert ``loads`` vector, every
        capacity-pool term (the etm-sized A2As and the expert FFN) is
        charged at the *most-loaded EP rank's* share — a synchronized
        stage runs at the pace of its slowest rank, so a hot expert
        multiplies the uniform plan's time by max-rank/mean-rank load.
        A plan carrying an ``ExpertPlacement`` is charged its own rank
        imbalance (replication spreads the hot expert) times its
        ``pool_scale`` (the shrunk ``cap_frac`` capacity pool) — this is
        how ``autosched.decide_placement`` scores placements against the
        uniform plan.
        """
        wf = self.wire_factor(wire_dtype)
        pl = getattr(plan, "placement", None)
        etm_scale = 1.0
        if pl is not None:
            etm_scale *= pl.pool_scale(max(int(s.T), 1))
        if loads is not None and len(loads):
            etm_scale *= _rank_imbalance(loads, s.n_ep, pl)
        n = max(getattr(plan, "n_chunks", 1), 1)
        overlap_hier = n >= 2
        fixed, per_chunk = 0.0, {}
        for st in plan.stages:
            t = self._t_stage_comm(st, s, wf, n if st.chunk else 1,
                                   overlap_hier, etm_scale)
            if t == 0.0:
                continue
            if st.chunk:
                ci = st.p("chunk_index", 0)
                per_chunk[ci] = per_chunk.get(ci, 0.0) + t
            else:
                fixed += t
        tc = max(per_chunk.values(), default=0.0)
        tf = self.t_ffn(s, plan.base or plan.name) / n * etm_scale
        if any(st.kind == "expert_ffn_grouped" for st in plan.stages):
            # ragged grouped-GEMM: compute scales with *routed* tokens
            # (k*B*L rows), not capacity (k*f*B*L slots) — the expected
            # MXU occupancy of the predicated kernel is 1/f for f >= 1
            tf *= min(1.0, 1.0 / max(s.f, 1e-9))
        return fixed + tc + (n - 1) * max(tc, tf) + tf

    def t_plan_stages(self, plan, s: MoELayerShape, wire_dtype=None,
                      loads=None) -> dict:
        """Per-stage predicted seconds for ``plan`` — the same pricing
        as :meth:`t_plan` (same wire factor, chunk scaling, etm skew),
        itemized instead of folded through the fill/drain closed form.

        Returns ``{stage_name: seconds}`` covering *every* stage of the
        plan: comm stages get their :meth:`_t_stage_comm` term, the
        expert-FFN stages split the compute term ``tf`` evenly, and
        local bookkeeping stages (gate/dispatch/combine/splits) are an
        explicit ``0.0`` — the model claims they are free, and the
        audit (``repro.obs.audit``) holds it to that by reporting their
        measured times without a relative error.

        Itemized serial times deliberately do NOT sum to
        :meth:`t_plan`: the closed form credits chunk overlap
        (``max(tc, tf)``), the per-stage view does not.  The audit
        reports both totals side by side.
        """
        wf = self.wire_factor(wire_dtype)
        pl = getattr(plan, "placement", None)
        etm_scale = 1.0
        if pl is not None:
            etm_scale *= pl.pool_scale(max(int(s.T), 1))
        if loads is not None and len(loads):
            etm_scale *= _rank_imbalance(loads, s.n_ep, pl)
        n = max(getattr(plan, "n_chunks", 1), 1)
        overlap_hier = n >= 2
        ffn = [st for st in plan.stages
               if st.kind in ("expert_ffn", "expert_ffn_grouped")]
        tf = self.t_ffn(s, plan.base or plan.name) * etm_scale
        if any(st.kind == "expert_ffn_grouped" for st in plan.stages):
            tf *= min(1.0, 1.0 / max(s.f, 1e-9))
        out = {}
        for st in plan.stages:
            if st.kind in ("expert_ffn", "expert_ffn_grouped"):
                out[st.name] = tf / len(ffn)
            else:
                out[st.name] = self._t_stage_comm(
                    st, s, wf, n if st.chunk else 1, overlap_hier,
                    etm_scale)
        return out

    # --- decode latency model (repro.serve) ---------------------------------
    def t_decode(self, s: MoELayerShape, wire_dtype=None,
                 kv_bytes: float = 0.0) -> float:
        """Predicted seconds for one MoE layer at *decode* time: the best
        candidate of the decode grid (``plan.analytic_schedules(infer=
        True)``, which adds the decode-dedicated plans, e.g. ``s1d``) at
        ``n_chunks=1`` — decode pools are a handful of tokens, far too
        small for capacity chunking to pay for its alphas.

        ``kv_bytes`` adds the paged-KV attention read for the step: the
        decode batch streams every live page of K/V once per token, an
        HBM-bandwidth-bound term (``kv_bytes / HBM_BW``) that grows with
        context length while the MoE terms stay fixed.

        The serving engine uses this for batch-bucket sizing
        (``repro.serve.engine.suggest_max_batch``): decode steps are
        alpha-dominated, so per-token latency falls with batch until the
        bandwidth terms take over — and with paged KV the block budget,
        not the row count, caps the batch.
        """
        from repro.core import plan as planlib  # lazy: avoid module cycle
        return max(kv_bytes, 0.0) / HBM_BW + min(
            self.t_plan(planlib.plan_for_shape(name, s, 1), s,
                        wire_dtype=wire_dtype)
            for name in planlib.analytic_schedules(infer=True))

    # --- Algorithm 1 --------------------------------------------------------
    def algorithm1(self, s: MoELayerShape) -> str:
        """Faithful transcription of Algorithm 1 (lines 1-9).

        Compares the S1 cost ``t_D1`` (line 4) against the S2 cost
        ``t_D2`` (line 5) for the layer shape and returns the winner:

        >>> ab = AlphaBeta(1e-5, 1e-9)
        >>> m = PerfModel(ab, ab, ab, ab, ab, ab)
        >>> big = MoELayerShape(B=64, L=4096, M=1024, H=1, E=4, k=4,
        ...                     f=8.0, n_mp=4, n_esp=1, n_ep=4)
        >>> m.algorithm1(big)      # T -> inf favours S1 (paper §IV-B)
        's1'
        """
        x = s.B * s.L * s.M                                  # line 1
        T = s.k * s.f * s.B * s.L / s.E                      # line 2 (T)
        y = s.E * T * s.M * s.n_esp                          # line 3
        t_d1 = (2 * (self.a2a_ep_esp.alpha
                     + self.a2a_ep_esp.beta * y / s.n_mp)
                + self.ag_mp.alpha + self.ag_mp.beta * x)    # line 4
        t_d2 = (self.a2a_ep_esp.alpha
                + self.a2a_ep_esp.beta * y / s.n_mp
                + self.overlap.alpha + self.overlap.beta * y / s.n_mp
                + self.ag_mp.alpha + self.ag_mp.beta * T * s.E * s.M)  # line 5 + AG_MP(ETM) of Eq. 14
        return "s1" if t_d1 <= t_d2 else "s2"                # lines 6-9

    def pick(self, s: MoELayerShape) -> str:
        """Algorithm-1 schedule choice (no pipelining considered)."""
        return self.algorithm1(s)


def _rank_imbalance(loads, n_ep: int, placement=None) -> float:
    """max-rank / mean-rank load for a per-expert load vector.

    With a placement, its replication spreads each expert's load across
    its replicas' ranks (``ExpertPlacement.imbalance``); without one,
    the canonical block mapping (expert e on rank ``e // (E / n_ep)``)
    applies.  Degenerate inputs price as balanced (1.0).

    >>> _rank_imbalance([4.0, 1.0, 1.0, 1.0], 4)
    2.2857142857142856
    >>> _rank_imbalance([1.0, 1.0, 1.0, 1.0], 2)
    1.0
    """
    if placement is not None:
        return placement.imbalance(loads)
    E = len(loads)
    if n_ep <= 1 or E % n_ep:
        return 1.0
    tot = float(sum(loads))
    if tot <= 0:
        return 1.0
    per = E // n_ep
    ranks = [sum(loads[r * per:(r + 1) * per]) for r in range(n_ep)]
    return max(ranks) / (tot / n_ep)


def fit_alpha_beta(sizes, times) -> AlphaBeta:
    """Least-squares fit of ``t = alpha + beta * x`` (paper §V-A).

    Degenerate inputs (all sizes equal) fall back to ``beta = 0`` with
    ``alpha`` the mean time; negative fitted parameters clamp at zero.

    >>> fit_alpha_beta([1, 2, 3], [3.0, 5.0, 7.0])
    AlphaBeta(alpha=1.0, beta=2.0)
    >>> fit_alpha_beta([4, 4], [2.0, 4.0])
    AlphaBeta(alpha=3.0, beta=0.0)
    """
    n = len(sizes)
    sx = sum(sizes)
    sy = sum(times)
    sxx = sum(x * x for x in sizes)
    sxy = sum(x * y for x, y in zip(sizes, times))
    denom = n * sxx - sx * sx
    if denom == 0:
        return AlphaBeta(alpha=sy / max(n, 1), beta=0.0)
    beta = (n * sxy - sx * sy) / denom
    alpha = (sy - beta * sx) / n
    return AlphaBeta(alpha=max(alpha, 0.0), beta=max(beta, 0.0))


# --- analytic TPU v5e fabric model ------------------------------------------

def tpu_v5e_model(n_ep: int, n_esp: int, n_mp: int, bytes_per_el: int = 2,
                  inter_pod: bool = False) -> PerfModel:
    """Analytic alpha-beta constants for a v5e mesh.

    MP/ESP map to the innermost mesh axis (fastest, all-ICI); EP spans the
    outer axis (and the DCI when ``inter_pod``).  Ring/bidirectional
    collectives move ``(g - 1) / g`` of the payload through a chip's
    ~``ICI_LINK_BW``.  Single-member groups cost nothing per element:

    >>> m = tpu_v5e_model(n_ep=4, n_esp=1, n_mp=1)
    >>> m.ag_esp.beta == 0.0 and m.a2a_ep.beta > 0.0
    True
    """
    def coll(bw, g):
        frac = (g - 1) / g if g > 1 else 0.0
        return AlphaBeta(ALPHA_ICI * max(g, 1), bytes_per_el * frac / bw)

    bw_outer = DCI_BW if inter_pod else ICI_LINK_BW
    a2a_combined = coll(min(ICI_LINK_BW, bw_outer), n_ep * n_esp)
    return PerfModel(
        a2a_ep_esp=a2a_combined,
        a2a_ep=coll(bw_outer, n_ep),
        ag_esp=coll(ICI_LINK_BW, n_esp),
        ar_esp=AlphaBeta(2 * ALPHA_ICI * n_esp,
                         2 * bytes_per_el * (n_esp - 1) / max(n_esp, 1)
                         / ICI_LINK_BW),
        ag_mp=coll(ICI_LINK_BW, n_mp),
        # SAA hides the faster of the two transfers; model the overlapped
        # phase as the a2a beta alone (AllGather rides in its shadow).
        overlap=a2a_combined,
        # betas above bake in bytes_per_el, so wire factors are relative
        wire_bytes_ref=float(bytes_per_el),
        # hierarchical (s2h) hops: the intra hop stays on all-ICI ESP
        # links; the inter hop crosses the outer fabric.  The fused
        # collective above pays min(ICI, outer) bandwidth on its whole
        # payload, so on an inter-pod mesh the decomposition — which
        # overlaps the two hops across alternating chunks — wins.
        a2a_intra=coll(ICI_LINK_BW, n_esp),
        a2a_inter=coll(bw_outer, n_ep),
    )


def speedup_table(shape: MoELayerShape, model: PerfModel) -> dict:
    """Analytic reproduction row: baseline vs S1 vs S2 vs Parm (auto).

    Returns the three closed-form times, the Algorithm-1 pick, and the
    baseline-relative speedups (``speedup_parm`` uses the picked
    schedule's time).
    """
    tb = model.t_baseline(shape)
    t1 = model.t_s1(shape)
    t2 = model.t_s2(shape)
    pick = model.algorithm1(shape)
    tp = t1 if pick == "s1" else t2
    return {"t_baseline": tb, "t_s1": t1, "t_s2": t2, "pick": pick,
            "speedup_s1": tb / t1, "speedup_s2": tb / t2,
            "speedup_parm": tb / tp}
