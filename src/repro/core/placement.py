"""Load-adaptive expert placement: replicate hot experts across EP ranks.

Parm's schedules assume uniform expert load, but real traffic is skewed:
one hot expert overflows its capacity slots (drops, or an inflated
capacity factor padding every cold expert too) while cold EP ranks idle.
Megatron-Core's MoE report and MegaScale-MoE both treat load balancing
as a first-class production problem; here it falls out of the PR 4 plan
IR as a graph transform (``plan.apply_placement``) instead of a rewrite.

An :class:`ExpertPlacement` maps *physical* expert slots to *logical*
experts.  A logical expert may own several physical slots ("replicas")
living on different EP ranks; the gate splits its traffic across the
replicas round-robin by capacity slot (replica-fractional dispatch), and
the combine gathers each token from the one replica that computed it —
replica outputs never need a cross-replica reduction because every
(token, choice) is routed to exactly one physical slot.  Weight
gradients *are* summed across replicas, for free, by the take-VJP of
the placed-weight gather in ``apply_moe``.

Because replication spreads a hot expert over r ranks, per-slot demand
drops by r and the per-slot capacity can shrink (``cap_frac``): the
dispatch/combine A2A payloads and the pooled FFN all scale with
``n_phys * cap_frac / n_experts`` instead of the inflated uniform
capacity factor a hot expert would otherwise force.

Everything here is static python/numpy — placements are trace-time
constants; only the tiny per-expert lookup tables enter jit.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class ExpertPlacement:
    """An expert -> physical-slot map with optional replication.

    ``assignments[p]`` is the logical expert living in physical slot
    ``p``; with ``R = len(assignments)`` slots and ``n_ep`` EP ranks,
    slot ``p`` lives on rank ``p // (R / n_ep)`` — the same EP-major
    layout ``dump_em``/the dispatch A2A already use for experts, so the
    executor's collectives work on placed buffers unchanged.

    ``cap_frac`` scales the per-physical-slot capacity relative to the
    uniform per-expert capacity (replication lets it shrink);
    ``epoch`` is the rebalance generation stamped into autosched
    decision-cache lines.
    """

    n_experts: int
    n_ep: int
    assignments: tuple
    cap_frac: float = 1.0
    epoch: int = 0

    def __post_init__(self):
        R, E = len(self.assignments), self.n_experts
        if R % self.n_ep:
            raise ValueError(
                f"placement: {R} physical slots not divisible by "
                f"n_ep={self.n_ep}")
        seen = set(self.assignments)
        if seen != set(range(E)):
            missing = sorted(set(range(E)) - seen)
            raise ValueError(
                f"placement: logical experts {missing} have no replica "
                f"(assignments must cover 0..{E - 1})")
        if not (0.0 < self.cap_frac <= 1.0):
            raise ValueError(
                f"placement: cap_frac {self.cap_frac} outside (0, 1]")

    # -- derived tables (python/numpy; trace-time constants) -----------

    @property
    def n_phys(self) -> int:
        """Number of physical expert slots (R >= n_experts)."""
        return len(self.assignments)

    @property
    def is_identity(self) -> bool:
        """True iff this is the uniform no-op placement."""
        return (self.cap_frac == 1.0
                and self.assignments == tuple(range(self.n_experts)))

    @property
    def rep_count(self) -> np.ndarray:
        """(E,) int32 — replica count per logical expert."""
        return np.bincount(np.asarray(self.assignments),
                           minlength=self.n_experts).astype(np.int32)

    @property
    def rep_table(self) -> np.ndarray:
        """(E, max_r) int32 — physical slot ids per logical expert,
        padded with the first replica (padding is never indexed: the
        round-robin replica index is always ``slot % rep_count``)."""
        rc = self.rep_count
        table = np.zeros((self.n_experts, int(rc.max())), np.int32)
        fill = np.zeros(self.n_experts, np.int64)
        for p, e in enumerate(self.assignments):
            table[e, fill[e]] = p
            fill[e] += 1
        for e in range(self.n_experts):            # pad with replica 0
            table[e, fill[e]:] = table[e, 0]
        return table

    @property
    def replica_index(self) -> np.ndarray:
        """(R,) int32 — each physical slot's index among its logical
        expert's replicas (the round-robin phase it serves)."""
        out = np.zeros(self.n_phys, np.int32)
        fill: dict = {}
        for p, e in enumerate(self.assignments):
            out[p] = fill.get(e, 0)
            fill[e] = out[p] + 1
        return out

    def scaled_cap(self, cap: int, align: int = 8) -> int:
        """Per-physical-slot capacity from the uniform per-expert
        capacity ``cap``, shrunk by ``cap_frac`` and aligned up."""
        c = max(1, int(math.ceil(cap * self.cap_frac)))
        return max(align, -(-c // align) * align)

    def pool_scale(self, cap: int, align: int = 8) -> float:
        """Placed capacity-pool size relative to the uniform pool
        (prices FFN flops and etm-sized A2A payloads in ``t_plan``)."""
        if cap <= 0:
            return self.n_phys * self.cap_frac / max(1, self.n_experts)
        return (self.n_phys * self.scaled_cap(cap, align)
                / float(self.n_experts * cap))

    def rank_loads(self, loads: Sequence[float]) -> np.ndarray:
        """(n_ep,) expected load fraction per EP rank under this
        placement: each replica serves ``load_e / rep_count_e``."""
        w = np.asarray(loads, np.float64)
        tot = float(w.sum())
        w = w / tot if tot > 0 else np.full(len(w), 1.0 / max(1, len(w)))
        per_slot = w[np.asarray(self.assignments)] / \
            self.rep_count[np.asarray(self.assignments)]
        return per_slot.reshape(self.n_ep, -1).sum(axis=1)

    def imbalance(self, loads: Sequence[float]) -> float:
        """max-rank load / mean-rank load (1.0 = perfectly balanced);
        the factor by which the most-loaded rank paces every
        load-bound stage."""
        r = self.rank_loads(loads)
        m = float(r.mean())
        return float(r.max()) / m if m > 0 else 1.0

    def summary(self) -> dict:
        """JSON-ready description (dryrun/serve artifacts, logs)."""
        rc = self.rep_count
        return {"n_experts": self.n_experts, "n_ep": self.n_ep,
                "n_phys": self.n_phys, "cap_frac": round(self.cap_frac, 4),
                "epoch": self.epoch,
                "replicated": {int(e): int(r) for e, r in enumerate(rc)
                               if r > 1},
                "assignments": [int(a) for a in self.assignments]}


def identity_placement(n_experts: int, n_ep: int) -> ExpertPlacement:
    """The uniform placement: expert e in slot e, full capacity."""
    return ExpertPlacement(n_experts=n_experts, n_ep=n_ep,
                           assignments=tuple(range(n_experts)))


def placement_from_loads(loads: Sequence[float], n_ep: int, *,
                         n_experts: Optional[int] = None,
                         capacity_factor: float = 1.0,
                         top_k: int = 1,
                         max_replicas: Optional[int] = None,
                         hot_threshold: float = 1.5,
                         slack: float = 1.25,
                         min_cap_frac: float = 0.05,
                         epoch: int = 0) -> ExpertPlacement:
    """Build a replication placement from a (possibly EMA'd) per-expert
    load vector.

    Experts whose load share exceeds ``hot_threshold`` x uniform get
    replicas roughly proportional to their share (capped at
    ``max_replicas``, default ``n_ep``); replica slots are packed onto
    EP ranks greedily by per-replica load (LPT), spreading replicas of
    the same expert across distinct ranks.  ``cap_frac`` is then sized
    so the hottest per-replica demand fits with ``slack`` headroom:
    ``cap_frac = slack * E * max_e(w_e / r_e) / (capacity_factor)``.

    Degenerate inputs (all-zero loads, ``n_ep == 1``) return the
    identity placement.
    """
    w = np.asarray(loads, np.float64)
    E = int(n_experts if n_experts is not None else len(w))
    if len(w) != E:
        raise ValueError(f"loads length {len(w)} != n_experts {E}")
    tot = float(w.sum())
    if n_ep <= 1 or tot <= 0 or E < n_ep:
        # identity slots = E, which must divide into n_ep ranks; when it
        # can't (E < n_ep), report the EP-free identity instead
        return identity_placement(
            E, n_ep if n_ep >= 1 and E % n_ep == 0 else 1)
    w = w / tot
    rmax = int(max_replicas) if max_replicas else n_ep
    # replicas ~ load share in units of the uniform share 1/E
    share = w * E
    reps = np.ones(E, np.int64)
    hot = share >= hot_threshold
    reps[hot] = np.clip(np.rint(share[hot]).astype(np.int64), 2, rmax)
    # pad R up to a multiple of n_ep by replicating whichever expert has
    # the highest remaining per-replica load (also improves balance)
    R = int(reps.sum())
    R_target = -(-R // n_ep) * n_ep
    while R < R_target:
        per = np.where(reps < rmax, w / reps, -1.0)
        e = int(per.argmax())
        if per[e] <= 0:                      # everything at rmax: pad coldest
            e = int((w / reps).argmin())
        reps[e] += 1
        R += 1
    # LPT pack replica units onto ranks (R/n_ep slots each), preferring
    # ranks that do not already hold a replica of the same expert
    slots_per_rank = R // n_ep
    units = sorted(((float(w[e] / reps[e]), e, j)
                    for e in range(E) for j in range(int(reps[e]))),
                   key=lambda u: (-u[0], u[1], u[2]))
    rank_load = np.zeros(n_ep, np.float64)
    rank_fill: list = [[] for _ in range(n_ep)]
    for load, e, _ in units:
        cands = [r for r in range(n_ep) if len(rank_fill[r]) < slots_per_rank]
        fresh = [r for r in cands if e not in rank_fill[r]]
        pool = fresh or cands
        r = min(pool, key=lambda r: (rank_load[r], r))
        rank_fill[r].append(e)
        rank_load[r] += load
    assignments = tuple(e for r in range(n_ep) for e in sorted(rank_fill[r]))
    # capacity fraction: hottest per-replica demand, relative to the
    # uniform per-expert capacity (which holds capacity_factor/E of the
    # pool's token-choices), with slack headroom
    peak = float((w / reps).max())
    cap_frac = slack * E * peak / max(capacity_factor, 1e-6)
    cap_frac = float(np.clip(cap_frac, min_cap_frac, 1.0))
    if R == E and cap_frac >= 1.0:
        # no replication and no capacity shrink: a bare permutation of
        # experts over ranks moves no work, so report uniform — this is
        # what lets maybe_rebalance fall back once loads even out
        return identity_placement(E, n_ep)
    p = ExpertPlacement(n_experts=E, n_ep=n_ep, assignments=assignments,
                        cap_frac=cap_frac, epoch=epoch)
    return identity_placement(E, n_ep) if p.is_identity else p


class LoadEMA:
    """Running exponential moving average of the per-expert load vector
    (the ``expert_load`` gate aux), collected each train step / decode
    round.  Pure numpy on host — feeds ``placement_from_loads`` and the
    ``load_imbalance`` history scalar."""

    def __init__(self, decay: float = 0.9):
        self.decay = float(decay)
        self.steps = 0
        self._v: Optional[np.ndarray] = None

    def update(self, loads) -> None:
        v = np.asarray(loads, np.float64).reshape(-1)
        if v.size == 0 or not np.all(np.isfinite(v)):
            return
        if self._v is None or self._v.shape != v.shape:
            self._v = v.copy()
        else:
            self._v = self.decay * self._v + (1.0 - self.decay) * v
        self.steps += 1

    @property
    def ready(self) -> bool:
        return self._v is not None and self.steps > 0

    def value(self) -> np.ndarray:
        """Current EMA vector ((0,) before any update)."""
        return np.zeros((0,)) if self._v is None else self._v.copy()

    def imbalance(self) -> float:
        """max / mean of the EMA (expert-level skew; 1.0 = uniform)."""
        if self._v is None or self._v.size == 0:
            return 1.0
        m = float(self._v.mean())
        return float(self._v.max()) / m if m > 0 else 1.0
