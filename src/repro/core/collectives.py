"""Parm's communication primitives as jax.lax collectives (shard_map-side).

The paper's EP&ESP-AlltoAll (§III-C) is one AlltoAll over the *combined*
EP x ESP device set, preceded by a local Dump (virtual duplication of the
dispatch buffer, one copy per expert shard) and followed — on the return
trip — by a local Combine that sums the ESP shards' partial outputs.
JAX expresses this directly: ``lax.all_to_all`` accepts a tuple of axis
names and XLA lowers it to a single fused all-to-all over the combined
group, which is what gives the simultaneous use of intra- and inter-node
links the paper argues for (Fig. 4c/d).

Buffer layout convention: combined-group send/recv buffers are
(G, El, c, M) where G = N_EP * N_ESP is ordered EP-major / ESP-minor —
matching ``lax.axis_index((ep, esp))`` — El = E / N_EP local experts,
and c is the per-source capacity.

SAA (§III-D, Fig. 5) — the simultaneous AlltoAll + AllGather used by S2 —
is re-expressed for TPU: instead of NCCL send/recv on multiple CUDA
streams, we chunk the combine AlltoAll and issue each chunk's
MP-AllGather as soon as that chunk lands.  The chunks are independent
ops in HLO, so the TPU async-collective (latency-hiding) scheduler can
overlap the AllGather of chunk i with the AlltoAll of chunk i+1.  (The
chunk-pipelined schedule bodies in ``repro.core.pipeline`` extend this
same trick across each whole schedule.)

Wire precision (§Perf, MegaScale-MoE-style): every bit-moving collective
here has a ``wire_*`` twin that ships its payload in
``CommConfig.wire_dtype`` (f32 passthrough, bf16 cast, or fp8_e4m3 with
per-chunk absmax scales piggybacked on the same collective) and runs the
backward collective in the same wire dtype.  See the block comment above
:class:`CommConfig`.

The pure layout primitives (``dump``/``undump_reduce`` and their
expert-major ``*_em`` twins) are plain array reshapes usable outside any
mesh; their docstring examples run under
``python -m doctest src/repro/core/collectives.py``.  The functions that
issue ``lax`` collectives (``mp_split``, ``mp_all_gather``,
``ep_all_to_all``, ``ep_esp_all_to_all``, ``saa_combine_allgather`` and
their ``wire_*`` twins) must be called from inside a shard_map body with
the named axes bound.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core.perfmodel import WIRE_BYTES, WIRE_DTYPES  # noqa: F401


# --- wire precision (comm dtype) ---------------------------------------------
# Parm's schedules shrink *how many* elements the MP+EP+ESP collectives
# move; the wire format shrinks *bytes per element* — the one lever the
# schedules cannot touch.  Every bit-moving collective (the dispatch and
# combine AlltoAlls, the output MP-AllGathers, the SAA chunks) can ship
# its payload encoded as bf16 (plain cast) or fp8_e4m3 (per-chunk absmax
# scale + cast, the scale bits piggybacked on the same collective).  Two
# collectives are deliberately exempt and stay at compute width:
#
#   * the baseline's pre-gate ESP-AllGather — rounding it would change
#     the gate's logits and therefore routing; wire precision must leave
#     expert_idx/slot_idx bit-identical (tests/test_comm_precision.py);
#   * the baseline's ESP-AllReduce — its summation happens in-network,
#     so there is no decode point before the arithmetic.
#
# Gradients: the transposed collective in the backward pass uses the
# same wire dtype (bf16 falls out of plain autodiff through the casts;
# fp8 uses an explicit custom_vjp that re-encodes the cotangent with a
# fresh absmax scale, since gradient magnitudes differ from activations).

@dataclass(frozen=True)
class CommConfig:
    """Wire format for the MoE collectives.

    ``wire_dtype``: ``"f32"`` (no compression), ``"bf16"``,
    ``"fp8_e4m3"``, or ``"auto"`` (the autoscheduler picks per layer
    shape — resolved to a concrete dtype before any collective runs).
    ``scaling`` applies to fp8 only: ``"per_chunk"`` rescales each
    M-row by its absmax (recommended); ``"none"`` casts directly and
    saturates at ±448.
    """

    wire_dtype: str = "f32"
    scaling: str = "per_chunk"

    def __post_init__(self):
        if self.wire_dtype not in WIRE_DTYPES + ("auto",):
            raise ValueError(f"unknown wire_dtype {self.wire_dtype!r}, "
                             f"want one of {WIRE_DTYPES + ('auto',)}")
        if self.scaling not in ("none", "per_chunk"):
            raise ValueError(f"unknown scaling {self.scaling!r}")


_FP8_MAX = 448.0   # largest finite float8_e4m3fn value
_SCALE_TAIL = 4    # fp8 payload rows carry their f32 scale as 4 extra bytes

# --- fp8 wire overflow monitoring / fault injection ---------------------------
# The guard rails (repro.runtime.guards) install a monitor callback that
# accumulates (saturating, total) element counts from every fp8 encode;
# the fault harness (repro.runtime.faults) can shrink the scales so
# payloads saturate on demand.  Both are trace-time gated: with the
# defaults (None / 0.0) wire_encode compiles to exactly the pre-existing
# program — production traces carry zero monitoring overhead.

_FP8_MONITOR = None      # callable(sat_count, n_elements) or None
_FP8_SAT_INJECT = 0.0    # scale-shrink factor (0.0 = off)


def set_fp8_monitor(cb) -> None:
    """Install (or clear, with None) the process-wide fp8 saturation
    monitor.  Affects traces built afterwards."""
    global _FP8_MONITOR
    _FP8_MONITOR = cb


def set_fp8_sat_injection(factor: float) -> None:
    """Shrink fp8 wire-encode scales by ``factor`` so payloads saturate
    (deterministic overflow injection); 0.0 disables."""
    global _FP8_SAT_INJECT
    _FP8_SAT_INJECT = float(factor)


def _emit_sat(ctx, sat, total) -> None:
    # runtime-checked too: a trace built while monitoring can outlive
    # disable_fp8_monitor(); stale callbacks must be harmless.
    if _FP8_MONITOR is not None:
        _FP8_MONITOR(int(sat), int(total))
    if int(sat):
        # telemetry: saturation events land in the metrics stream with
        # the trace-time tags frozen into this callback (e.g. which MoE
        # layer this encode belongs to) plus the live runtime context
        # (e.g. the current train step) merged in by obs.emit.
        obs.emit("fp8_sat", sat=int(sat), total=int(total), **ctx)


def _monitor_sat(vals) -> None:
    """Count saturating/non-finite elements of a pre-cast fp8 payload
    into the installed monitor and/or the obs event sink (trace-time
    no-op when neither is active)."""
    if _FP8_MONITOR is None and not obs.enabled():
        return
    sat = jnp.sum((~jnp.isfinite(vals)) | (jnp.abs(vals) > _FP8_MAX))
    jax.debug.callback(functools.partial(_emit_sat, obs.trace_context()),
                       sat, vals.size)


def _fp8_dtype():
    if not hasattr(jnp, "float8_e4m3fn"):  # pragma: no cover - old jax
        raise NotImplementedError(
            "this jax build has no float8_e4m3fn; use wire_dtype='bf16'")
    return jnp.float8_e4m3fn


def _active(comm) -> str:
    wd = getattr(comm, "wire_dtype", "f32") if comm is not None else "f32"
    if wd == "auto":
        raise ValueError("CommConfig.wire_dtype='auto' must be resolved "
                         "(autosched.decide) before reaching a collective")
    return wd


def wire_encode(x, comm: CommConfig | None):
    """Encode ``x`` into its wire format (ready for a bit-moving
    collective).  f32 is the identity; bf16 a cast; fp8_e4m3 a per-row
    (absmax over the trailing M dim) scale + cast with the f32 scale
    bitcast into ``_SCALE_TAIL`` extra fp8 elements appended along M —
    so the scales ride the *same* collective as the payload."""
    wd = _active(comm)
    if wd == "f32":
        return x
    if wd == "bf16":
        return x.astype(jnp.bfloat16)
    f8 = _fp8_dtype()
    xf = x.astype(jnp.float32)
    if comm.scaling == "none":
        # e4m3fn has no inf: clamp so out-of-range casts saturate at
        # +-448 instead of producing NaN payloads.
        _monitor_sat(xf)
        return jnp.clip(xf, -_FP8_MAX, _FP8_MAX).astype(f8)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = lax.stop_gradient(jnp.maximum(amax, 1e-30) / _FP8_MAX)
    if _FP8_SAT_INJECT:
        scale = scale / _FP8_SAT_INJECT
    ratio = xf / scale
    _monitor_sat(ratio)
    # clip is the identity for in-range values (absmax scaling keeps
    # |ratio| <= 448 exactly) and turns injected/overflowed values into
    # saturated-but-finite payloads the monitor has already counted.
    payload = jnp.clip(ratio, -_FP8_MAX, _FP8_MAX).astype(f8)
    sbits = lax.bitcast_convert_type(        # (..., 1) f32 -> (..., 1, 4) u8
        lax.bitcast_convert_type(scale, jnp.uint8), f8)
    return jnp.concatenate(
        [payload, sbits.reshape(sbits.shape[:-2] + (_SCALE_TAIL,))], axis=-1)


def wire_decode(w, comm: CommConfig | None, out_dtype):
    """Invert :func:`wire_encode` after the collective has moved ``w``
    (bit-preserving, so the piggybacked fp8 scales decode exactly)."""
    wd = _active(comm)
    if wd in ("f32", "bf16"):
        return w.astype(out_dtype)
    if comm.scaling == "none":
        return w.astype(out_dtype)
    payload, sb = w[..., :-_SCALE_TAIL], w[..., -_SCALE_TAIL:]
    scale = lax.bitcast_convert_type(
        lax.bitcast_convert_type(
            sb.reshape(sb.shape[:-1] + (1, _SCALE_TAIL)), jnp.uint8),
        jnp.float32)
    return (payload.astype(jnp.float32) * scale).astype(out_dtype)


def _wire_moved(x, move, comm, *, bwd_move=None, bwd_post=None):
    """Run a bit-moving collective ``move`` in the wire format, with the
    backward collective in the same wire dtype.

    f32 runs ``move`` raw; bf16 composes casts (autodiff then transposes
    the collective on the bf16 cotangent for free).  fp8 needs a
    custom_vjp: the cotangent's dynamic range differs from the forward
    activations', so the backward pass re-encodes it with its own
    absmax scales, moves it through ``bwd_move`` (default: ``move``,
    correct for the self-transposing split==concat AlltoAlls), decodes,
    and applies ``bwd_post`` (the local reduction the true transpose of
    an AllGather needs).
    """
    wd = _active(comm)
    if wd in ("f32", "bf16"):
        # plain composition: vjp of the casts + collective is the
        # transposed collective over the same wire dtype.
        return wire_decode(move(wire_encode(x, comm)), comm, x.dtype)

    dtype = x.dtype

    def run(v):
        return wire_decode(move(wire_encode(v, comm)), comm, dtype)

    @jax.custom_vjp
    def wired(v):
        return run(v)

    def fwd(v):
        return run(v), None

    def bwd(_, g):
        mv = bwd_move or move
        gd = wire_decode(mv(wire_encode(g, comm)), comm, dtype)
        return ((bwd_post(gd) if bwd_post is not None else gd),)

    wired.defvjp(fwd, bwd)
    return wired(x)


def wire_raw_ok(comm) -> bool:
    """True when the wire format is a plain dtype view (f32 identity or
    bf16 cast) — the payload can then stay *encoded* across a fused
    kernel boundary (the grouped megakernel decodes in its prologue and
    re-encodes in its epilogue).  fp8 cannot: its piggybacked scale tail
    changes the M dim, so it always decodes at the collective."""
    return _active(comm) in ("f32", "bf16")


def wire_roundtrip(x, comm=None):
    """Encode-then-decode with no movement: the local stand-in for a
    wire-format collective on a single-member group (the fused grouped
    path composes this around the expert FFN when the wire dtype needs
    a real codec, e.g. fp8)."""
    return _wire_moved(x, lambda v: v, comm)


def _axes(axes):
    """Normalize an axis spec (name or iterable of names) to a tuple.

    >>> _axes("model")
    ('model',)
    >>> _axes(("ep", "esp"))
    ('ep', 'esp')
    """
    return (axes,) if isinstance(axes, str) else tuple(axes)


# --- PauseMP primitives ------------------------------------------------------

def mp_split(x, mp_axes, n_mp: int, axis: int = 0):
    """MP-Split: take this MP rank's 1/N_MP slice along ``axis``.

    The forward pass is free (a dynamic slice); its transpose is an
    all-gather, as the paper notes for Split ops.  Must run inside a
    shard_map body with ``mp_axes`` bound (it reads ``lax.axis_index``);
    ``n_mp == 1`` is an identity and needs no mesh.
    """
    if n_mp == 1:
        return x
    idx = lax.axis_index(_axes(mp_axes))
    size = x.shape[axis] // n_mp
    return lax.dynamic_slice_in_dim(x, idx * size, size, axis)


def mp_all_gather(x, mp_axes, n_mp: int, axis: int = 0):
    """MP-AllGather: restore the full dim along ``axis`` (the transpose of
    :func:`mp_split`; a tiled ``lax.all_gather`` over ``mp_axes``)."""
    if n_mp == 1:
        return x
    return lax.all_gather(x, _axes(mp_axes), axis=axis, tiled=True)


# --- EP&ESP-AlltoAll ---------------------------------------------------------

def dump(d, n_ep: int, n_esp: int):
    """Local Dump (Fig. 4c): lay out the dispatch buffer for the combined
    AlltoAll, virtually duplicating each expert's tokens once per shard.

    d: (E, c, M) -> (G, El, c, M); destination g = (i', j') receives the
    tokens of experts owned by EP rank i' (identical for every shard j').
    G is EP-major / ESP-minor, matching ``lax.axis_index((ep, esp))``:

    >>> d = jnp.array([[[1.]], [[2.]]])            # (E=2, c=1, M=1)
    >>> dump(d, n_ep=2, n_esp=2).shape             # G=4, El=1
    (4, 1, 1, 1)
    >>> dump(d, n_ep=2, n_esp=2)[:, 0, 0, 0].tolist()
    [1.0, 1.0, 2.0, 2.0]
    """
    E, c, M = d.shape
    El = E // n_ep
    out = d.reshape(n_ep, 1, El, c, M)
    out = jnp.broadcast_to(out, (n_ep, n_esp, El, c, M))
    return out.reshape(n_ep * n_esp, El, c, M)


def undump_reduce(r, n_ep: int, n_esp: int):
    """Local Combine (Fig. 4d): sum the N_ESP shards' partial outputs.

    r: (G, El, c, M) returned partials -> (E, c, M) full outputs in the
    original dispatch-buffer layout.  The inverse of :func:`dump` up to
    the ESP reduction — each expert's slot sums its n_esp partials:

    >>> r = jnp.arange(1., 5.).reshape(4, 1, 1, 1)  # (G=4, El=1, c=1, M=1)
    >>> undump_reduce(r, n_ep=2, n_esp=2)[:, 0, 0].tolist()
    [3.0, 7.0]
    """
    G, El, c, M = r.shape
    r = r.reshape(n_ep, n_esp, El, c, M).sum(axis=1)
    return r.reshape(n_ep * El, c, M)


def to_expert_batch(rb):
    """(G, El, c, M) received buffer -> (El, G*c, M) per-expert token batch.

    Costs a full-buffer G<->El transpose (XLA materializes it); the
    expert-major ``*_em`` twins below avoid that.

    >>> rb = jnp.arange(6.).reshape(3, 1, 2, 1)    # (G=3, El=1, c=2, M=1)
    >>> to_expert_batch(rb).shape
    (1, 6, 1)
    """
    G, El, c, M = rb.shape
    return rb.transpose(1, 0, 2, 3).reshape(El, G * c, M)


def from_expert_batch(h, G: int):
    """(El, G*c, M) expert outputs -> (G, El, c, M) return buffer (the
    exact inverse of :func:`to_expert_batch`).

    >>> rb = jnp.arange(6.).reshape(3, 1, 2, 1)
    >>> bool((from_expert_batch(to_expert_batch(rb), G=3) == rb).all())
    True
    """
    El, Gc, M = h.shape
    c = Gc // G
    return h.reshape(El, G, c, M).transpose(1, 0, 2, 3)


def ep_esp_all_to_all(x, ep_axes, esp_axes, *, split_axis=0, concat_axis=0):
    """One fused AlltoAll over the combined (EP, ESP) group (§III-C).

    ``lax.all_to_all`` with a tuple of axis names lowers to a single
    all-to-all over the combined device set, which is what exploits the
    intra- and inter-node links simultaneously (paper Fig. 4c/d).
    Shard_map-only (needs both axis groups bound).
    """
    ep, esp = _axes(ep_axes), _axes(esp_axes)
    names = ep + tuple(a for a in esp if a not in ep)
    return lax.all_to_all(x, names, split_axis, concat_axis, tiled=True)


def ep_all_to_all(x, ep_axes, *, split_axis=0, concat_axis=0):
    """Plain EP-AlltoAll over the EP axes only (baseline schedule).
    Shard_map-only."""
    return lax.all_to_all(x, _axes(ep_axes), split_axis, concat_axis,
                          tiled=True)


def hier_ep_esp_all_to_all(x, ep_axes, esp_axes, n_ep: int, n_esp: int, *,
                           axis=1, order: str = "esp_first"):
    """Hierarchical EP&ESP-AlltoAll: two sequential hops instead of one
    fused collective (MegaScale-MoE-style, the s2h schedule).

    ``x`` carries the combined-group dim ``G = n_ep * n_esp`` (EP-major /
    ESP-minor, matching ``lax.axis_index((ep, esp))``) at ``axis``.  The
    dim is viewed as ``(n_ep, n_esp)`` and exchanged with one AlltoAll
    over the ESP axes (intra-group: the fast, intra-node links on a
    production mesh) and one over the EP axes (inter-group: the slow
    links) — in either ``order``.  Both orders produce *bitwise* the
    fused :func:`ep_esp_all_to_all` result: writing the source buffer as
    ``S[(i,j)][a,b]`` (rank (i,j)'s block destined for rank (a,b)), the
    ESP hop yields ``T[(i,j)][a,j'] = S[(i,j')][a,j]`` and the EP hop
    then ``U[(i,j)][i',j'] = S[(i',j')][i,j]`` — exactly the fused
    AlltoAll's delivery — and the two hops commute.

    The decomposition buys nothing by itself; the win is that the hops
    of *different* capacity chunks are independent HLO ops, so a chunk
    running ``esp_first`` overlaps its intra-node hop with another
    chunk's inter-node hop (``plan.split_capacity`` alternates the order
    per chunk for s2h).  Shard_map-only.
    """
    if order not in ("esp_first", "ep_first"):
        raise ValueError(f"unknown hier order {order!r}")
    shp = x.shape
    x5 = x.reshape(shp[:axis] + (n_ep, n_esp) + shp[axis + 1:])
    ep_dim, esp_dim = axis, axis + 1

    def hop(v, names, dim):
        return lax.all_to_all(v, _axes(names), dim, dim, tiled=True)

    if order == "esp_first":
        x5 = hop(x5, esp_axes, esp_dim)
        x5 = hop(x5, ep_axes, ep_dim)
    else:
        x5 = hop(x5, ep_axes, ep_dim)
        x5 = hop(x5, esp_axes, esp_dim)
    return x5.reshape(shp)


# --- wire-format collective entry points -------------------------------------
# The schedule bodies call these instead of the raw collectives above;
# with the default CommConfig (f32) they are byte-identical passthroughs.

def wire_ep_esp_all_to_all(x, ep_axes, esp_axes, comm=None, *,
                           split_axis=0, concat_axis=0):
    """:func:`ep_esp_all_to_all` with the payload in ``comm``'s wire
    dtype (backward AlltoAll in the same dtype).  Requires
    ``split_axis == concat_axis`` so the collective is its own
    transpose — true of every schedule call site."""
    assert split_axis == concat_axis, "wire a2a must be self-transposing"

    def move(w):
        return ep_esp_all_to_all(w, ep_axes, esp_axes,
                                 split_axis=split_axis,
                                 concat_axis=concat_axis)

    return _wire_moved(x, move, comm)


def wire_ep_all_to_all(x, ep_axes, comm=None, *, split_axis=0,
                       concat_axis=0):
    """:func:`ep_all_to_all` in the wire format (baseline schedule)."""
    assert split_axis == concat_axis, "wire a2a must be self-transposing"

    def move(w):
        return ep_all_to_all(w, ep_axes, split_axis=split_axis,
                             concat_axis=concat_axis)

    return _wire_moved(x, move, comm)


def wire_hier_ep_esp_all_to_all(x, ep_axes, esp_axes, n_ep: int,
                                n_esp: int, comm=None, *, axis=1,
                                order: str = "esp_first"):
    """:func:`hier_ep_esp_all_to_all` in the wire format: one encode
    before the first hop, one decode after the second, so *both* hops
    ship compressed payload (and the fp8 scales ride both collectives).
    The two-hop composition equals the fused AlltoAll in either order,
    hence is self-transposing — the backward pass reuses the same move."""

    def move(w):
        return hier_ep_esp_all_to_all(w, ep_axes, esp_axes, n_ep, n_esp,
                                      axis=axis, order=order)

    return _wire_moved(x, move, comm)


def wire_mp_all_gather(x, mp_axes, n_mp: int, comm=None, axis: int = 0):
    """:func:`mp_all_gather` in the wire format.

    Only for *post-combine output* gathers (S1's exit AllGather, the
    baseline's would-be output path): the transpose of a tiled
    AllGather is a reduce-scatter, realized for the fp8 backward as an
    AlltoAll over the gathered dim followed by a local sum — so the
    summation happens at full precision *after* decode.
    """
    if n_mp == 1:
        return x

    def move(w):
        return lax.all_gather(w, _axes(mp_axes), axis=axis, tiled=True)

    def bwd_move(w):
        return lax.all_to_all(w, _axes(mp_axes), axis, axis, tiled=True)

    def bwd_post(g):
        s = g.shape
        g = g.reshape(s[:axis] + (n_mp, s[axis] // n_mp) + s[axis + 1:])
        return g.sum(axis=axis)

    return _wire_moved(x, move, comm, bwd_move=bwd_move, bwd_post=bwd_post)


def wire_all_gather_stacked(x, mp_axes, n_mp: int, comm=None,
                            axis: int = 1):
    """Untiled (stacking) AllGather in the wire format — the SAA /
    ``s2_pipe`` per-chunk MP-AllGather, which inserts a new group dim at
    ``axis``.  fp8 backward: AlltoAll over the group dim, decode, sum."""

    def move(w):
        return lax.all_gather(w, _axes(mp_axes), axis=axis, tiled=False)

    def bwd_move(w):
        return lax.all_to_all(w, _axes(mp_axes), axis, axis, tiled=True)

    def bwd_post(g):
        return g.sum(axis=axis)

    return _wire_moved(x, move, comm, bwd_move=bwd_move, bwd_post=bwd_post)


# --- expert-major buffer layout (§Perf A2) -----------------------------------
# The (G, El, c, M) layout forces a G<->El transpose of the full combined
# buffer on each side of the AlltoAll (XLA materializes it).  Keeping El
# leading — (El, G, c, M), AlltoAll over split_axis=1 — makes the
# expert-batch view a free reshape; only the Ns-times-smaller (E, c, M)
# pre-dump buffer is ever transposed.

def dump_em(d, n_ep: int, n_esp: int):
    """Dump in expert-major layout: (E, c, M) -> (El, G, c, M).

    Same virtual duplication as :func:`dump`, but the local-expert dim
    leads so the AlltoAll runs over ``split_axis=1`` and the expert-batch
    view is a free reshape:

    >>> d = jnp.array([[[1.]], [[2.]]])            # (E=2, c=1, M=1)
    >>> dump_em(d, n_ep=2, n_esp=2).shape          # (El=1, G=4, c=1, M=1)
    (1, 4, 1, 1)
    >>> dump_em(d, n_ep=2, n_esp=2)[0, :, 0, 0].tolist()
    [1.0, 1.0, 2.0, 2.0]
    """
    E, c, M = d.shape
    El = E // n_ep
    out = d.reshape(n_ep, El, c, M).transpose(1, 0, 2, 3)   # (El, Ne, c, M)
    out = jnp.broadcast_to(out[:, :, None], (El, n_ep, n_esp, c, M))
    return out.reshape(El, n_ep * n_esp, c, M)


def undump_reduce_em(r, n_ep: int, n_esp: int):
    """(El, G, c, M) returned partials -> (E, c, M), summing ESP shards
    (the expert-major twin of :func:`undump_reduce`).

    >>> r = jnp.arange(1., 5.).reshape(1, 4, 1, 1)  # (El=1, G=4, c=1, M=1)
    >>> undump_reduce_em(r, n_ep=2, n_esp=2)[:, 0, 0].tolist()
    [3.0, 7.0]
    """
    El, G, c, M = r.shape
    r = r.reshape(El, n_ep, n_esp, c, M).sum(axis=2)        # (El, Ne, c, M)
    return r.transpose(1, 0, 2, 3).reshape(n_ep * El, c, M)


def to_expert_batch_em(rb):
    """(El, G, c, M) -> (El, G*c, M): free reshape (no relayout).

    >>> to_expert_batch_em(jnp.zeros((2, 3, 4, 5))).shape
    (2, 12, 5)
    """
    El, G, c, M = rb.shape
    return rb.reshape(El, G * c, M)


def from_expert_batch_em(h, G: int):
    """(El, G*c, M) -> (El, G, c, M): free reshape (inverse of
    :func:`to_expert_batch_em`).

    >>> from_expert_batch_em(jnp.zeros((2, 12, 5)), G=3).shape
    (2, 3, 4, 5)
    """
    El, Gc, M = h.shape
    return h.reshape(El, G, Gc // G, M)


# --- SAA: simultaneous AlltoAll + AllGather (S2 combine path) ---------------

def saa_combine_allgather(y, ep_axes, esp_axes, mp_axes, *, n_ep: int,
                          n_esp: int, n_mp: int, n_chunks: int = 4,
                          comm: CommConfig | None = None):
    """Chunked overlap of the combine EP&ESP-AlltoAll with MP-AllGather.

    y: (El, G, c, M) partial outputs headed back to their source ranks
    (expert-major layout, §Perf A2).  Returns (E, c * N_MP, M): combined
    outputs with the full capacity dim restored across the MP group,
    slot-ordered (mp_rank, slot) to match the pre-split dispatch buffer.
    Both per-chunk collectives (the AlltoAll and the AllGather) ship in
    ``comm``'s wire dtype.
    """
    El, G, c, M = y.shape
    n_chunks = max(1, min(n_chunks, c))
    while c % n_chunks:
        n_chunks -= 1
    cs = c // n_chunks
    E = n_ep * El
    parts = []
    for i in range(n_chunks):
        chunk = lax.slice_in_dim(y, i * cs, (i + 1) * cs, axis=2)
        back = wire_ep_esp_all_to_all(chunk, ep_axes, esp_axes, comm,
                                      split_axis=1, concat_axis=1)
        comb = undump_reduce_em(back, n_ep, n_esp)              # (E, cs, M)
        if n_mp == 1:
            parts.append(comb[:, None])                         # (E, 1, cs, M)
        else:
            # untiled gather -> explicit (E, N_MP, cs, M) so chunk order can
            # be restored to (mp_rank, chunk, slot) below.
            parts.append(wire_all_gather_stacked(comb, mp_axes, n_mp,
                                                 comm, axis=1))
    stacked = jnp.stack(parts, axis=2)                # (E, N_MP, n_chunks, cs, M)
    return stacked.reshape(E, n_mp * c, M)
