"""Parm's communication primitives as jax.lax collectives (shard_map-side).

The paper's EP&ESP-AlltoAll (§III-C) is one AlltoAll over the *combined*
EP x ESP device set, preceded by a local Dump (virtual duplication of the
dispatch buffer, one copy per expert shard) and followed — on the return
trip — by a local Combine that sums the ESP shards' partial outputs.
JAX expresses this directly: ``lax.all_to_all`` accepts a tuple of axis
names and XLA lowers it to a single fused all-to-all over the combined
group, which is what gives the simultaneous use of intra- and inter-node
links the paper argues for (Fig. 4c/d).

Buffer layout convention: combined-group send/recv buffers are
(G, El, c, M) where G = N_EP * N_ESP is ordered EP-major / ESP-minor —
matching ``lax.axis_index((ep, esp))`` — El = E / N_EP local experts,
and c is the per-source capacity.

SAA (§III-D, Fig. 5) — the simultaneous AlltoAll + AllGather used by S2 —
is re-expressed for TPU: instead of NCCL send/recv on multiple CUDA
streams, we chunk the combine AlltoAll and issue each chunk's
MP-AllGather as soon as that chunk lands.  The chunks are independent
ops in HLO, so the TPU async-collective (latency-hiding) scheduler can
overlap the AllGather of chunk i with the AlltoAll of chunk i+1.  (The
chunk-pipelined schedule bodies in ``repro.core.pipeline`` extend this
same trick across each whole schedule.)

The pure layout primitives (``dump``/``undump_reduce`` and their
expert-major ``*_em`` twins) are plain array reshapes usable outside any
mesh; their docstring examples run under
``python -m doctest src/repro/core/collectives.py``.  The functions that
issue ``lax`` collectives (``mp_split``, ``mp_all_gather``,
``ep_all_to_all``, ``ep_esp_all_to_all``, ``saa_combine_allgather``)
must be called from inside a shard_map body with the named axes bound.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _axes(axes):
    """Normalize an axis spec (name or iterable of names) to a tuple.

    >>> _axes("model")
    ('model',)
    >>> _axes(("ep", "esp"))
    ('ep', 'esp')
    """
    return (axes,) if isinstance(axes, str) else tuple(axes)


# --- PauseMP primitives ------------------------------------------------------

def mp_split(x, mp_axes, n_mp: int, axis: int = 0):
    """MP-Split: take this MP rank's 1/N_MP slice along ``axis``.

    The forward pass is free (a dynamic slice); its transpose is an
    all-gather, as the paper notes for Split ops.  Must run inside a
    shard_map body with ``mp_axes`` bound (it reads ``lax.axis_index``);
    ``n_mp == 1`` is an identity and needs no mesh.
    """
    if n_mp == 1:
        return x
    idx = lax.axis_index(_axes(mp_axes))
    size = x.shape[axis] // n_mp
    return lax.dynamic_slice_in_dim(x, idx * size, size, axis)


def mp_all_gather(x, mp_axes, n_mp: int, axis: int = 0):
    """MP-AllGather: restore the full dim along ``axis`` (the transpose of
    :func:`mp_split`; a tiled ``lax.all_gather`` over ``mp_axes``)."""
    if n_mp == 1:
        return x
    return lax.all_gather(x, _axes(mp_axes), axis=axis, tiled=True)


# --- EP&ESP-AlltoAll ---------------------------------------------------------

def dump(d, n_ep: int, n_esp: int):
    """Local Dump (Fig. 4c): lay out the dispatch buffer for the combined
    AlltoAll, virtually duplicating each expert's tokens once per shard.

    d: (E, c, M) -> (G, El, c, M); destination g = (i', j') receives the
    tokens of experts owned by EP rank i' (identical for every shard j').
    G is EP-major / ESP-minor, matching ``lax.axis_index((ep, esp))``:

    >>> d = jnp.array([[[1.]], [[2.]]])            # (E=2, c=1, M=1)
    >>> dump(d, n_ep=2, n_esp=2).shape             # G=4, El=1
    (4, 1, 1, 1)
    >>> dump(d, n_ep=2, n_esp=2)[:, 0, 0, 0].tolist()
    [1.0, 1.0, 2.0, 2.0]
    """
    E, c, M = d.shape
    El = E // n_ep
    out = d.reshape(n_ep, 1, El, c, M)
    out = jnp.broadcast_to(out, (n_ep, n_esp, El, c, M))
    return out.reshape(n_ep * n_esp, El, c, M)


def undump_reduce(r, n_ep: int, n_esp: int):
    """Local Combine (Fig. 4d): sum the N_ESP shards' partial outputs.

    r: (G, El, c, M) returned partials -> (E, c, M) full outputs in the
    original dispatch-buffer layout.  The inverse of :func:`dump` up to
    the ESP reduction — each expert's slot sums its n_esp partials:

    >>> r = jnp.arange(1., 5.).reshape(4, 1, 1, 1)  # (G=4, El=1, c=1, M=1)
    >>> undump_reduce(r, n_ep=2, n_esp=2)[:, 0, 0].tolist()
    [3.0, 7.0]
    """
    G, El, c, M = r.shape
    r = r.reshape(n_ep, n_esp, El, c, M).sum(axis=1)
    return r.reshape(n_ep * El, c, M)


def to_expert_batch(rb):
    """(G, El, c, M) received buffer -> (El, G*c, M) per-expert token batch.

    Costs a full-buffer G<->El transpose (XLA materializes it); the
    expert-major ``*_em`` twins below avoid that.

    >>> rb = jnp.arange(6.).reshape(3, 1, 2, 1)    # (G=3, El=1, c=2, M=1)
    >>> to_expert_batch(rb).shape
    (1, 6, 1)
    """
    G, El, c, M = rb.shape
    return rb.transpose(1, 0, 2, 3).reshape(El, G * c, M)


def from_expert_batch(h, G: int):
    """(El, G*c, M) expert outputs -> (G, El, c, M) return buffer (the
    exact inverse of :func:`to_expert_batch`).

    >>> rb = jnp.arange(6.).reshape(3, 1, 2, 1)
    >>> bool((from_expert_batch(to_expert_batch(rb), G=3) == rb).all())
    True
    """
    El, Gc, M = h.shape
    c = Gc // G
    return h.reshape(El, G, c, M).transpose(1, 0, 2, 3)


def ep_esp_all_to_all(x, ep_axes, esp_axes, *, split_axis=0, concat_axis=0):
    """One fused AlltoAll over the combined (EP, ESP) group (§III-C).

    ``lax.all_to_all`` with a tuple of axis names lowers to a single
    all-to-all over the combined device set, which is what exploits the
    intra- and inter-node links simultaneously (paper Fig. 4c/d).
    Shard_map-only (needs both axis groups bound).
    """
    ep, esp = _axes(ep_axes), _axes(esp_axes)
    names = ep + tuple(a for a in esp if a not in ep)
    return lax.all_to_all(x, names, split_axis, concat_axis, tiled=True)


def ep_all_to_all(x, ep_axes, *, split_axis=0, concat_axis=0):
    """Plain EP-AlltoAll over the EP axes only (baseline schedule).
    Shard_map-only."""
    return lax.all_to_all(x, _axes(ep_axes), split_axis, concat_axis,
                          tiled=True)


# --- expert-major buffer layout (§Perf A2) -----------------------------------
# The (G, El, c, M) layout forces a G<->El transpose of the full combined
# buffer on each side of the AlltoAll (XLA materializes it).  Keeping El
# leading — (El, G, c, M), AlltoAll over split_axis=1 — makes the
# expert-batch view a free reshape; only the Ns-times-smaller (E, c, M)
# pre-dump buffer is ever transposed.

def dump_em(d, n_ep: int, n_esp: int):
    """Dump in expert-major layout: (E, c, M) -> (El, G, c, M).

    Same virtual duplication as :func:`dump`, but the local-expert dim
    leads so the AlltoAll runs over ``split_axis=1`` and the expert-batch
    view is a free reshape:

    >>> d = jnp.array([[[1.]], [[2.]]])            # (E=2, c=1, M=1)
    >>> dump_em(d, n_ep=2, n_esp=2).shape          # (El=1, G=4, c=1, M=1)
    (1, 4, 1, 1)
    >>> dump_em(d, n_ep=2, n_esp=2)[0, :, 0, 0].tolist()
    [1.0, 1.0, 2.0, 2.0]
    """
    E, c, M = d.shape
    El = E // n_ep
    out = d.reshape(n_ep, El, c, M).transpose(1, 0, 2, 3)   # (El, Ne, c, M)
    out = jnp.broadcast_to(out[:, :, None], (El, n_ep, n_esp, c, M))
    return out.reshape(El, n_ep * n_esp, c, M)


def undump_reduce_em(r, n_ep: int, n_esp: int):
    """(El, G, c, M) returned partials -> (E, c, M), summing ESP shards
    (the expert-major twin of :func:`undump_reduce`).

    >>> r = jnp.arange(1., 5.).reshape(1, 4, 1, 1)  # (El=1, G=4, c=1, M=1)
    >>> undump_reduce_em(r, n_ep=2, n_esp=2)[:, 0, 0].tolist()
    [3.0, 7.0]
    """
    El, G, c, M = r.shape
    r = r.reshape(El, n_ep, n_esp, c, M).sum(axis=2)        # (El, Ne, c, M)
    return r.transpose(1, 0, 2, 3).reshape(n_ep * El, c, M)


def to_expert_batch_em(rb):
    """(El, G, c, M) -> (El, G*c, M): free reshape (no relayout).

    >>> to_expert_batch_em(jnp.zeros((2, 3, 4, 5))).shape
    (2, 12, 5)
    """
    El, G, c, M = rb.shape
    return rb.reshape(El, G * c, M)


def from_expert_batch_em(h, G: int):
    """(El, G*c, M) -> (El, G, c, M): free reshape (inverse of
    :func:`to_expert_batch_em`).

    >>> from_expert_batch_em(jnp.zeros((2, 12, 5)), G=3).shape
    (2, 3, 4, 5)
    """
    El, Gc, M = h.shape
    return h.reshape(El, G, Gc // G, M)


# --- SAA: simultaneous AlltoAll + AllGather (S2 combine path) ---------------

def saa_combine_allgather(y, ep_axes, esp_axes, mp_axes, *, n_ep: int,
                          n_esp: int, n_mp: int, n_chunks: int = 4):
    """Chunked overlap of the combine EP&ESP-AlltoAll with MP-AllGather.

    y: (El, G, c, M) partial outputs headed back to their source ranks
    (expert-major layout, §Perf A2).  Returns (E, c * N_MP, M): combined
    outputs with the full capacity dim restored across the MP group,
    slot-ordered (mp_rank, slot) to match the pre-split dispatch buffer.
    """
    El, G, c, M = y.shape
    n_chunks = max(1, min(n_chunks, c))
    while c % n_chunks:
        n_chunks -= 1
    cs = c // n_chunks
    E = n_ep * El
    parts = []
    for i in range(n_chunks):
        chunk = lax.slice_in_dim(y, i * cs, (i + 1) * cs, axis=2)
        back = ep_esp_all_to_all(chunk, ep_axes, esp_axes,
                                 split_axis=1, concat_axis=1)
        comb = undump_reduce_em(back, n_ep, n_esp)              # (E, cs, M)
        if n_mp == 1:
            parts.append(comb[:, None])                         # (E, 1, cs, M)
        else:
            # untiled gather -> explicit (E, N_MP, cs, M) so chunk order can
            # be restored to (mp_rank, chunk, slot) below.
            parts.append(lax.all_gather(comb, _axes(mp_axes), axis=1,
                                        tiled=False))
    stacked = jnp.stack(parts, axis=2)                # (E, N_MP, n_chunks, cs, M)
    return stacked.reshape(E, n_mp * c, M)
