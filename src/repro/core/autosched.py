"""The ``schedule="auto"`` runtime: per-layer (schedule, chunks, wire)
decisions.

Parm's Algorithm 1 picks S1 or S2 from the alpha-beta model; the
pipelined bodies (``repro.core.pipeline``) add a second axis — how many
micro-chunks to split the AlltoAll/FFN chain into — and the wire-format
subsystem (``repro.core.collectives.CommConfig``) a third: how many
bytes each element of those collectives puts on the fabric.  This
module owns the joint decision:

  * **analytic** mode enumerates the schedule axis from the *plan
    registry* (``repro.core.plan.PLANS``) and scores every (schedule,
    n_chunks) candidate by walking its plan graph with
    :meth:`repro.core.perfmodel.PerfModel.t_plan` (Algorithm 1's S1/S2
    comparison generalized with the compute-overlap term) — no devices
    touched, fully deterministic under a fixed perf model.
  * **measured** mode runs a one-shot calibration on the live mesh: each
    candidate is jitted and timed on synthetic data of the layer's shape
    (:func:`measure_candidates`), and the observed winner is recorded.

Either way the result is a :class:`ScheduleDecision` cached per
``(MoELayerShape, mode, candidates, perf model)`` — so a training run
decides once per distinct MoE layer shape, every later ``apply_moe``
trace hits the cache, and repeated runs under the same perf model make
identical picks (asserted by ``tests/test_autosched.py``).

``apply_moe`` consults :func:`decide` whenever ``MoEConfig.schedule`` is
``"auto"``; ``launch/train.py --autosched measured`` switches modes from
the command line.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro import obs
from repro.core import plan as planlib
from repro.core.perfmodel import (MoELayerShape, PerfModel, WIRE_BYTES,
                                  tpu_v5e_model)
from repro.core.pipeline import PIPELINE_OF  # populates the plan registry

#: The schedule axis of the candidate grid is the *plan registry*
#: (``repro.core.plan.PLANS``): registering a schedule automatically adds
#: it to the analytic and measured grids per its ``PlanEntry`` flags.
#: ``baseline`` is measured-only (it can win on tiny single-axis meshes,
#: but Algorithm 1 proves S1/S2 dominate it analytically — §IV-B);
#: ``s1_seqpar`` is in neither grid (it needs the sequence-parallel
#: activation contract, so it is only ever forced).
DEFAULT_CHUNKS = (1, 2, 4, 8)
#: wire dtypes scored by default (no compression; the legacy pair grid
#: scores with wire_dtype=None, so decisions match the pre-wire runtime)
DEFAULT_WIRE = ("f32",)
#: candidates when ``CommConfig.wire_dtype == "auto"``.  fp8 is excluded
#: on purpose: the analytic model knows only bytes, so it would always
#: pick the narrowest dtype; fp8's accuracy cost must be opted into
#: explicitly (``wire_dtype="fp8_e4m3"``), never chosen silently.
AUTO_WIRE = ("f32", "bf16")


@dataclass(frozen=True)
class ScheduleDecision:
    """The cached outcome of one auto-scheduling decision.

    ``schedule`` is the base schedule name (``baseline``/``s1``/``s2``),
    ``n_chunks`` the micro-chunk count (1 = unchunked), ``wire_dtype``
    the collective payload width, ``source`` how it was reached
    (``analytic`` / ``measured`` / ``forced``), and ``times`` the scored
    candidates as ``(candidate, seconds)`` pairs sorted fastest-first —
    candidates are ``(schedule, n_chunks)`` pairs under the default
    f32-only wire grid (back-compat) and ``(schedule, n_chunks,
    wire_dtype)`` triples under a joint wire decision.
    """

    schedule: str
    n_chunks: int = 1
    source: str = "analytic"
    times: tuple = ()
    wire_dtype: str = "f32"
    #: the process-wide placement epoch this decision was made under
    #: (see :func:`set_placement`); ``cache_summary`` marks decisions
    #: from an older epoch as stale.
    placement_epoch: int = 0

    @property
    def body_name(self) -> str:
        """The ``schedules.BODY`` key implementing this decision."""
        if self.n_chunks > 1:
            return PIPELINE_OF.get(self.schedule, self.schedule)
        return self.schedule


_CACHE: dict = {}

#: process-wide wire ceiling: fp8 decisions are clamped up to this dtype
#: when set (see :func:`set_wire_ceiling`) — the guard rails' overflow
#: fallback.  None = no clamping (the default).
_WIRE_CEILING = None

#: callbacks fired by :func:`invalidate` (observability for plan swaps)
_INVALIDATION_HOOKS: list = []

#: process-wide expert placement (``repro.core.placement.ExpertPlacement``
#: or None = uniform) consulted by ``apply_moe`` when
#: ``MoEConfig.placement == "auto"``, plus a monotone epoch counter so
#: cached decisions record which placement regime they were made under.
_PLACEMENT = None
_PLACEMENT_EPOCH = 0


def clear_cache() -> None:
    """Drop every cached decision and reset the placement registry
    (tests, or after remeshing)."""
    global _PLACEMENT, _PLACEMENT_EPOCH
    _CACHE.clear()
    _PLACEMENT = None
    _PLACEMENT_EPOCH = 0


def invalidate(reason: str = "", shape=None) -> int:
    """Decision-cache invalidation hook: drop cached decisions and
    notify registered hooks.  Returns the number of entries dropped.

    With ``shape=None`` (the default) every decision is dropped — the
    "cheap plan swap" entry point: after changing something decisions
    depend on outside the cache key (e.g. the wire ceiling), call this
    and re-jit; the retrace re-consults :func:`decide`.  Passing a
    ``MoELayerShape`` drops only that shape's decisions (every mode /
    grid / perf-model variant), leaving other layers' lines warm.
    """
    if shape is None:
        n = len(_CACHE)
        _CACHE.clear()
    else:
        drop = [k for k in _CACHE if k[0] == shape]
        for k in drop:
            del _CACHE[k]
        n = len(drop)
    for cb in list(_INVALIDATION_HOOKS):
        cb(reason, n)
    obs.emit("autosched_invalidate", reason=reason, dropped=n)
    return n


def set_placement(placement) -> int:
    """Install ``placement`` (an ``ExpertPlacement`` or None = uniform)
    as the process-wide expert placement and bump the placement epoch.

    The decision cache is deliberately NOT flushed — already-jitted
    steps keep running their traced plans (no re-jit churn); the epoch
    is part of every new :func:`decide` cache key, so the *next* re-jit
    (the caller's choice of moment, e.g. ``Trainer``'s rebalance
    trigger) re-decides under the new placement while
    :func:`cache_summary` marks the old lines stale in the meantime.
    Returns the new epoch.
    """
    global _PLACEMENT, _PLACEMENT_EPOCH
    _PLACEMENT = placement
    _PLACEMENT_EPOCH += 1
    obs.emit("placement_epoch", epoch=_PLACEMENT_EPOCH,
             uniform=placement is None,
             n_phys=getattr(placement, "n_phys", None),
             cap_frac=getattr(placement, "cap_frac", None))
    return _PLACEMENT_EPOCH


def current_placement():
    """The installed ``ExpertPlacement`` (None = uniform) — what
    ``apply_moe`` resolves ``MoEConfig.placement == "auto"`` to at
    trace time."""
    return _PLACEMENT


def placement_epoch() -> int:
    return _PLACEMENT_EPOCH


def add_invalidation_hook(cb) -> None:
    """Register ``cb(reason, n_dropped)`` to observe invalidations."""
    _INVALIDATION_HOOKS.append(cb)


def remove_invalidation_hook(cb) -> None:
    if cb in _INVALIDATION_HOOKS:
        _INVALIDATION_HOOKS.remove(cb)


def set_wire_ceiling(wire) -> None:
    """Clamp every *resolved* wire decision to at least ``wire`` bytes
    per element (None clears).  ``apply_moe`` applies the clamp via
    :func:`clamp_wire` after resolving forced/auto wire dtypes, so a
    single ``set_wire_ceiling("bf16")`` + :func:`invalidate` + re-jit
    swaps every fp8 wire in the model to bf16 — the guard rails' fp8
    overflow fallback — without touching configs or restarting."""
    global _WIRE_CEILING
    if wire is not None and wire not in WIRE_BYTES:
        raise ValueError(f"unknown wire dtype {wire!r} "
                         f"(want one of {tuple(WIRE_BYTES)})")
    _WIRE_CEILING = wire


def wire_ceiling():
    return _WIRE_CEILING


def clamp_wire(wire: str) -> str:
    """Apply the process-wide wire ceiling to a resolved wire dtype:
    dtypes narrower than the ceiling are widened to it, wider ones pass
    through untouched."""
    if _WIRE_CEILING is None or wire not in WIRE_BYTES:
        return wire
    if WIRE_BYTES[wire] < WIRE_BYTES[_WIRE_CEILING]:
        return _WIRE_CEILING
    return wire


def cache_info() -> dict:
    """Snapshot of the decision cache: key -> ScheduleDecision."""
    return dict(_CACHE)


def cache_summary(exclude=()) -> str:
    """One line per cached decision, for run logs.  ``exclude`` filters
    out keys already present before a run (see ``Trainer``), so multi-
    model processes only report their own decisions."""
    lines = []
    for key, d in sorted(_CACHE.items(), key=lambda kv: repr(kv[0][0])):
        if key in exclude:
            continue
        shape, mode = key[0], key[1]
        cls = " decode" if getattr(shape, "infer", False) else ""
        ep = d.placement_epoch
        stale = " STALE" if ep != _PLACEMENT_EPOCH else ""
        lines.append(
            f"autosched[{mode}{cls}] BxL={shape.B}x{shape.L} M={shape.M} "
            f"E={shape.E} ep/esp/mp={shape.n_ep}/{shape.n_esp}/{shape.n_mp}"
            f" -> {d.schedule} x{d.n_chunks} chunks wire={d.wire_dtype}"
            f" ({d.source} placement-epoch={ep}{stale})")
    return "\n".join(lines)


def _norm(cand):
    """Candidate -> (schedule, n_chunks, wire_dtype), defaulting f32."""
    return cand if len(cand) == 3 else (cand[0], cand[1], "f32")


def decide(shape: MoELayerShape, *, perf_model: Optional[PerfModel] = None,
           mode: str = "analytic", chunk_candidates=DEFAULT_CHUNKS,
           wire_candidates=DEFAULT_WIRE, schedules=None,
           measure: Optional[Callable] = None) -> ScheduleDecision:
    """Pick (schedule, n_chunks, wire_dtype) for one MoE layer shape,
    with caching.

    ``wire_candidates`` widens the grid to a joint comm-precision
    decision (``AUTO_WIRE`` when ``CommConfig.wire_dtype == "auto"``);
    with the default f32-only grid, candidates stay the legacy
    ``(schedule, n_chunks)`` pairs.  ``schedules`` restricts the
    schedule axis (a forced schedule that still wants a wire decision).
    Exact ties break toward the *wider* wire dtype, so compression is
    only picked where the model says the comm term actually shrinks the
    layer time.  ``measure`` (measured mode) maps the candidate list to
    ``{candidate: seconds}``; :func:`measure_candidates` builds one from
    a live mesh.  The decision is cached on every argument — pass the
    same arguments, get the identical (cached) decision back.
    """
    if mode not in ("analytic", "measured"):
        raise ValueError(f"unknown autosched mode {mode!r}")
    pm = perf_model or tpu_v5e_model(shape.n_ep, shape.n_esp, shape.n_mp)
    wire_candidates = tuple(wire_candidates)
    joint_wire = wire_candidates != ("f32",)
    # Resolve the schedule grid BEFORE the cache lookup: the registry can
    # grow (register_plan) after a decision was cached, and the stale
    # entry must not shadow the widened grid.
    # The decode shape class (shape.infer) widens the grid to the
    # decode-dedicated plans (s1d) — and, being part of ``shape``, also
    # keys the cache, so a decode decision can never evict a training/
    # prefill decision for the same sizes.
    if schedules is not None:
        scheds = tuple(schedules)
    elif mode == "measured":
        scheds = planlib.measured_schedules(infer=shape.infer)
    else:
        scheds = planlib.analytic_schedules(infer=shape.infer)
    # The placement epoch is part of the key: after a rebalance
    # (set_placement) the stale line stays cached (the running jit still
    # uses it) but any retrace decides afresh under the new placement.
    key = (shape, mode, tuple(chunk_candidates), pm, wire_candidates,
           scheds, _PLACEMENT_EPOCH)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit

    if mode == "measured":
        if measure is None:
            raise ValueError("measured mode needs a `measure` callable "
                             "(see autosched.measure_candidates)")
        cands = [((s, n, w) if joint_wire else (s, n))
                 for s in scheds for n in chunk_candidates
                 for w in wire_candidates]
        times = dict(measure(cands))
    else:
        # Each candidate is scored by walking its actual plan graph
        # (PerfModel.t_plan) — the same stages the executor will run, so
        # a newly registered schedule is scored with no new closed form.
        # Legacy f32-only grids score with wire_dtype=None (factor 1.0,
        # the width the betas were fitted at) so default-config decisions
        # are exactly PR 2's.  A joint grid scores each wire dtype at its
        # true byte width relative to PerfModel.wire_bytes_ref — only the
        # *ratios* between candidates decide the argmin.
        times = {}
        for s in scheds:
            for n in chunk_candidates:
                p = planlib.plan_for_shape(s, shape, n)
                for w in wire_candidates:
                    times[(s, n, w) if joint_wire else (s, n)] = \
                        pm.t_plan(p, shape,
                                  wire_dtype=w if joint_wire else None)
    # rank by time; exact ties prefer the wider wire (no silent
    # compression), then candidate-grid order (stable sort).
    ranked = tuple(sorted(
        times.items(),
        key=lambda kv: (kv[1], -WIRE_BYTES[_norm(kv[0])[2]])))
    sched, n_chunks, wire = _norm(ranked[0][0])
    decision = ScheduleDecision(schedule=sched, n_chunks=n_chunks,
                                source=mode, times=ranked,
                                wire_dtype=wire,
                                placement_epoch=_PLACEMENT_EPOCH)
    _CACHE[key] = decision
    # cache-fill only: the per-trace cache hits stay silent, so the
    # metrics stream records one decision event per distinct layer line
    obs.emit("autosched_decision", schedule=sched, n_chunks=n_chunks,
             wire=wire, mode=mode,
             infer=bool(getattr(shape, "infer", False)),
             tokens=shape.B * shape.L, d_model=shape.M, E=shape.E,
             placement_epoch=_PLACEMENT_EPOCH)
    return decision


def decide_placement(shape, loads, *, schedule, n_chunks: int = 1,
                     candidate=None, perf_model: Optional[PerfModel] = None,
                     capacity_factor: float = 1.0, top_k: int = 1,
                     margin: float = 1.05, max_replicas=None):
    """Score a load-derived expert placement against uniform for one
    layer shape.

    Builds ``candidate`` (default: ``placement_from_loads`` over the
    observed per-expert ``loads``), prices the layer's plan both ways
    with the skew-aware cost model (``PerfModel.t_plan(..., loads=...)``
    — uniform pays the max-rank load inflation, the placed plan pays
    its shrunk pool at its own residual imbalance), and returns
    ``(placement_or_None, t_placed, t_uniform)`` where the placement is
    ``None`` unless it beats uniform by at least ``margin``.
    """
    from repro.core.placement import placement_from_loads

    pm = perf_model or tpu_v5e_model(shape.n_ep, shape.n_esp, shape.n_mp)
    if candidate is None:
        candidate = placement_from_loads(
            loads, shape.n_ep, n_experts=shape.E,
            capacity_factor=capacity_factor, top_k=top_k,
            max_replicas=max_replicas, epoch=_PLACEMENT_EPOCH + 1)
    t_uni = pm.t_plan(planlib.plan_for_shape(schedule, shape, n_chunks),
                      shape, loads=loads)
    if candidate is None or candidate.is_identity:
        return None, t_uni, t_uni
    t_cand = pm.t_plan(
        planlib.plan_for_shape(schedule, shape, n_chunks,
                               placement=candidate), shape, loads=loads)
    win = t_cand * margin < t_uni
    return (candidate if win else None), t_cand, t_uni


def maybe_rebalance(loads, *, margin: float = 1.05,
                    capacity_factor: float = 1.0, top_k: int = 1,
                    perf_model: Optional[PerfModel] = None,
                    max_replicas=None, infer: bool = False):
    """The rebalance trigger: derive a placement from the live load EMA,
    score it against uniform over every compatible cached decision, and
    install it on a win.

    ``loads`` is the smoothed per-expert load vector (``LoadEMA.value``).
    Candidate shapes come from :func:`cache_info` — the layers this
    process has actually decided for (``infer`` selects the decode
    class).  The candidate must beat uniform by ``margin`` on *every*
    compatible shape (the placement is process-wide, so a loss anywhere
    vetoes).  On a win, :func:`set_placement` installs it and the new
    epoch is returned; if the loads have evened out (identity candidate)
    while a placement is installed, the placement is cleared (also a new
    epoch).  Returns None when nothing changes — the caller skips the
    re-jit entirely.
    """
    from repro.core.placement import placement_from_loads

    import numpy as _np

    loads = _np.asarray(loads, dtype=_np.float64)
    seen, todo = set(), []
    for key, d in _CACHE.items():
        shape = key[0]
        if bool(getattr(shape, "infer", False)) != infer:
            continue
        if shape.n_ep <= 1 or shape.E != loads.size:
            continue
        sk = (shape, d.schedule, d.n_chunks)
        if sk in seen:
            continue
        seen.add(sk)
        todo.append(sk)
    if not todo:
        return None
    n_ep = todo[0][0].n_ep
    cand = placement_from_loads(
        loads, n_ep, n_experts=int(loads.size),
        capacity_factor=capacity_factor, top_k=top_k,
        max_replicas=max_replicas, epoch=_PLACEMENT_EPOCH + 1)
    if infer and cand.cap_frac < 1.0:
        # decode layers run drop-free (apply_moe forces cap_frac=1.0),
        # so score the candidate the way decode will actually run it; a
        # capacity-shrink-only candidate (no replication) degenerates to
        # a bare permutation at full capacity — treat as uniform
        from dataclasses import replace as _dc_replace
        from repro.core.placement import identity_placement
        cand = identity_placement(cand.n_experts, n_ep) \
            if cand.n_phys == cand.n_experts \
            else _dc_replace(cand, cap_frac=1.0)
    if cand.is_identity:
        if _PLACEMENT is not None:
            return set_placement(None)  # loads evened out: back to uniform
        return None
    cur = _PLACEMENT
    if cur is not None and cur.assignments == cand.assignments \
            and abs(cur.cap_frac - cand.cap_frac) < 0.05:
        return None  # already running (close enough to) this placement
    for shape, sched, nc in todo:
        if shape.n_ep != n_ep:
            continue  # placement is per-EP-degree; skip foreign meshes
        got, _, _ = decide_placement(
            shape, loads, schedule=sched, n_chunks=nc, candidate=cand,
            perf_model=perf_model, margin=margin)
        if got is None:
            return None
    return set_placement(cand)


def measure_candidates(mesh, dims, cfg, *, tokens: int, d_model: int,
                       iters: int = 3, warmup: int = 1,
                       seed: int = 0) -> Callable:
    """Build a ``measure`` callable timing candidates on the live mesh.

    Returns ``f(candidates) -> {candidate: seconds}`` — candidates are
    ``(schedule, n_chunks)`` pairs or ``(schedule, n_chunks, wire_dtype)``
    triples — that jits ``apply_moe`` once per candidate over synthetic
    data and records median wall time.  ``tokens`` is the *global* pool
    (B*L of the real layer): the nested ``apply_moe`` re-shards it over
    the same batch axes, so each candidate runs at the true per-device
    token count.  Raises if every candidate fails; individual failures
    score ``inf``.  The imports are lazy to keep ``moe -> autosched``
    one-directional at module load.
    """

    def _measure(candidates):
        import sys as _sys
        import time as _time

        import jax
        import jax.numpy as jnp
        from dataclasses import replace

        from repro.core.collectives import CommConfig
        from repro.core.moe import apply_moe, init_moe_params

        key = jax.random.PRNGKey(seed)
        params = init_moe_params(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (1, tokens, d_model), jnp.float32)
        out, errors = {}, {}
        for cand in candidates:
            sched, n_chunks, wire = _norm(cand)
            c = replace(cfg, schedule=sched, pipeline_chunks=n_chunks,
                        comm=CommConfig(wire_dtype=wire,
                                        scaling=cfg.comm.scaling))
            fn = jax.jit(lambda x, p, c=c, s=sched: apply_moe(
                x, p, mesh=mesh, dims=dims, cfg=c, schedule=s)[0])
            try:
                for _ in range(max(warmup, 1)):
                    fn(x, params).block_until_ready()
                ts = []
                for _ in range(max(iters, 1)):
                    t0 = _time.perf_counter()
                    fn(x, params).block_until_ready()
                    ts.append(_time.perf_counter() - t0)
                ts.sort()
                out[cand] = ts[len(ts) // 2]
            except Exception as e:  # noqa: BLE001 — unlowerable candidate
                out[cand] = float("inf")
                errors[cand] = repr(e)
        if errors and all(t == float("inf") for t in out.values()):
            raise RuntimeError(
                "autosched measured calibration failed for every candidate: "
                + "; ".join(f"{c}: {m}" for c, m in errors.items()))
        for c, m in errors.items():
            # partial failures score inf (never win) but must be visible,
            # or "measured mode never picks X" is undebuggable from logs
            print(f"autosched: candidate {c} failed calibration: {m}",
                  file=_sys.stderr, flush=True)
        return out

    def run(candidates):
        # decide() is usually reached while TRACING train_step; calling
        # the candidate jits on that thread would stage them into the
        # ambient trace (returning tracers) instead of executing.  JAX's
        # trace state is thread-local, so a worker thread gives a clean
        # eager context on every jax version — the calibration runs for
        # real on the live devices while the outer trace is suspended.
        import threading

        box = {}

        def work():
            try:
                box["out"] = _measure(candidates)
            except BaseException as e:  # noqa: BLE001 — reraise on caller
                box["err"] = e

        t = threading.Thread(target=work, name="autosched-calibration")
        t.start()
        t.join()
        if "err" in box:
            raise box["err"]
        return box["out"]

    return run
