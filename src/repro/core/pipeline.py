"""Chunk-pipelined variants of the Parm schedules (comm/compute overlap).

FSMoE (arXiv:2501.10714) and MegaScale-MoE (arXiv:2505.11432) observe
that the remaining serial time in an S1/S2-style schedule is the
dispatch/combine AlltoAll sitting back-to-back with the expert FFN.  The
bodies here remove that serialization: after the (unchanged, full-pool)
gate + dispatch, the per-expert capacity buffer is split into
``info.pipeline_chunks`` micro-chunks along the capacity dim, and each
chunk runs its own dispatch-AlltoAll -> expert FFN -> combine-AlltoAll
chain.  The chunks are *independent* ops in HLO — no data dependency
links chunk i's FFN to chunk i+1's dispatch AlltoAll — so XLA's async
collective (latency-hiding) scheduler issues the AlltoAll of chunk i+1
while the FFN of chunk i occupies the MXUs, exactly the double-buffered
overlap the NCCL multi-stream implementations hand-build.  This is the
same TPU re-expression already used for S2's SAA combine
(``collectives.saa_combine_allgather``), extended to the whole schedule
body and to all three schedules.

Chunking happens *after* gating, along the capacity dim of the dispatch
buffer, so routing, capacity semantics and dropped tokens are bit-for-bit
those of the unchunked schedule; the expert FFN is pointwise over
capacity slots, so any chunk count produces the same values
(``tests/test_pipeline.py`` asserts parity, grads included, for
``n_chunks`` in {1, 2, 4}).

``n_chunks`` is clamped to the largest divisor of the chunked capacity
dim that is <= the requested count (n_chunks=1 degenerates to the
unchunked schedule).  The per-layer winner (schedule x chunk count) is
picked by ``repro.core.autosched``; sweep it with
``benchmarks/bench_pipeline.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import collectives as coll
from repro.core.gating import combine, dispatch, topk_gate
from repro.core.schedules import BODY, MoEShardInfo, _aux_mean, expert_ffn

PIPELINE_OF = {"baseline": "baseline_pipe", "s1": "s1_pipe",
               "s2": "s2_pipe", "s1_seqpar": "s1_seqpar_pipe"}
UNCHUNKED_OF = {v: k for k, v in PIPELINE_OF.items()}


def clamp_chunks(cap: int, want: int) -> int:
    """Largest divisor of ``cap`` that is <= ``want`` (and >= 1)."""
    n = max(1, min(want, cap))
    while cap % n:
        n -= 1
    return n


def _chunks(buf, n_chunks: int, axis: int = 1):
    """Split ``buf`` into ``n_chunks`` equal slices along ``axis``."""
    c = buf.shape[axis]
    cs = c // n_chunks
    return [lax.slice_in_dim(buf, i * cs, (i + 1) * cs, axis=axis)
            for i in range(n_chunks)]


# --- pipelined baseline ------------------------------------------------------

def baseline_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    """Baseline schedule with the EP-AlltoAll / FFN / EP-AlltoAll chain
    chunked over the capacity dim.  The ESP-AllGather and the gate stay
    whole (they precede routing); each chunk then carries its own pair of
    EP-AlltoAlls around its FFN slice, so the return AlltoAll of chunk i
    overlaps the FFN of chunk i+1."""
    Ne, Ns = info.n_ep, info.n_esp
    E = info.gate.n_experts
    g = coll.mp_all_gather(x, info.esp_axes, Ns, axis=0)        # (S*Ns, M)
    cap_g = info.cap * Ns
    gate = topk_gate(g, wg, info.gate, cap_g)
    eidx, slot, w, aux = gate
    d = dispatch(g, eidx, slot, cap_g, E, info.kernel,
                 flat=gate.flat(cap_g, E))                      # (E, T*Ns, M)
    n = clamp_chunks(cap_g, info.pipeline_chunks)
    parts = []
    for ch in _chunks(d, n, axis=1):                            # (E, cs, M)
        cs = ch.shape[1]
        sb = ch.reshape(Ne, E // Ne, cs, -1)
        rb = coll.wire_ep_all_to_all(sb, info.ep_axes, info.comm)
        xb = coll.to_expert_batch(rb)                           # (El, Ne*cs, M)
        h = expert_ffn(xb, w1, w3, w2, info)
        h = lax.psum(h, info.esp_axes)
        back = coll.wire_ep_all_to_all(coll.from_expert_batch(h, Ne),
                                       info.ep_axes, info.comm)
        parts.append(back.reshape(E, cs, -1))
    full = parts[0] if n == 1 else jnp.concatenate(parts, axis=1)
    out = combine(full, eidx, slot, w, cap_g, info.kernel,
                  flat=gate.flat(cap_g, E))
    y = coll.mp_split(out, info.esp_axes, Ns, axis=0)           # (S, M)
    return y, _aux_mean(aux, info)


# --- pipelined S1 ------------------------------------------------------------

def s1_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo, *,
                 seqpar: bool = False):
    """S1 with the fused EP&ESP-AlltoAll / FFN chain chunked over the
    per-shard capacity dim.  Entry MP-Split, gate and exit MP-AllGather
    are those of the unchunked S1 (they bracket the whole pool)."""
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    xs = x if seqpar else coll.mp_split(x, info.mp_axes, Nm, axis=0)
    c1 = info.cap if seqpar else info.cap // Nm
    gate = topk_gate(xs, wg, info.gate, c1)
    eidx, slot, w, aux = gate
    d = dispatch(xs, eidx, slot, c1, E, info.kernel,
                 flat=gate.flat(c1, E))                         # (E, c1, M)
    n = clamp_chunks(c1, info.pipeline_chunks)
    parts = []
    for ch in _chunks(d, n, axis=1):                            # (E, cs, M)
        sb = coll.dump_em(ch, Ne, Ns)                           # (El, G, cs, M)
        rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                         info.comm, split_axis=1,
                                         concat_axis=1)
        xb = coll.to_expert_batch_em(rb)                        # (El, G*cs, M)
        h = expert_ffn(xb, w1, w3, w2, info)
        back = coll.wire_ep_esp_all_to_all(
            coll.from_expert_batch_em(h, info.combined_group),
            info.ep_axes, info.esp_axes, info.comm, split_axis=1,
            concat_axis=1)
        parts.append(coll.undump_reduce_em(back, Ne, Ns))       # (E, cs, M)
    mine = parts[0] if n == 1 else jnp.concatenate(parts, axis=1)
    y = combine(mine, eidx, slot, w, c1, info.kernel,
                flat=gate.flat(c1, E))                          # (S/Nm, M)
    if not seqpar:
        y = coll.wire_mp_all_gather(y, info.mp_axes, Nm, info.comm,
                                    axis=0)
    return y, _aux_mean(aux, info)


# --- pipelined S2 ------------------------------------------------------------

def s2_pipe_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    """S2 with the *whole* dispatch-AlltoAll / FFN / SAA chain chunked:
    this extends the SAA overlap (which the unchunked S2 applies to the
    combine AlltoAll + MP-AllGather only) across the dispatch AlltoAll
    and the expert FFN as well, so every chunk's combine+AllGather rides
    in the shadow of later chunks' dispatch+FFN."""
    Ne, Ns, Nm = info.n_ep, info.n_esp, info.n_mp
    E = info.gate.n_experts
    gate = topk_gate(x, wg, info.gate, info.cap)
    eidx, slot, w, aux = gate
    d = dispatch(x, eidx, slot, info.cap, E, info.kernel,
                 flat=gate.flat(info.cap, E))                   # (E, T, M)
    ds = coll.mp_split(d, info.mp_axes, Nm, axis=1)             # (E, T/Nm, M)
    c = ds.shape[1]
    n = clamp_chunks(c, info.pipeline_chunks)
    parts = []
    for ch in _chunks(ds, n, axis=1):                           # (E, cs, M)
        sb = coll.dump_em(ch, Ne, Ns)                           # (El, G, cs, M)
        rb = coll.wire_ep_esp_all_to_all(sb, info.ep_axes, info.esp_axes,
                                         info.comm, split_axis=1,
                                         concat_axis=1)
        xb = coll.to_expert_batch_em(rb)
        h = expert_ffn(xb, w1, w3, w2, info)
        y4 = coll.from_expert_batch_em(h, info.combined_group)
        back = coll.wire_ep_esp_all_to_all(y4, info.ep_axes,
                                           info.esp_axes, info.comm,
                                           split_axis=1, concat_axis=1)
        comb = coll.undump_reduce_em(back, Ne, Ns)              # (E, cs, M)
        if Nm == 1:
            parts.append(comb[:, None])                         # (E, 1, cs, M)
        else:
            parts.append(coll.wire_all_gather_stacked(
                comb, tuple(info.mp_axes), Nm, info.comm,
                axis=1))                                        # (E, Nm, cs, M)
    # (E, Nm, n, cs, M) -> (E, Nm * c, M): position mp*c + i*cs + s is the
    # original (mp_rank, slot) order, so the layout is n_chunks-invariant
    # (same bookkeeping as collectives.saa_combine_allgather).
    stacked = jnp.stack(parts, axis=2)
    full = stacked.reshape(E, Nm * c, -1)                       # (E, T, M)
    y = combine(full, eidx, slot, w, info.cap, info.kernel,
                flat=gate.flat(info.cap, E))                    # (S, M)
    return y, _aux_mean(aux, info)


PIPELINE_BODY = {
    "baseline_pipe": baseline_pipe_body,
    "s1_pipe": s1_pipe_body,
    "s2_pipe": s2_pipe_body,
    "s1_seqpar_pipe": lambda *a, **k: s1_pipe_body(*a, seqpar=True, **k),
}
BODY.update(PIPELINE_BODY)
