"""Chunk-pipelined schedule variants (comm/compute overlap), generated.

FSMoE (arXiv:2501.10714) and MegaScale-MoE (arXiv:2505.11432) observe
that the remaining serial time in an S1/S2-style schedule is the
dispatch/combine AlltoAll sitting back-to-back with the expert FFN.  The
``*_pipe`` family removes that serialization: after the (unchanged,
full-pool) gate + dispatch, the per-expert capacity buffer is split into
``info.pipeline_chunks`` micro-chunks along the capacity dim, and each
chunk runs its own dispatch-AlltoAll -> expert FFN -> combine-AlltoAll
chain.  The chunks are *independent* subgraphs in HLO, so XLA's async
collective (latency-hiding) scheduler issues the AlltoAll of chunk i+1
while the FFN of chunk i occupies the MXUs.

Since the plan-IR refactor these are no longer hand-written bodies: each
``*_pipe`` name is the *same* registered plan as its base schedule with
the ``plan.split_capacity`` graph transform applied (chunk count from
``info.pipeline_chunks``, clamped to the largest divisor of the chunked
capacity dim).  Chunking happens after gating, along the capacity dim of
the dispatch buffer, so routing, capacity semantics and dropped tokens
are bit-for-bit those of the unchunked schedule
(``tests/test_plan_executor.py`` asserts parity against the golden
legacy bodies for ``n_chunks`` in {1, 2, 4}, gradients included).

The per-layer winner (schedule x chunk count x wire dtype) is picked by
``repro.core.autosched``; sweep it with ``benchmarks/bench_pipeline.py``.
"""

from __future__ import annotations

from repro.core.executor import execute
from repro.core.plan import build_plan, clamp_chunks  # noqa: F401 (re-export)
from repro.core.schedules import BODY, MoEShardInfo

PIPELINE_OF = {"baseline": "baseline_pipe", "s1": "s1_pipe",
               "s2": "s2_pipe", "s1_seqpar": "s1_seqpar_pipe",
               "s2h": "s2h_pipe", "s1g": "s1g_pipe"}
UNCHUNKED_OF = {v: k for k, v in PIPELINE_OF.items()}


def _pipe_body(name):
    def body(x, wg, w1, w3, w2, info: MoEShardInfo):
        return execute(build_plan(name, info), x, wg, w1, w3, w2, info)
    body.__name__ = f"{name}_pipe_body"
    body.__qualname__ = body.__name__
    body.__doc__ = (f"``{name}`` with ``split_capacity`` applied at "
                    "``info.pipeline_chunks`` (1 degenerates to the "
                    "unchunked plan).")
    return body


baseline_pipe_body = _pipe_body("baseline")
s1_pipe_body = _pipe_body("s1")
s2_pipe_body = _pipe_body("s2")
s1_seqpar_pipe_body = _pipe_body("s1_seqpar")
s2h_pipe_body = _pipe_body("s2h")
s1g_pipe_body = _pipe_body("s1g")

PIPELINE_BODY = {
    "baseline_pipe": baseline_pipe_body,
    "s1_pipe": s1_pipe_body,
    "s2_pipe": s2_pipe_body,
    "s1_seqpar_pipe": s1_seqpar_pipe_body,
    "s2h_pipe": s2h_pipe_body,
    "s1g_pipe": s1g_pipe_body,
}
BODY.update(PIPELINE_BODY)
