# The paper's primary contribution: Parm's dedicated MP+EP+ESP schedules
# (baseline / S1 / S2), the fused EP&ESP-AlltoAll + SAA collectives, and
# the alpha-beta Algorithm-1 auto-selector.
from repro.core.moe import (  # noqa: F401
    MoEConfig,
    apply_moe,
    init_moe_params,
    moe_param_specs,
    select_schedule,
)
from repro.core.gating import GateConfig, capacity, topk_gate  # noqa: F401
from repro.core.perfmodel import (  # noqa: F401
    AlphaBeta,
    MoELayerShape,
    PerfModel,
    fit_alpha_beta,
    tpu_v5e_model,
)
from repro.core.schedules import SCHEDULES, MoEShardInfo  # noqa: F401
