# The paper's primary contribution: Parm's dedicated MP+EP+ESP schedules
# (baseline / S1 / S2 / the hierarchical S2H, each a declarative plan
# whose chunk-pipelined *_pipe and wire-precision variants are graph
# transforms), the fused EP&ESP-AlltoAll + SAA collectives, and the
# alpha-beta Algorithm-1 auto-selector with its caching autosched
# runtime scoring the plan-registry grid.
from repro.core.autosched import ScheduleDecision, decide  # noqa: F401
from repro.core.plan import (  # noqa: F401
    PLANS,
    Plan,
    Stage,
    apply_wire,
    build_plan,
    plan_summary,
    register_plan,
    split_capacity,
)
from repro.core.executor import execute  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    PIPELINE_BODY,
    PIPELINE_OF,
    clamp_chunks,
)
from repro.core.moe import (  # noqa: F401
    MoEConfig,
    apply_moe,
    init_moe_params,
    moe_param_specs,
    select_schedule,
)
from repro.core.gating import GateConfig, capacity, topk_gate  # noqa: F401
from repro.core.perfmodel import (  # noqa: F401
    AlphaBeta,
    MoELayerShape,
    PerfModel,
    fit_alpha_beta,
    tpu_v5e_model,
)
from repro.core.schedules import SCHEDULES, MoEShardInfo  # noqa: F401
