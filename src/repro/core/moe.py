"""The MoE layer: Parm's schedules as a first-class, composable module.

``apply_moe`` is the public entry point used by every model definition.
It wires the schedule bodies (repro.core.schedules + the chunk-pipelined
variants in repro.core.pipeline) into a shard_map over the caller's mesh,
handles the decode-time fallback when the token count cannot be sharded
over the EP axes, computes capacities, and — when ``schedule="auto"``
and/or ``CommConfig.wire_dtype="auto"`` — consults the autoscheduler
(repro.core.autosched) for the per-layer (schedule, n_chunks,
wire_dtype) decision, analytically or from a one-shot measured
calibration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace as _dc_replace
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

import itertools

from repro import compat, obs
from repro.core import autosched, executor
from repro.core import plan as planlib
from repro.core.collectives import CommConfig
from repro.core.gating import GateConfig, capacity
from repro.core.perfmodel import MoELayerShape, PerfModel, tpu_v5e_model
from repro.core.pipeline import PIPELINE_OF, UNCHUNKED_OF, clamp_chunks
from repro.core.schedules import BODY, MoEShardInfo, expert_ffn
from repro.kernels.registry import KernelConfig
from repro.parallel.mesh import ParallelDims, axis_size


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                     # per-expert hidden size
    n_experts: int
    top_k: int = 1
    capacity_factor: float = 1.25
    n_shared_experts: int = 0     # llama4-style shared expert(s)
    glu: bool = True              # SwiGLU experts
    normalize_topk: bool = False
    aux_loss_weight: float = 1e-2
    z_loss_weight: float = 1e-3
    schedule: str = "auto"        # baseline | s1 | s2 | s1_seqpar | s2h |
    #   *_pipe | auto — or any schedule registered via plan.register_plan
    saa_chunks: int = 4
    pipeline_chunks: int = 1      # micro-chunks for the *_pipe bodies (1 = off)
    autosched: str = "analytic"   # "auto" decision mode: analytic | measured
    act: str = "silu"             # expert activation ("silu" | "gelu")
    kernel: KernelConfig = KernelConfig()  # hot-path op backend + tiles
    comm: CommConfig = CommConfig()  # collective wire format (f32 default;
    #   wire_dtype="auto" lets the autoscheduler pick f32-vs-bf16 jointly
    #   with (schedule, n_chunks); fp8_e4m3 must be requested explicitly)
    placement: object = None      # expert placement: None (uniform) |
    #   "auto" (read the live placement from the autosched registry at
    #   trace time — the rebalance loop's swap point) | a concrete
    #   ExpertPlacement (forced, e.g. the parity tests)

    def gate_config(self) -> GateConfig:
        return GateConfig(
            n_experts=self.n_experts, top_k=self.top_k,
            capacity_factor=self.capacity_factor,
            normalize_topk=self.normalize_topk,
            aux_loss_weight=self.aux_loss_weight,
            z_loss_weight=self.z_loss_weight)


def init_moe_params(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    M, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / math.sqrt(M)
    scale_out = 1.0 / math.sqrt(F)
    p = {
        "wg": jax.random.normal(ks[0], (M, E), jnp.float32) * scale_in,
        "w1": jax.random.normal(ks[1], (E, M, F), dtype) * scale_in,
        "w2": jax.random.normal(ks[2], (E, F, M), dtype) * scale_out,
    }
    if cfg.glu:
        p["w3"] = jax.random.normal(ks[3], (E, M, F), dtype) * scale_in
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["shared_w1"] = jax.random.normal(ks[4], (M, Fs), dtype) * scale_in
        p["shared_w3"] = jax.random.normal(ks[5], (M, Fs), dtype) * scale_in
        p["shared_w2"] = (jax.random.normal(key, (Fs, M), dtype)
                          * (1.0 / math.sqrt(Fs)))
    return p


def moe_param_specs(cfg: MoEConfig, mesh, dims: ParallelDims) -> dict:
    """PartitionSpecs: experts over EP, hidden over ESP, gate replicated."""
    def ep_ok(n):
        return dims.ep and n % axis_size(mesh, dims.ep) == 0

    def esp_ok(n):
        return dims.esp and n % axis_size(mesh, dims.esp) == 0

    E, F, M = cfg.n_experts, cfg.d_ff, cfg.d_model
    e_ax = tuple(dims.ep) if ep_ok(E) else None
    f_ax = tuple(dims.esp) if esp_ok(F) else None
    specs = {
        "wg": P(None, None),
        "w1": P(e_ax, None, f_ax),
        "w2": P(e_ax, f_ax, None),
    }
    if cfg.glu:
        specs["w3"] = P(e_ax, None, f_ax)
    if cfg.n_shared_experts:
        mp_ax = tuple(dims.mp) if dims.mp and (
            F * cfg.n_shared_experts) % axis_size(mesh, dims.mp) == 0 else None
        specs["shared_w1"] = P(None, mp_ax)
        specs["shared_w3"] = P(None, mp_ax)
        specs["shared_w2"] = P(mp_ax, None)
    return specs


def shard_pool_capacity(tokens_global: int, n_token_shard: int, n_mp: int,
                        gate_cfg: GateConfig, infer: bool = False):
    """(s_local, cap) for one device's token pool — THE capacity formula.

    ``s_local`` is the per-shard pool (``tokens_global`` split over the
    token-shard group: batch axes, plus MP under the seqpar contract);
    ``cap`` is the per-expert capacity aligned to ``max(8, n_mp)`` so the
    S1/S2 capacity splits stay divisible.  ``apply_moe`` computes its
    capacities through this helper and ``launch/dryrun.py`` mirrors it,
    so the recorded decisions/plans match what actually compiles.

    ``infer=True`` (decode-time pools) raises ``cap`` to cover the whole
    pool: a decode batch mixes live requests with idle padding rows, and
    Parm-style capacity drops would let one request's token be displaced
    by batch *composition* — with ``cap >= pool`` every token always has
    a slot, so a row's decode output is independent of its batch mates
    (the invariant the serving engine's parity tests pin down).  The
    memory cost is E * pool * M, negligible at decode sizes.
    """
    s_local = tokens_global // max(n_token_shard, 1)
    align = max(8, n_mp)
    cap = max(align, -(-capacity(max(s_local, 1), gate_cfg)
                       // align) * align)
    if infer:
        cap = max(cap, -(-max(s_local, 1) // align) * align)
    return s_local, cap


_TRACE_ORDINAL = itertools.count()  # apply_moe call ordinal (trace tag)


# --- decode fallback ---------------------------------------------------------

def _replicated_body(x, wg, w1, w3, w2, info: MoEShardInfo):
    """All-reduce-based MoE for tiny token counts (decode with B < EP size):
    tokens stay replicated, each device computes its local experts masked by
    the routing, and a psum over (EP, ESP) assembles the output."""
    El = w1.shape[0]
    gate = info.gate
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(wg, jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, eidx = lax.top_k(probs, gate.top_k)                 # (S, k)
    if gate.normalize_topk:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
    ep_idx = lax.axis_index(info.ep_axes) if info.ep_axes else 0
    gids = ep_idx * El + jnp.arange(El)                         # (El,)
    sel = (eidx[:, :, None] == gids[None, None, :]).astype(x.dtype)
    wsel = jnp.einsum("sk,ske->se", gate_w.astype(x.dtype), sel)  # (S, El)
    xb = jnp.broadcast_to(x[None], (El, *x.shape))              # (El, S, M)
    h = expert_ffn(xb, w1, w3, w2, info)                        # partial
    y = jnp.einsum("esm,se->sm", h, wsel)
    red = tuple(dict.fromkeys(info.ep_axes + info.esp_axes))
    if red:
        y = lax.psum(y, red)
    aux = {"aux_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0),
           "drop_frac": jnp.float32(0.0)}
    return y, aux


# --- public entry ------------------------------------------------------------

def select_schedule(cfg: MoEConfig, shape: MoELayerShape,
                    perf_model: Optional[PerfModel] = None) -> str:
    """Schedule name for one layer shape (no chunk count; see
    ``autosched.decide`` for the full (schedule, n_chunks) decision)."""
    if cfg.schedule != "auto":
        return cfg.schedule
    pm = perf_model or tpu_v5e_model(shape.n_ep, shape.n_esp, shape.n_mp)
    return autosched.decide(shape, perf_model=pm).schedule


def apply_moe(x, params: dict, *, mesh, dims: ParallelDims, cfg: MoEConfig,
              schedule: Optional[str] = None,
              perf_model: Optional[PerfModel] = None,
              infer: bool = False):
    """Run one MoE layer under the configured Parm schedule.

    x: (B, L, M) activations; replicated over MP axes (or MP-split over
    them under the ``s1_seqpar`` contract).  Returns (y, aux).

    ``infer=True`` marks a decode-time call (``decode_block``): the
    layer shape joins the *decode* shape class — its own autosched cache
    entries, the decode-widened schedule grid (``s1d``), no capacity
    chunking, and drop-free capacity (``shard_pool_capacity``).
    """
    B, L, M = x.shape
    sizes = dims.sizes(mesh)
    n_ep, n_esp, n_mp = sizes["ep"], sizes["esp"], sizes["mp"]
    gate_cfg = cfg.gate_config()

    if n_ep > 1 and cfg.n_experts % n_ep:
        raise ValueError(f"E={cfg.n_experts} not divisible by EP={n_ep}")
    if n_esp > 1 and cfg.d_ff % n_esp:
        raise ValueError(f"d_ff={cfg.d_ff} not divisible by ESP={n_esp}")

    tokens_global = B * L
    batch_ax = dims.batch_axes
    n_batch = axis_size(mesh, batch_ax)

    sched = schedule or cfg.schedule
    n_chunks = max(cfg.pipeline_chunks, 1)
    seqpar = sched in ("s1_seqpar", "s1_seqpar_pipe")
    token_shard = batch_ax + (dims.mp if seqpar else ())
    n_token_shard = axis_size(mesh, token_shard)

    s_local, cap = shard_pool_capacity(tokens_global, n_token_shard,
                                       n_mp, gate_cfg, infer=infer)
    divisible = (tokens_global % max(n_token_shard, 1) == 0
                 and (seqpar or s_local % max(n_mp, 1) == 0)
                 and s_local > 0)
    use_fallback = (not divisible) or s_local < n_mp

    comm = cfg.comm or CommConfig()
    wire = comm.wire_dtype
    if use_fallback:
        sched = "dense_decode"
        wire = "f32" if wire == "auto" else wire  # psum-only body: no wire
    elif sched == "auto" or wire == "auto":
        shape = MoELayerShape(
            B=max(s_local // max(L, 1), 1), L=min(L, s_local), M=M,
            H=cfg.d_ff, E=cfg.n_experts, k=cfg.top_k,
            f=cfg.capacity_factor, n_mp=n_mp, n_esp=n_esp, n_ep=n_ep,
            infer=infer)
        # Only score chunk counts the bodies can actually run: every
        # schedule's chunked dim is a multiple of cap/N_MP, so clamping
        # against it keeps scored == executed (and dedups candidates).
        # Decode pools never chunk: the per-chunk alphas dominate at a
        # handful of tokens, so the decode grid is pinned to n_chunks=1.
        cands = ((1,) if infer else
                 tuple(sorted({clamp_chunks(cap // max(n_mp, 1), n)
                               for n in autosched.DEFAULT_CHUNKS})))
        # A forced schedule with wire="auto" restricts the decision to
        # that schedule (and the forced chunk count): only the wire axis
        # is still free.
        forced = None
        if sched != "auto":
            forced = (UNCHUNKED_OF.get(sched, sched),)
            cands = (clamp_chunks(cap // max(n_mp, 1), n_chunks),)
        wire_cands = (autosched.AUTO_WIRE if wire == "auto" else (wire,))
        # tokens_global: the nested apply_moe re-shards over the same
        # batch axes, so candidates are timed at the true per-device pool.
        measure = (autosched.measure_candidates(
            mesh, dims, cfg, tokens=tokens_global, d_model=M)
            if cfg.autosched == "measured" else None)
        decision = autosched.decide(shape, perf_model=perf_model,
                                    mode=cfg.autosched,
                                    chunk_candidates=cands,
                                    wire_candidates=wire_cands,
                                    schedules=forced, measure=measure)
        if sched == "auto":
            sched, n_chunks = decision.schedule, decision.n_chunks
        wire = decision.wire_dtype if wire == "auto" else wire
    # guard-rail wire ceiling (fp8 overflow fallback): clamp the resolved
    # wire up to the process-wide floor width, if one is set.  Applied
    # after auto/forced resolution so it covers both paths; a no-op
    # (identity) when no ceiling is active.
    wire = autosched.clamp_wire(wire)
    if not use_fallback and n_chunks > 1 and sched in PIPELINE_OF:
        # route chunked requests to the pipelined body of the same schedule
        sched = PIPELINE_OF[sched]

    # Expert placement: "auto" reads the live rebalanced placement from
    # the autosched registry at trace time (the Trainer/Engine re-jit
    # after autosched.set_placement, so the swap needs no config churn).
    # A placement only applies when there is an EP group to remap over
    # and its geometry matches this layer; the decode fallback body
    # computes densely and ignores it.
    pl = cfg.placement
    if pl == "auto":
        pl = autosched.current_placement()
    if pl is not None and (use_fallback or n_ep <= 1
                           or pl.n_experts != cfg.n_experts
                           or pl.n_ep != n_ep):
        pl = None
    if pl is not None and infer and pl.cap_frac < 1.0:
        # decode pools are drop-free by contract (shard_pool_capacity
        # raises cap to cover the pool); keep the replication but not
        # the capacity shrink, so r_e * cap >= pool always holds
        pl = _dc_replace(pl, cap_frac=1.0)

    info = MoEShardInfo(
        ep_axes=tuple(dims.ep), esp_axes=tuple(dims.esp),
        mp_axes=tuple(dims.mp), n_ep=n_ep, n_esp=n_esp, n_mp=n_mp,
        tokens=s_local, cap=cap, gate=gate_cfg, act=cfg.act, glu=cfg.glu,
        saa_chunks=cfg.saa_chunks, pipeline_chunks=n_chunks,
        kernel=cfg.kernel,
        comm=CommConfig(wire_dtype=wire, scaling=comm.scaling),
        placement=pl)

    if sched == "dense_decode":
        body = _replicated_body
    else:
        body = BODY.get(sched)
    if body is None:
        # A schedule registered via plan.register_plan but without a BODY
        # alias (the docs' "add a schedule" path): execute its plan
        # directly, chunked per info.pipeline_chunks.  Registration alone
        # is enough to be selectable — by name or by the auto grids.
        base = UNCHUNKED_OF.get(sched, sched)
        if base not in planlib.PLANS:
            raise KeyError(f"unknown schedule {sched!r}: not in "
                           f"schedules.BODY nor the plan registry "
                           f"(have {sorted(set(BODY) | set(planlib.PLANS))})")

        def body(xt, wg, w1, w3_, w2, info, _base=base):
            return executor.execute(planlib.build_plan(_base, info),
                                    xt, wg, w1, w3_, w2, info)
    if pl is not None:
        # Placed-weight gather: physical slot p computes logical expert
        # assignments[p].  Done outside the shard_map so the take-VJP
        # scatter-adds replica weight gradients back into the logical
        # parameters — the placement's "summed combine" for weights.
        # (R, M, F) shards over the same P(ep, ...) specs: R % n_ep == 0.
        idx = jnp.asarray(pl.assignments, jnp.int32)
        gathered = {k: jnp.take(params[k], idx, axis=0)
                    for k in ("w1", "w2", "w3") if params.get(k) is not None}
        params = dict(params, **gathered)
    pspecs = moe_param_specs(cfg, mesh, dims)
    w3 = params.get("w3")
    if w3 is None:
        # non-GLU experts have no w3: ship a zero-size replicated stand-in
        # instead of aliasing w1 into a dead (sharded, transferred) operand.
        w3 = jnp.zeros((0,), x.dtype)
        w3_spec = P(None)
    else:
        w3_spec = pspecs["w3"]

    x_spec = (P(tuple(token_shard) or None, None) if not use_fallback
              else P(None, None))
    in_specs = (x_spec, pspecs["wg"], pspecs["w1"], w3_spec, pspecs["w2"])
    out_specs = (x_spec, {k: P() for k in
                          ("aux_loss", "z_loss", "drop_frac",
                           "expert_load")})

    def shard_body(xt, wg, w1, w3_, w2):
        y, aux = body(xt, wg, w1, w3_ if cfg.glu else None, w2, info)
        # per-expert routed-row counts, averaged over the per-device gate
        # pools (replicated so the P() out_spec holds); the decode
        # fallback body has no capacity buffer, hence no routed counts
        routed = aux.get("routed",
                         jnp.zeros((cfg.n_experts,), jnp.float32))
        aux = {k: aux[k] for k in ("aux_loss", "z_loss", "drop_frac")}
        aux["expert_load"] = lax.pmean(routed, tuple(mesh.axis_names))
        return y.astype(x.dtype), aux

    xt = x.reshape(tokens_global, M)
    # trace-time telemetry tags: runtime events whose callbacks are
    # built while tracing this layer (the fp8 saturation monitor) carry
    # which apply_moe call / schedule / wire they belong to.
    with obs.trace_tag(moe_call=next(_TRACE_ORDINAL), schedule=sched,
                       wire=wire):
        y, aux = compat.shard_map(
            shard_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False)(xt, params["wg"], params["w1"], w3,
                             params["w2"])
    y = y.reshape(B, L, M)

    if cfg.n_shared_experts:
        h = jnp.einsum("blm,mf->blf", x, params["shared_w1"])
        h = jax.nn.silu(h) * jnp.einsum("blm,mf->blf", x, params["shared_w3"])
        y = y + jnp.einsum("blf,fm->blm", h, params["shared_w2"])
    return y, aux
