"""AdamW with gradient clipping, cosine LR schedule, and sharding-aware
optimizer state (moments inherit the parameter PartitionSpecs; optional
ZeRO-1 shards the leading dim over the DP axes when divisible).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    zero1: bool = False


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs, mesh=None, dp_axes=(), zero1=False,
                    params_shape=None):
    """Moments inherit param specs; ZeRO-1 additionally shards dim 0 over
    the DP axes when the dim is divisible and currently unsharded."""
    from repro.parallel.mesh import axis_size

    def z1(spec, shaped):
        if not zero1 or not dp_axes or mesh is None:
            return spec
        parts = list(spec) + [None] * (len(shaped.shape) - len(spec))
        n = axis_size(mesh, dp_axes)
        # shard the largest still-unsharded dim divisible by n (dim 0 is
        # often the layer-stack axis, rarely divisible)
        best = None
        for i, (d, sp) in enumerate(zip(shaped.shape, parts)):
            if sp is None and d % max(n, 1) == 0 and d >= n:
                if best is None or d > shaped.shape[best]:
                    best = i
        if best is not None:
            parts[best] = tuple(dp_axes)
            return P(*parts)
        return spec

    if zero1 and params_shape is not None:
        mom = jax.tree.map(z1, param_specs, params_shape,
                           is_leaf=lambda x: isinstance(x, P))
    else:
        mom = param_specs
    return {"mu": mom, "nu": jax.tree.map(lambda s: s, mom,
                                          is_leaf=lambda x: isinstance(x, P)),
            "step": P()}


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 decay_mask=None, lr_scale=1.0, finite=None):
    """One AdamW step. decay_mask: pytree of bool (True = apply WD);
    defaults to ndim >= 2 leaves (no WD on norms/biases/gates).
    ``lr_scale`` multiplies the scheduled LR (the guard rails' dynamic
    backoff knob); the default 1.0 is bit-exact with no scaling.

    ``finite`` (a traced bool scalar, e.g. ``isfinite(loss)``) opts into
    the guard rails' skip-step: it is AND-ed with ``isfinite(grad_norm)``
    and the select ``where(finite, new, old)`` is applied *inside* each
    leaf's update expression — XLA fuses it into the same elementwise
    loop as the update itself, so the guarded step costs no extra memory
    pass over the trees (a separate post-hoc tree-select measurably does
    not fuse).  A masked-out step leaves params, moments, and the step
    counter bit-identical to never having run; the combined mask comes
    back in the metrics as ``"finite"``."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step) * lr_scale
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    if finite is not None:
        finite = finite & jnp.isfinite(gnorm)

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, mu0, nu0, wd):
        g = g.astype(jnp.float32) * scale
        mu = cfg.beta1 * mu0 + (1 - cfg.beta1) * g
        nu = cfg.beta2 * nu0 + (1 - cfg.beta2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if wd:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if finite is not None:
            p2 = jnp.where(finite, p2, p)
            mu = jnp.where(finite, mu, mu0)
            nu = jnp.where(finite, nu, nu0)
        return p2, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    flat_wd = tdef.flatten_up_to(decay_mask)
    new = [upd(p, g, mu, nu, wd) for p, g, mu, nu, wd
           in zip(flat_p, flat_g, flat_mu, flat_nu, flat_wd)]
    new_p = tdef.unflatten([t[0] for t in new])
    new_state = {"mu": tdef.unflatten([t[1] for t in new]),
                 "nu": tdef.unflatten([t[2] for t in new]),
                 "step": step if finite is None
                 else jnp.where(finite, step, state["step"])}
    om = {"grad_norm": gnorm, "lr": lr}
    if finite is not None:
        om["finite"] = finite
    return new_p, new_state, om
