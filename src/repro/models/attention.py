"""Attention: GQA / MHA, causal, sliding-window, chunked-local and cross.

Two execution paths share one parameter layout:
  * full einsum attention for short sequences (and as the oracle),
  * a flash-style KV-block scan (online softmax, pure jnp + lax.scan) for
    long sequences — memory O(L * block) instead of O(L^2), lowerable on
    any backend; the Pallas TPU kernel (repro.kernels.flash_attention)
    implements the same contract with explicit VMEM tiling.

Decode: one query token against a KV cache; sliding-window caches are
ring buffers of size ``window``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope, dense_init


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    use_rope: bool = True
    causal: bool = True
    window: int | None = None     # sliding window (tokens), None = full
    chunk: int | None = None      # llama4-style chunked local attention
    qkv_bias: bool = False
    softmax_scale: float | None = None
    flash_block: int = 512        # KV block for the scan path
    flash_threshold: int = 2048   # use scan path above this seq length
    masked_cache_update: bool = False  # elementwise cache write (§Perf C2)
    context_parallel: bool = False     # shard scores over cache length (§Perf C3)

    @property
    def scale(self):
        return self.softmax_scale or 1.0 / math.sqrt(self.head_dim)


def init_attn(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (D, K * hd), dtype=dtype),
        "wv": dense_init(ks[2], (D, K * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, D), fan_in=H * hd, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def attn_specs(mesh, mp_axes, cfg: AttnConfig):
    from repro.parallel.mesh import axis_size
    n_mp = axis_size(mesh, mp_axes) if mp_axes else 1
    q_ax = tuple(mp_axes) if mp_axes and (cfg.n_heads * cfg.head_dim) % n_mp == 0 \
        else None
    kv_ax = tuple(mp_axes) if mp_axes and cfg.n_kv_heads % n_mp == 0 else None
    kv_sp = tuple(mp_axes) if kv_ax else None
    p = {"wq": P(None, q_ax), "wk": P(None, kv_sp), "wv": P(None, kv_sp),
         "wo": P(q_ax, None)}
    if cfg.qkv_bias:
        p["bq"] = P(q_ax)
        p["bk"] = P(kv_sp)
        p["bv"] = P(kv_sp)
    return p


def _mask_bias(cfg: AttnConfig, q_pos, k_pos):
    """Additive mask from query/key absolute positions."""
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones_like(d, dtype=bool)
    if cfg.causal:
        ok &= d >= 0
    if cfg.window is not None:
        ok &= d < cfg.window
    if cfg.chunk is not None:
        ok &= (q_pos[:, None] // cfg.chunk) == (k_pos[None, :] // cfg.chunk)
    # finite mask constant: fully-masked KV blocks stay NaN-free in the
    # online softmax (exp(-inf - -inf) is NaN; -1e30 self-corrects via the
    # running-max rescale) and give exactly-zero probabilities in the
    # recompute backward.
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def sdpa_full(q, k, v, bias, scale):
    """q: (B,Lq,H,hd)  k,v: (B,Lk,H,hd)  bias: (Lq,Lk) or (B,1,Lq,Lk)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = s + (bias if bias.ndim == 4 else bias[None, None])
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def sdpa_flash_scan(q, k, v, cfg: AttnConfig, q_pos, k_pos):
    """Online-softmax attention scanning KV blocks; O(L*block) memory in
    BOTH directions: a recompute-based custom_vjp stores only (out, lse)
    and rebuilds each block's probabilities in the backward pass — the
    flash-attention backward.  (Scan's default AD saved every block's
    probability tile + f32 accumulator carry: ~130 GB/chip for command-r
    train_4k — EXPERIMENTS.md §Perf D3/D4.)"""
    blk = min(cfg.flash_block, k.shape[1])
    while k.shape[1] % blk:
        blk //= 2

    @jax.custom_vjp
    def attn(q, k, v, q_pos, k_pos):
        out, lse = _flash_fwd_scan(q, k, v, cfg, q_pos, k_pos, blk)
        return out

    def fwd(q, k, v, q_pos, k_pos):
        out, lse = _flash_fwd_scan(q, k, v, cfg, q_pos, k_pos, blk)
        return out, (q, k, v, out, lse, q_pos, k_pos)

    def bwd(res, dout):
        *res5, q_pos, k_pos = res
        dq, dk, dv = _flash_bwd_scan(tuple(res5), dout, cfg, q_pos,
                                     k_pos, blk)
        return dq, dk, dv, None, None

    attn.defvjp(fwd, bwd)
    return attn(q, k, v, q_pos, k_pos)


def _flash_fwd_scan(q, k, v, cfg: AttnConfig, q_pos, k_pos, blk):
    B, Lq, H, hd = q.shape
    n_blocks = k.shape[1] // blk
    qf = q.astype(jnp.float32) * cfg.scale

    def step(carry, blk_idx):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(k, blk_idx * blk, blk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, blk_idx * blk, blk, axis=1)
        kp = lax.dynamic_slice_in_dim(k_pos, blk_idx * blk, blk, axis=0)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, ks.astype(jnp.float32))
        s = s + _mask_bias(cfg, q_pos, kp)[None, None]
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Lq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Lq), jnp.float32)
    a0 = jnp.zeros((B, H, Lq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), jnp.arange(n_blocks))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(l)                                  # (B, H, Lq)
    return out, lse


def _flash_bwd_scan(res, dout, cfg: AttnConfig, q_pos, k_pos, blk):
    q, k, v, out, lse = res
    B, Lq, H, hd = q.shape
    n_blocks = k.shape[1] // blk
    qf = q.astype(jnp.float32) * cfg.scale
    do = dout.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B, H, Lq, hd)
    of = out.astype(jnp.float32).transpose(0, 2, 1, 3)
    D = jnp.sum(do * of, axis=-1)                         # (B, H, Lq)

    def step(dq, blk_idx):
        ks = lax.dynamic_slice_in_dim(k, blk_idx * blk, blk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, blk_idx * blk, blk, axis=1)
        kp = lax.dynamic_slice_in_dim(k_pos, blk_idx * blk, blk, axis=0)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, ks.astype(jnp.float32))
        s = s + _mask_bias(cfg, q_pos, kp)[None, None]
        p = jnp.exp(s - lse[..., None])                   # (B, H, Lq, blk)
        dv_b = jnp.einsum("bhqk,bhqd->bkhd", p, do)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do, vs.astype(jnp.float32))
        ds = p * (dp - D[..., None])
        dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds,
                             ks.astype(jnp.float32)) * cfg.scale
        dk_b = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Lq, H, hd), jnp.float32)
    dq, (dks, dvs) = lax.scan(step, dq0, jnp.arange(n_blocks))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(k.shape)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(v.shape)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


def apply_attn(p, cfg: AttnConfig, x, *, positions=None, kv_x=None,
               kv_positions=None, use_pallas=False, kernel=None):
    """Training/prefill forward. kv_x != None = cross attention.

    Kernel-backend selection: ``use_pallas=True`` (legacy flag) or a
    ``kernel`` config resolving to ``"pallas"`` routes self-attention
    through the registry's ``flash_attention`` op; otherwise the jnp paths
    below (full sdpa / online-softmax scan) run — they ARE the reference
    implementation, with masking modes the kernel doesn't cover (chunked
    local attention, arbitrary position vectors).
    """
    B, L, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = kv_x if kv_x is not None else x
    Lk = src.shape[1]
    q = (x @ p["wq"]).reshape(B, L, H, hd)
    k = (src @ p["wk"]).reshape(B, Lk, K, hd)
    v = (src @ p["wv"]).reshape(B, Lk, K, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(K, hd)
        v = v + p["bv"].reshape(K, hd)
    # the Pallas kernel derives positions from block indices, so it is only
    # valid for the default contiguous-from-zero layout (record before the
    # arange defaults are filled in)
    contiguous_pos = positions is None and kv_positions is None
    if positions is None:
        positions = jnp.arange(L)
    if kv_positions is None:
        kv_positions = jnp.arange(Lk)
    if cfg.use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    from repro.kernels.registry import get_op, resolve_backend
    want_pallas = use_pallas or (
        kernel is not None and resolve_backend(cfg=kernel) == "pallas")
    # the kernel handles causal/window masks over contiguous positions only
    kernel_ok = cfg.chunk is None and kv_x is None and contiguous_pos
    if want_pallas and kernel_ok:
        # KV stays in its native GQA layout — the kernel's index map folds
        # the query-head -> kv-head mapping, no repeat ever hits HBM
        op = get_op("flash_attention", cfg=kernel, causal=cfg.causal,
                    window=cfg.window, scale=cfg.scale)
        out = op(q, k, v)
    else:
        k = _repeat_kv(k, H // K)
        v = _repeat_kv(v, H // K)
        if max(L, Lk) > cfg.flash_threshold:
            out = sdpa_flash_scan(q, k, v, cfg, positions, kv_positions)
        else:
            bias = _mask_bias(cfg, positions, kv_positions) if (
                cfg.causal or cfg.window or cfg.chunk) else jnp.zeros(
                    (L, Lk), jnp.float32)
            out = sdpa_full(q, k, v, bias, cfg.scale)
    return out.reshape(B, L, H * hd) @ p["wo"]


# --- decode with KV cache -----------------------------------------------------

def init_cache(cfg: AttnConfig, batch, max_len, dtype=jnp.float32):
    W = cfg.window if cfg.window is not None else max_len
    W = min(W, max_len)
    return {
        "k": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        # absolute position per slot, tracked per row: the serving
        # engine's continuous batching puts every request at its own
        # position, so slot validity is per (row, slot), not per slot
        "pos": jnp.zeros((batch, W), jnp.int32) - 1,
    }


def prefill_attn(p, cfg: AttnConfig, x, cache, lengths, *, kernel=None):
    """Batched one-shot prefill: whole-prompt self-attention + KV fill.

    ``x`` is the (B, L, D) right-padded prompt batch, ``lengths`` the
    (B,) valid token counts.  One call computes the causal attention
    over every prompt position AND writes the (rope-rotated) K/V into
    the decode cache at positions ``0..L-1``; the per-row ``pos`` map
    marks only slots ``< lengths[b]`` valid, so padding (and any stale
    K/V from a previous occupant of the cache row) is invisible to later
    decode steps.  Causality keeps padded positions from influencing
    valid ones, so each row's result is independent of how much padding
    its prefill bucket carries.

    Requires a full-length cache (``W >= L``): the engine rejects
    sliding-window archs rather than re-deriving ring-buffer fills.
    """
    B, L, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    W = cache["k"].shape[1]
    if W < L:
        raise ValueError(f"prefill_attn needs cache W={W} >= prompt L={L}")
    q = (x @ p["wq"]).reshape(B, L, H, hd)
    k = (x @ p["wk"]).reshape(B, L, K, hd)
    v = (x @ p["wv"]).reshape(B, L, K, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(K, hd)
        v = v + p["bv"].reshape(K, hd)
    positions = jnp.arange(L)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # cache fill: K/V land at their absolute positions (post-rope, the
    # same values decode_attn would have written one token at a time)
    widx = jnp.arange(W)
    valid = (widx[None, :] < lengths[:, None]) & (widx < L)[None]
    new_cache = {
        "k": lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
        "v": lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
        "pos": jnp.where(valid, widx[None, :], -1).astype(jnp.int32),
    }

    from repro.kernels.registry import get_op, resolve_backend
    want_pallas = kernel is not None and \
        resolve_backend(cfg=kernel) == "pallas"
    if want_pallas and cfg.chunk is None:
        op = get_op("flash_attention", cfg=kernel, causal=cfg.causal,
                    window=cfg.window, scale=cfg.scale)
        out = op(q, k, v)
    else:
        kk = _repeat_kv(k, H // K)
        vv = _repeat_kv(v, H // K)
        if L > cfg.flash_threshold:
            out = sdpa_flash_scan(q, kk, vv, cfg, positions, positions)
        else:
            out = sdpa_full(q, kk, vv,
                            _mask_bias(cfg, positions, positions),
                            cfg.scale)
    return out.reshape(B, L, H * hd) @ p["wo"], new_cache


def paged_chunk_attn(p, cfg: AttnConfig, x, arena, table, starts, lens):
    """Unified paged attention: ONE primitive for decode, one-shot
    prefill and chunked prefill, reading/writing the block arena through
    per-row page tables.

    ``x`` is a (B, C, D) chunk of per-row token spans: row b holds
    ``lens[b]`` valid tokens at absolute positions ``starts[b] ..
    starts[b] + lens[b] - 1``.  ``C = 1`` with ``lens = 1`` is a decode
    step; ``starts = 0`` with the whole prompt is one-shot prefill;
    anything between is a prefill chunk.  ``arena`` is this layer's
    paged cache ``{"k","v": (N, bs, Kh, hd), "pos": (N, bs)}`` (physical
    page 0 = the null page), ``table`` the (B, nb) int32 page table.

    The chunk's rope-rotated K/V are scattered into the arena at flat
    page slots ``table[b, p // bs] * bs + p % bs`` (invalid rows target
    the null page and write ``pos = -1``), then every query attends the
    full gathered ``(B, nb * bs)`` context.  Because the gather lays
    position p at index p — exactly the slab cache's layout — and the
    score/softmax op order below matches ``sdpa_full``/``decode_attn``,
    outputs are bit-identical to the slab paths (masked columns are
    exact zeros after softmax; see the paged-vs-slab oracle in
    tests/helpers/run_paged_parity.py).

    Returns ``(out (B, C, D), new_arena)``.
    """
    B, C, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    N, bs = arena["pos"].shape
    nb = table.shape[1]
    q = (x @ p["wq"]).reshape(B, C, H, hd)
    k = (x @ p["wk"]).reshape(B, C, K, hd)
    v = (x @ p["wv"]).reshape(B, C, K, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(K, hd)
        v = v + p["bv"].reshape(K, hd)
    offs = jnp.arange(C)
    qpos = starts[:, None] + offs[None, :]                # (B, C) absolute
    valid_q = offs[None, :] < lens[:, None]               # (B, C)
    if cfg.use_rope:
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    # scatter the chunk into the arena (flat (N*bs, ...) view): invalid
    # rows/pages land in the null page with pos -1, so they stay masked
    blk_idx = jnp.clip(qpos // bs, 0, nb - 1)
    phys = jnp.take_along_axis(table, blk_idx, axis=1)    # (B, C)
    ok = valid_q & (phys > 0) & (qpos < nb * bs)
    flat = jnp.where(ok, phys * bs + qpos % bs, 0).reshape(-1)
    pos_w = jnp.where(ok, qpos, -1).astype(jnp.int32).reshape(-1)
    # invalid writes are VALUE-zeroed, not just masked: an idle row's
    # hidden state is NaN (its whole context is masked), and a NaN in
    # the null page would leak into live rows through the value einsum
    # (softmax weight 0 * NaN = NaN).  Zeros keep the null page inert
    # AND make the duplicate-index scatter at flat slot 0 deterministic.
    okk = ok.reshape(-1)[:, None, None]
    k_w = jnp.where(okk, k.reshape(-1, K, hd), 0).astype(arena["k"].dtype)
    v_w = jnp.where(okk, v.reshape(-1, K, hd), 0).astype(arena["v"].dtype)
    new_arena = {
        "k": arena["k"].reshape(N * bs, K, hd)
        .at[flat].set(k_w).reshape(N, bs, K, hd),
        "v": arena["v"].reshape(N * bs, K, hd)
        .at[flat].set(v_w).reshape(N, bs, K, hd),
        "pos": arena["pos"].reshape(N * bs)
        .at[flat].set(pos_w).reshape(N, bs),
    }

    # gather each row's full context: page p // bs, offset p % bs —
    # gathered index IS the absolute position (the slab layout)
    gk = jnp.take(new_arena["k"], table, axis=0).reshape(B, nb * bs, K, hd)
    gv = jnp.take(new_arena["v"], table, axis=0).reshape(B, nb * bs, K, hd)
    gpos = jnp.take(new_arena["pos"], table, axis=0).reshape(B, nb * bs)
    kk = _repeat_kv(gk, H // K)
    vv = _repeat_kv(gv, H // K)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * cfg.scale
    gp = gpos[:, None, :]                                 # (B, 1, W)
    qp = qpos[:, :, None]                                 # (B, C, 1)
    valid = (gp >= 0) & (gp <= qp)                        # (B, C, W)
    if cfg.window is not None:
        valid &= gp > qp - cfg.window
    if cfg.chunk is not None:
        valid &= (gp // cfg.chunk) == (qp // cfg.chunk)
    s = jnp.where(valid[:, None], s, -jnp.inf)
    pr = jax.nn.softmax(s, -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vv)
    return out.reshape(B, C, H * hd) @ p["wo"], new_arena


def decode_attn(p, cfg: AttnConfig, x, cache, step, *, kv_cache_static=None,
                mesh=None, mp_axes=None):
    """One-token decode. x: (B, 1, D); ``step`` is the absolute position —
    a scalar (classic lockstep serving: every row at the same position)
    or a ``(B,)`` vector (continuous batching: each row at its own).

    Full-attention caches are length max_len; sliding-window caches are
    ring buffers of size ``window`` (slot = pos % window).
    """
    B, _, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    if kv_cache_static is not None:
        # cross-attention: static precomputed K/V (e.g. image/audio context)
        k, v = kv_cache_static["k"], kv_cache_static["v"]
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(H, hd)
        k = _repeat_kv(k, H // K)
        v = _repeat_kv(v, H // K)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * cfg.scale
        pr = jax.nn.softmax(s, -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", pr, v)
        return out.reshape(B, 1, H * hd) @ p["wo"], cache

    k = (x @ p["wk"]).reshape(B, 1, K, hd)
    v = (x @ p["wv"]).reshape(B, 1, K, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(H, hd)
        k = k + p["bk"].reshape(K, hd)
        v = v + p["bv"].reshape(K, hd)
    vec = jnp.ndim(step) > 0                 # per-row positions (engine)
    if cfg.use_rope:
        pos = step[:, None] if vec else jnp.full((1,), step)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = step % W
    if vec:
        # per-row slot write: each request appends at its own position
        onehot = jnp.arange(W)[None, :] == slot[:, None]      # (B, W)
        ck = jnp.where(onehot[..., None, None],
                       k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(onehot[..., None, None],
                       v.astype(cache["v"].dtype), cache["v"])
        cpos = jnp.where(onehot, step[:, None], cache["pos"])
    elif cfg.masked_cache_update:
        # elementwise masked write: partitions cleanly when the cache
        # length dim is sharded (context-parallel decode), unlike a
        # dynamic-update-slice at a data-dependent offset which makes
        # GSPMD all-gather the cache (§Perf C2).
        onehot = (jnp.arange(W) == slot)
        ck = jnp.where(onehot[None, :, None, None],
                       k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(onehot[None, :, None, None],
                       v.astype(cache["v"].dtype), cache["v"])
        cpos = jnp.where(onehot[None, :], step, cache["pos"])
    else:
        ck = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        cpos = cache["pos"].at[:, slot].set(step)
    new_cache = {"k": ck, "v": cv, "pos": cpos}

    kk = _repeat_kv(ck, H // K)
    vv = _repeat_kv(cv, H // K)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * cfg.scale
    if mesh is not None and mp_axes and cfg.context_parallel \
            and W % math.prod(mesh.shape[a] for a in mp_axes) == 0:
        # context-parallel decode (§Perf C3): keep scores sharded along the
        # cache-length dim so GSPMD reshards the tiny query instead of
        # all-gathering the multi-GB K/V cache.
        from jax.sharding import NamedSharding
        s = lax.with_sharding_constraint(
            s, NamedSharding(mesh, P(None, None, None, tuple(mp_axes))))
    step_b = step[:, None] if vec else step
    valid = (cpos >= 0) & (cpos <= step_b)                    # (B, W)
    if cfg.window is not None:
        valid &= cpos > step_b - cfg.window
    if cfg.chunk is not None:
        valid &= (cpos // cfg.chunk) == (step_b // cfg.chunk)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, -1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vv)
    return out.reshape(B, 1, H * hd) @ p["wo"], new_cache
