"""Model assembly: embedding -> run-partitioned scanned blocks -> head.

Layers are grouped into maximal consecutive same-kind runs; each run's
parameters are stacked with a leading layer axis and executed with
``lax.scan`` so the lowered HLO stays compact for 40+-layer models (the
multi-pod dry-run compiles every architecture at full size).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.attention import AttnConfig
from repro.models.layers import (apply_norm, embed, embedding_specs,
                                 init_embedding, init_norm, norm_specs,
                                 sinusoidal_positions, unembed)
from repro.parallel.mesh import ParallelDims, axis_size as _axis_size


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.runs = cfg.runs()
        self.has_cross = any(
            blk.base_kind(k) in ("cross", "xdec") for k, _ in self.runs)

    # --- params -----------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, len(self.runs) + 4)
        params = {"embed": init_embedding(keys[0], cfg.vocab_size,
                                          cfg.d_model, dtype),
                  "final_norm": init_norm(cfg.d_model, cfg.norm_type)}
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size),
                                       dtype) / math.sqrt(cfg.d_model)}
        for r, (kind, n) in enumerate(self.runs):
            ks = jax.random.split(keys[2 + r], n)
            stacked = jax.vmap(
                lambda k: blk.init_block(k, cfg, kind, dtype))(ks)
            params[f"run{r}"] = stacked
        if cfg.arch_type == "audio" and cfg.encoder_layers:
            ks = jax.random.split(keys[-1], cfg.encoder_layers)
            params["encoder"] = jax.vmap(
                lambda k: blk.init_block(k, cfg, "encoder", dtype))(ks)
            params["enc_norm"] = init_norm(cfg.d_model, cfg.norm_type)
        return params

    def specs(self, mesh, dims: ParallelDims) -> dict:
        cfg = self.cfg
        specs = {"embed": embedding_specs(mesh, dims.mp, cfg.vocab_size),
                 "final_norm": norm_specs(cfg.norm_type)}
        if not cfg.tie_embeddings:
            v_ax = embedding_specs(mesh, dims.mp, cfg.vocab_size)["table"][0]
            specs["lm_head"] = {"w": P(None, v_ax)}

        def add_layer_dim(spec):
            return P(*((None,) + tuple(spec)))

        for r, (kind, n) in enumerate(self.runs):
            s = blk.block_specs(cfg, kind, mesh, dims)
            specs[f"run{r}"] = jax.tree.map(
                add_layer_dim, s, is_leaf=lambda x: isinstance(x, P))
        if cfg.arch_type == "audio" and cfg.encoder_layers:
            s = blk.block_specs(cfg, "encoder", mesh, dims)
            specs["encoder"] = jax.tree.map(
                add_layer_dim, s, is_leaf=lambda x: isinstance(x, P))
            specs["enc_norm"] = norm_specs(cfg.norm_type)
        return specs

    # --- forward ------------------------------------------------------------
    def _encode_ctx(self, params, batch):
        """Context tokens for cross-attention: VLM image embeds (stub
        frontend) or the whisper encoder run over stub audio frames."""
        cfg = self.cfg
        ctx = batch.get("ctx_embeds")
        if ctx is None:
            return None
        if cfg.arch_type == "audio":
            x = ctx + sinusoidal_positions(ctx.shape[1],
                                           cfg.d_model).astype(ctx.dtype)

            def enc_step(h, layer_params):
                h, _ = blk.apply_block(layer_params, cfg, "encoder", h,
                                       mesh=self._mesh, dims=self._dims)
                return h, None

            x, _ = lax.scan(enc_step, x, params["encoder"])
            return apply_norm(params["enc_norm"], x, cfg.norm_eps,
                              cfg.kernel_cfg)
        return ctx

    def forward(self, params, batch, *, mesh, dims: ParallelDims,
                schedule: Optional[str] = None):
        """Full-sequence forward (train / prefill). Returns (logits, aux)."""
        x, aux = self._backbone(params, batch, mesh=mesh, dims=dims,
                                schedule=schedule)
        return self._head(params, x), aux

    def _backbone(self, params, batch, *, mesh, dims: ParallelDims,
                  schedule: Optional[str] = None):
        """Embedding -> blocks -> final norm (no LM head)."""
        cfg = self.cfg
        self._mesh, self._dims = mesh, dims
        tokens = batch["tokens"]
        B, L = tokens.shape
        x = embed(params["embed"], tokens)
        if not cfg.use_rope and cfg.arch_type not in ("ssm",):
            x = x + sinusoidal_positions(L, cfg.d_model).astype(x.dtype)
        ctx = self._encode_ctx(params, batch)
        # None = the default contiguous-from-zero layout; apply_attn fills in
        # the arange itself and stays eligible for the Pallas kernel path
        # (which derives positions from block indices).
        positions = None
        aux_total = jnp.float32(0.0)
        expert_load = jnp.zeros((0,), jnp.float32)

        seq_spec = None
        if cfg.seq_parallel and dims.mp and L % max(
                1, _axis_size(mesh, dims.mp)) == 0:
            # Megatron-SP (§Perf B2): keep the residual stream sequence-
            # sharded over MP between blocks; GSPMD turns the per-layer
            # AllReduces into ReduceScatter+AllGather and runs the norms /
            # residual adds on L/N_MP tokens.
            from jax.sharding import NamedSharding, PartitionSpec as P
            baxes = tuple(dims.batch_axes) or None
            seq_spec = NamedSharding(mesh, P(baxes, tuple(dims.mp), None))

        for r, (kind, n) in enumerate(self.runs):
            def step(h, layer_params, kind=kind):
                h2, aux = blk.apply_block(
                    layer_params, cfg, kind, h, mesh=mesh, dims=dims,
                    ctx=ctx, positions=positions, schedule=schedule)
                if seq_spec is not None:
                    h2 = jax.lax.with_sharding_constraint(h2, seq_spec)
                return h2, aux

            if cfg.remat:
                step = jax.checkpoint(step)
            x, auxs = lax.scan(step, x, params[f"run{r}"])
            aux_total = aux_total + jnp.sum(auxs["loss"])
            if auxs["expert_load"].shape[-1]:
                run_load = jnp.sum(auxs["expert_load"], axis=0)  # (E,)
                expert_load = run_load if not expert_load.shape[-1] \
                    else expert_load + run_load

        x = apply_norm(params["final_norm"], x, cfg.norm_eps,
                       cfg.kernel_cfg)
        return x, {"aux_loss": aux_total, "expert_load": expert_load}

    def _head(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = x @ params["lm_head"]["w"]
        return logits * cfg.logit_scale

    def loss(self, params, batch, *, mesh, dims, schedule=None):
        cfg = self.cfg
        self._mesh, self._dims = mesh, dims
        tokens = batch["tokens"]
        labels = batch["labels"]
        B, L = tokens.shape

        # run the backbone once; compute CE in sequence chunks so the
        # (B, L, V) f32 logits are never materialized (134 GB/chip for
        # command-r train_4k otherwise — see EXPERIMENTS.md §Perf).
        hidden, aux = self._backbone(params, batch, mesh=mesh,
                                     dims=dims, schedule=schedule)
        logits_fn_input = hidden
        b_local = max(B // max(_axis_size(mesh, dims.batch_axes), 1), 1)
        chunk = L
        while b_local * chunk * cfg.vocab_size > (1 << 28) and chunk % 2 == 0:
            chunk //= 2
        n_chunks = L // chunk if L % chunk == 0 else 1
        if n_chunks <= 1:
            logits = self._head(params, logits_fn_input)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            ll = jnp.take_along_axis(logp, labels[..., None],
                                     axis=-1)[..., 0]
            mask = (labels >= 0).astype(jnp.float32)
            ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            def chunk_ce(x_c, y_c):
                logits = self._head(params, x_c)
                logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                ll = jnp.take_along_axis(logp, y_c[..., None], -1)[..., 0]
                m = (y_c >= 0).astype(jnp.float32)
                return jnp.sum(-ll * m), jnp.sum(m)

            def step(carry, idx):
                x_c = lax.dynamic_slice_in_dim(logits_fn_input,
                                               idx * chunk, chunk, 1)
                y_c = lax.dynamic_slice_in_dim(labels, idx * chunk,
                                               chunk, 1)
                s, n = jax.checkpoint(chunk_ce)(x_c, y_c)
                return (carry[0] + s, carry[1] + n), None

            (tot, n), _ = lax.scan(step, (jnp.float32(0.0),
                                          jnp.float32(0.0)),
                                   jnp.arange(n_chunks))
            ce = tot / jnp.maximum(n, 1.0)
        total = ce + aux["aux_loss"]
        return total, {"ce": ce, "aux": aux["aux_loss"],
                       "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0)),
                       # per-expert routed-row counts, summed over layers
                       # ((0,) for dense models) — Trainer prints these at
                       # step 0 and the dryrun artifact records them
                       "expert_load": aux["expert_load"]}

    # --- decode ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None) -> dict:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        cache = {}
        for r, (kind, n) in enumerate(self.runs):
            one = blk.init_block_cache(cfg, kind, batch, max_len, dtype)
            cache[f"run{r}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)
        return cache

    def ctx_kv(self, params, batch, *, mesh=None, dims=None):
        """Precompute static cross-attention K/V per run (serving-side)."""
        cfg = self.cfg
        if mesh is not None:
            self._mesh, self._dims = mesh, dims
        ctx = self._encode_ctx(params, batch)
        if ctx is None:
            return None
        out = {}
        for r, (kind, n) in enumerate(self.runs):
            base = blk.base_kind(kind)
            if base not in ("cross", "xdec"):
                continue
            acfg = blk.attn_config(cfg, kind, cross=True)
            K, hd = acfg.n_kv_heads, acfg.head_dim

            def kv_one(p):
                k = (ctx @ p["xattn"]["wk"]).reshape(
                    ctx.shape[0], ctx.shape[1], K, hd)
                v = (ctx @ p["xattn"]["wv"]).reshape(
                    ctx.shape[0], ctx.shape[1], K, hd)
                return {"k": k, "v": v}

            out[f"run{r}"] = jax.vmap(kv_one)(params[f"run{r}"])
        return out

    def prefill_step(self, params, cache, batch, *, lengths, mesh,
                     dims: ParallelDims, schedule: Optional[str] = None):
        """Batched one-shot prefill: ONE forward over the right-padded
        prompts that fills every layer's KV cache (the serving engine's
        admission path — never a per-token loop).

        ``lengths`` (B,) are the valid prompt lengths; returns
        ``(last_logits, new_cache)`` where ``last_logits[b]`` is the
        (V,)-vector at row b's own final prompt position — the logits
        the first generated token is sampled from.
        """
        cfg = self.cfg
        self._mesh, self._dims = mesh, dims
        bad = [k for k, _ in self.runs
               if blk.base_kind(k) not in ("dense", "moe")]
        if bad:
            raise NotImplementedError(
                f"prefill_step: unsupported block kinds {bad} "
                "(cache-filling prefill covers dense/moe decoder stacks)")
        tokens = batch["tokens"]
        B, L = tokens.shape
        x = embed(params["embed"], tokens)
        if not cfg.use_rope:
            x = x + sinusoidal_positions(L, cfg.d_model).astype(x.dtype)
        new_cache = {}
        for r, (kind, n) in enumerate(self.runs):
            def step(h, scanned, kind=kind):
                layer_params, layer_cache = scanned
                return blk.prefill_block(
                    layer_params, cfg, kind, h, layer_cache, lengths,
                    mesh=mesh, dims=dims, schedule=schedule)

            x, new_cache[f"run{r}"] = lax.scan(
                step, x, (params[f"run{r}"], cache[f"run{r}"]))
        x = apply_norm(params["final_norm"], x, cfg.norm_eps,
                       cfg.kernel_cfg)
        idx = jnp.clip(lengths - 1, 0, L - 1)
        h_last = x[jnp.arange(B), idx]                     # (B, D)
        logits = self._head(params, h_last[:, None, :])[:, 0]
        return logits, new_cache

    def paged_step(self, params, cache, batch, *, mesh, dims,
                   schedule: Optional[str] = None, infer: bool = False,
                   with_aux: bool = False):
        """One step over a PAGED KV arena (the serving engine's unified
        path): per-row token spans written/read through page tables.

        ``batch`` holds ``tokens`` (B, C), ``starts`` (B,) absolute
        position of each row's first token, ``lens`` (B,) valid counts,
        and ``tables`` (B, max_blocks) int32 page tables into the arena
        (``cache`` leaves are ``(layers, n_pages, block_size, ...)``).
        ``C = 1``/``lens = 1``/``infer=True`` is a decode round; larger
        C is a prefill chunk (``infer=False`` keeps the prefill-shaped
        MoE autosched decision).  Returns ``(last_logits, new_cache)``
        with ``last_logits[b]`` at row b's final valid chunk position —
        only meaningful for rows whose span ends their prompt (or the
        decoded token).  ``with_aux=True`` returns ``(last_logits,
        new_cache, aux)`` where ``aux["expert_load"]`` is the (E,)
        per-expert routed-row count summed over layers ((0,) for dense
        stacks) — the serving engine's load-EMA feed; the default keeps
        existing callers' arity.
        """
        cfg = self.cfg
        self._mesh, self._dims = mesh, dims
        bad = [k for k, _ in self.runs
               if blk.base_kind(k) not in ("dense", "moe")]
        if bad:
            raise NotImplementedError(
                f"paged_step: unsupported block kinds {bad} "
                "(paged serving covers dense/moe decoder stacks)")
        tokens = batch["tokens"]
        starts, lens, tables = batch["starts"], batch["lens"], batch["tables"]
        B, C = tokens.shape
        x = embed(params["embed"], tokens)
        if not cfg.use_rope:
            pe = sinusoidal_positions(2048, cfg.d_model)
            qpos = jnp.minimum(starts[:, None] + jnp.arange(C), 2047)
            x = x + jnp.take(pe, qpos, axis=0).astype(x.dtype)
        new_cache = {}
        expert_load = jnp.zeros((0,), jnp.float32)
        for r, (kind, n) in enumerate(self.runs):
            def step(h, scanned, kind=kind):
                layer_params, layer_cache = scanned
                out = blk.paged_block(
                    layer_params, cfg, kind, h, layer_cache, tables,
                    starts, lens, mesh=mesh, dims=dims, schedule=schedule,
                    infer=infer, with_aux=with_aux)
                if with_aux:
                    h2, c2, load = out
                    return h2, (c2, load)
                return out

            if with_aux:
                x, (new_cache[f"run{r}"], loads) = lax.scan(
                    step, x, (params[f"run{r}"], cache[f"run{r}"]))
                if loads.shape[-1]:
                    run_load = jnp.sum(loads, axis=0)        # (E,)
                    expert_load = run_load if not expert_load.shape[-1] \
                        else expert_load + run_load
            else:
                x, new_cache[f"run{r}"] = lax.scan(
                    step, x, (params[f"run{r}"], cache[f"run{r}"]))
        x = apply_norm(params["final_norm"], x, cfg.norm_eps,
                       cfg.kernel_cfg)
        idx = jnp.clip(lens - 1, 0, C - 1)
        h_last = x[jnp.arange(B), idx]                    # (B, D)
        logits = self._head(params, h_last[:, None, :])[:, 0]
        if with_aux:
            return logits, new_cache, {"expert_load": expert_load}
        return logits, new_cache

    def decode_step(self, params, cache, batch, *, mesh, dims,
                    schedule=None, ctx_kv=None):
        """One serve step: (B, 1) token -> (B, 1, V) logits + new cache.
        ``batch["step"]`` is the absolute position — a scalar (lockstep)
        or a (B,) vector (continuous batching, one position per row)."""
        cfg = self.cfg
        self._mesh, self._dims = mesh, dims
        tokens = batch["tokens"]
        step = batch["step"]
        x = embed(params["embed"], tokens)
        if not cfg.use_rope and cfg.arch_type not in ("ssm",):
            pe = sinusoidal_positions(2048, cfg.d_model)
            idx = jnp.minimum(step, 2047)
            if jnp.ndim(idx) > 0:
                x = x + jnp.take(pe, idx, axis=0)[:, None, :].astype(x.dtype)
            else:
                x = x + lax.dynamic_index_in_dim(
                    pe, idx, keepdims=True).astype(x.dtype)
        new_cache = {}
        for r, (kind, n) in enumerate(self.runs):
            ckv = ctx_kv.get(f"run{r}") if ctx_kv else None

            def step_fn(h, scanned, kind=kind):
                layer_params, layer_cache, layer_ckv = scanned
                h2, c2 = blk.decode_block(
                    layer_params, cfg, kind, h, layer_cache, step,
                    mesh=mesh, dims=dims, ctx_kv=layer_ckv,
                    schedule=schedule)
                return h2, c2

            scanned = (params[f"run{r}"], cache[f"run{r}"], ckv)
            if ckv is None:
                def step_fn2(h, sc, kind=kind):
                    lp, lc = sc
                    h2, c2 = blk.decode_block(lp, cfg, kind, h, lc, step,
                                              mesh=mesh, dims=dims,
                                              schedule=schedule)
                    return h2, c2
                x, new_cache[f"run{r}"] = lax.scan(
                    step_fn2, x, (params[f"run{r}"], cache[f"run{r}"]))
            else:
                x, new_cache[f"run{r}"] = lax.scan(step_fn, x, scanned)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps,
                       cfg.kernel_cfg)
        return self._head(params, x), new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
