"""Shared neural-net layers (functional, param-dict convention).

Every module is a triple of functions:
  init_*(key, ...) -> params pytree (nested dicts of arrays)
  *_specs(...)     -> matching pytree of PartitionSpec
  apply-style function taking (params, x, ...)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.registry import get_op


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(fan_in))


# --- norms -------------------------------------------------------------------

def init_norm(d, norm_type="rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_specs(norm_type="rmsnorm"):
    p = {"scale": P(None)}
    if norm_type == "layernorm":
        p["bias"] = P(None)
    return p


def apply_norm(p, x, eps=1e-5, kernel=None):
    """LayerNorm (bias present) stays inline jnp; RMSNorm routes through the
    kernel registry (``rmsnorm`` op) so the backend follows ``kernel`` —
    the ref oracle is numerically identical to the historical inline code."""
    if "bias" in p:
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
        return out.astype(x.dtype)
    op = get_op("rmsnorm", cfg=kernel, eps=eps)
    return op(x.reshape(-1, x.shape[-1]), p["scale"]).reshape(x.shape)


# --- rotary embeddings --------------------------------------------------------

def rope_freqs(head_dim, theta=1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=1e4):
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., L, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length, d):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((length, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --- dense FFN ---------------------------------------------------------------

def init_ffn(key, d_model, d_ff, glu=True, bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
         "w_out": dense_init(ks[1], (d_ff, d_model), fan_in=d_ff, dtype=dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def ffn_specs(mesh, mp_axes, d_ff, glu=True, bias=False):
    from repro.parallel.mesh import axis_size
    ff_ax = tuple(mp_axes) if mp_axes and d_ff % axis_size(mesh, mp_axes) == 0 \
        else None
    p = {"w_in": P(None, ff_ax), "w_out": P(ff_ax, None)}
    if glu:
        p["w_gate"] = P(None, ff_ax)
    if bias:
        p["b_in"] = P(ff_ax)
        p["b_out"] = P(None)
    return p


def apply_ffn(p, x, act="silu"):
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[act]
    h = x @ p["w_in"]
    if "b_in" in p:
        h = h + p["b_in"]
    if "w_gate" in p:
        h = actf(x @ p["w_gate"]) * h
    else:
        h = actf(h)
    out = h @ p["w_out"]
    if "b_out" in p:
        out = out + p["b_out"]
    return out


# --- embeddings ---------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embedding_specs(mesh, mp_axes, vocab):
    from repro.parallel.mesh import axis_size
    v_ax = tuple(mp_axes) if mp_axes and vocab % axis_size(mesh, mp_axes) == 0 \
        else None
    return {"table": P(v_ax, None)}


def embed(p, ids):
    return p["table"][ids]


def unembed(p, x):
    return x @ p["table"].T
