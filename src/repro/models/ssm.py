"""State-space / recurrent blocks: Mamba-style selective SSM (Hymba's
parallel heads), and xLSTM's mLSTM / sLSTM cells.

Training uses chunked scans (outer lax.scan over time chunks, parallel
math within a chunk) so the lowered HLO is compact and the working set
is O(chunk), matching how these cells are executed efficiently on TPU.
Decode carries the recurrent state (O(1) per token) — this is what makes
the ``long_500k`` shape tractable for the SSM/hybrid architectures.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


# =============================== Mamba ========================================

@dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    chunk: int = 128


def init_mamba(key, cfg: MambaConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    Di, N = cfg.d_inner, cfg.d_state
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * Di), dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, Di), dtype) * 0.1,
        "conv_b": jnp.zeros((Di,), dtype),
        "w_bc": dense_init(ks[2], (Di, 2 * N), dtype=dtype),
        "w_dt": dense_init(ks[3], (Di, Di), dtype=dtype) * 0.1,
        "b_dt": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (Di,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))),
        "d_skip": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[5], (Di, cfg.d_model), fan_in=Di,
                               dtype=dtype),
    }


def mamba_specs(mesh, mp_axes, cfg: MambaConfig):
    from repro.parallel.mesh import axis_size
    n = axis_size(mesh, mp_axes) if mp_axes else 1
    di_ax = tuple(mp_axes) if mp_axes and cfg.d_inner % n == 0 else None
    return {
        "in_proj": P(None, di_ax), "conv_w": P(None, di_ax),
        "conv_b": P(di_ax), "w_bc": P(di_ax, None), "w_dt": P(None, di_ax),
        "b_dt": P(di_ax), "a_log": P(di_ax, None), "d_skip": P(di_ax),
        "out_proj": P(di_ax, None),
    }


def _mamba_chunk(h0, xs, cfg):
    """Parallel in-chunk selective scan.  xs: dict of (B, c, Di[/N]) slices;
    h0: (B, Di, N) carried state.  Returns (h_c, y)."""
    dt, Bm, Cm, xin = xs["dt"], xs["B"], xs["C"], xs["x"]
    a = -jnp.exp(xs["a_log"])                                   # (Di, N)
    dA = jnp.exp(dt[..., None] * a)                             # (B,c,Di,N)
    dBx = (dt * xin)[..., None] * Bm[:, :, None, :]             # (B,c,Di,N)
    # associative scan over the chunk: h_t = dA_t h_{t-1} + dBx_t

    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    pA, pH = lax.associative_scan(comb, (dA, dBx), axis=1)
    h = pA * h0[:, None] + pH                                   # (B,c,Di,N)
    y = jnp.einsum("bcdn,bcn->bcd", h, Cm)
    return h[:, -1], y


def apply_mamba(p, cfg: MambaConfig, x, state=None):
    """x: (B, L, D).  state=None -> training (returns y only);
    state=(conv_buf, h) -> single-token decode (L==1), returns (y, state)."""
    B, L, D = x.shape
    Di, N, C = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                          # (B, L, Di)

    if state is None:
        pad = jnp.pad(xin, ((0, 0), (C - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + L] * p["conv_w"][i] for i in range(C))
        conv = jax.nn.silu(conv + p["conv_b"])
        dt = jax.nn.softplus(conv @ p["w_dt"] + p["b_dt"])
        bc = conv @ p["w_bc"]
        Bm, Cm = jnp.split(bc, 2, axis=-1)                      # (B, L, N)
        chunk = min(cfg.chunk, L)
        while L % chunk:
            chunk //= 2
        n_chunks = L // chunk

        def step(h, idx):
            sl = lambda t: lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
            h2, y = _mamba_chunk(
                h, {"dt": sl(dt), "B": sl(Bm), "C": sl(Cm), "x": sl(conv),
                    "a_log": p["a_log"]}, cfg)
            return h2, y

        h0 = jnp.zeros((B, Di, N), jnp.float32)
        _, ys = lax.scan(step, h0, jnp.arange(n_chunks))
        y = ys.transpose(1, 0, 2, 3).reshape(B, L, Di)
        y = y + conv * p["d_skip"]
        return (y * jax.nn.silu(z)).astype(x.dtype) @ p["out_proj"]

    # ---- decode: one step ----
    conv_buf, h = state                                          # (B,C,Di), (B,Di,N)
    conv_buf = jnp.concatenate([conv_buf[:, 1:], xin], axis=1)
    conv = jax.nn.silu(
        jnp.einsum("bcd,cd->bd", conv_buf, p["conv_w"]) + p["conv_b"])
    dt = jax.nn.softplus(conv @ p["w_dt"] + p["b_dt"])           # (B, Di)
    bc = conv @ p["w_bc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    a = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt[..., None] * a)
    h = dA * h + (dt * conv)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + conv * p["d_skip"]
    y = (y * jax.nn.silu(z[:, 0])).astype(x.dtype) @ p["out_proj"]
    return y[:, None], (conv_buf, h)


def init_mamba_state(cfg: MambaConfig, batch, dtype=jnp.float32):
    return (jnp.zeros((batch, cfg.d_conv, cfg.d_inner), dtype),
            jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32))


# =============================== mLSTM ========================================

@dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    chunk: int = 64

    @property
    def d_inner(self):
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self):
        return self.d_inner // self.n_heads


def init_mlstm(key, cfg: MLSTMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    D, Di = cfg.d_model, cfg.d_inner
    return {
        "up_proj": dense_init(ks[0], (D, 2 * Di), dtype=dtype),
        "wq": dense_init(ks[1], (Di, Di), dtype=dtype),
        "wk": dense_init(ks[2], (Di, Di), dtype=dtype),
        "wv": dense_init(ks[3], (Di, Di), dtype=dtype),
        "w_if": dense_init(ks[4], (Di, 2 * cfg.n_heads), dtype=dtype) * 0.1,
        "b_i": jnp.zeros((cfg.n_heads,), jnp.float32) - 3.0,
        "b_f": jnp.zeros((cfg.n_heads,), jnp.float32) + 3.0,
        "down_proj": dense_init(ks[5], (Di, D), fan_in=Di, dtype=dtype),
    }


def mlstm_specs(mesh, mp_axes, cfg: MLSTMConfig):
    from repro.parallel.mesh import axis_size
    n = axis_size(mesh, mp_axes) if mp_axes else 1
    ax = tuple(mp_axes) if mp_axes and cfg.d_inner % n == 0 else None
    return {"up_proj": P(None, ax), "wq": P(None, ax), "wk": P(None, ax),
            "wv": P(None, ax), "w_if": P(None, None), "b_i": P(None),
            "b_f": P(None), "down_proj": P(ax, None)}


def _mlstm_chunk(carry, qkvif, cfg):
    """Stabilized chunkwise mLSTM (matrix memory + normalizer).

    carry: C (B,H,dk,dv), n (B,H,dk), m (B,H).
    qkvif: q,k,v (B,c,H,hd); logi, logf (B,c,H).
    """
    C, nrm, m = carry
    q, k, v, logi, logf = qkvif
    B, c, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    cum_f = jnp.cumsum(logf, axis=1)                            # (B,c,H)
    # stabilizer: m_t = cum_f_t + max(m_prev, runmax_{j<=t}(logi_j - cum_f_j))
    a = logi - cum_f
    m_step = cum_f + jnp.maximum(m[:, None], lax.cummax(a, axis=1))
    m_new = m_step[:, -1]
    # inter-chunk: decayed previous state
    decay_q = jnp.exp(m[:, None] + cum_f - m_step)              # (B,c,H)
    y_inter = jnp.einsum("bchd,bhde->bche", q, C) * decay_q[..., None]
    n_inter = jnp.einsum("bchd,bhd->bch", q, nrm) * decay_q
    # intra-chunk: masked decayed attention
    cf = cum_f
    dmat = cf[:, :, None] - cf[:, None, :] + logi[:, None]      # (B,ci,cj,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    dmat = jnp.exp(dmat - m_step[:, :, None])
    s = jnp.einsum("bihd,bjhd->bijh", q, k) * scale * dmat
    y_intra = jnp.einsum("bijh,bjhd->bihd", s, v)
    n_intra = jnp.sum(s, axis=2)
    y = (y_inter + y_intra)
    denom = jnp.maximum(jnp.abs(n_inter + n_intra),
                        jnp.exp(-m_step))[..., None]
    y = y / denom
    # state update
    decay_k = jnp.exp(cf[:, -1:] - cf + logi - m_new[:, None])  # (B,c,H)
    kv = jnp.einsum("bchd,bche,bch->bhde", k * scale, v, decay_k)
    ksum = jnp.einsum("bchd,bch->bhd", k * scale, decay_k)
    decay_C = jnp.exp(m[:, None] + cf[:, -1:] - m_new[:, None])[:, 0]
    C_new = C * decay_C[..., None, None] + kv
    n_new = nrm * decay_C[..., None] + ksum
    return (C_new, n_new, m_new), y


def apply_mlstm(p, cfg: MLSTMConfig, x, state=None):
    """x: (B, L, D) train (state=None) or (B, 1, D) decode."""
    B, L, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    up = x @ p["up_proj"]
    xi, z = jnp.split(up, 2, axis=-1)                           # (B,L,Di)
    q = (xi @ p["wq"]).reshape(B, L, H, hd).astype(jnp.float32)
    k = (xi @ p["wk"]).reshape(B, L, H, hd).astype(jnp.float32)
    v = (xi @ p["wv"]).reshape(B, L, H, hd).astype(jnp.float32)
    gif = (xi @ p["w_if"]).reshape(B, L, H, 2).astype(jnp.float32)
    logi = gif[..., 0] + p["b_i"]
    logf = jax.nn.log_sigmoid(gif[..., 1] + p["b_f"])

    if state is None:
        chunk = min(cfg.chunk, L)
        while L % chunk:
            chunk //= 2
        nc = L // chunk

        def step(carry, i):
            sl = lambda t: lax.dynamic_slice_in_dim(t, i * chunk, chunk, 1)
            carry, y = _mlstm_chunk(
                carry, (sl(q), sl(k), sl(v), sl(logi), sl(logf)), cfg)
            return carry, y

        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
        _, ys = lax.scan(step, (C0, n0, m0), jnp.arange(nc))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H * hd)
        out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["down_proj"]
        return out

    (C, nrm, m) = state
    carry, y = _mlstm_chunk((C, nrm, m),
                            (q, k, v, logi, logf), cfg)
    y = y.reshape(B, 1, H * hd)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["down_proj"]
    return out, carry


def init_mlstm_state(cfg: MLSTMConfig, batch):
    H, hd = cfg.n_heads, cfg.head_dim
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.zeros((batch, H), jnp.float32))


# =============================== sLSTM ========================================

@dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int


def init_slstm(key, cfg: SLSTMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    hd = D // cfg.n_heads
    return {
        "w_x": dense_init(ks[0], (D, 4 * D), dtype=dtype),
        # block-diagonal recurrent weights: (heads, hd, 4*hd)
        "r_h": jax.random.normal(ks[1], (cfg.n_heads, hd, 4 * hd),
                                 dtype) / math.sqrt(hd),
        "bias": jnp.concatenate([jnp.zeros((D,)), jnp.zeros((D,)) + 3.0,
                                 jnp.zeros((2 * D,))]).astype(jnp.float32),
        "out_proj": dense_init(ks[2], (D, D), dtype=dtype),
    }


def slstm_specs(mesh, mp_axes, cfg: SLSTMConfig):
    return {"w_x": P(None, None), "r_h": P(None, None, None),
            "bias": P(None), "out_proj": P(None, None)}


def _slstm_step(p, cfg, carry, gx):
    """One sLSTM step. carry: (c, n, h, m) each (B, D); gx: (B, 4D)."""
    c, n, h, m = carry
    B, D = c.shape
    H = cfg.n_heads
    hd = D // H
    hh = h.reshape(B, H, hd)
    gr = jnp.einsum("bhd,hde->bhe", hh, p["r_h"]).reshape(B, 4 * D)
    g = (gx + gr + p["bias"]).astype(jnp.float32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(gf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def apply_slstm(p, cfg: SLSTMConfig, x, state=None):
    B, L, D = x.shape
    gx = x @ p["w_x"]                                           # (B, L, 4D)
    if state is None:
        z0 = jnp.zeros((B, D), jnp.float32)
        carry0 = (z0, z0, z0, z0)

        def step(carry, g):
            carry = _slstm_step(p, cfg, carry, g)
            return carry, carry[2]

        _, hs = lax.scan(step, carry0, gx.transpose(1, 0, 2))
        y = hs.transpose(1, 0, 2).astype(x.dtype)
        return y @ p["out_proj"]
    carry = _slstm_step(p, cfg, state, gx[:, 0])
    y = carry[2][:, None].astype(x.dtype) @ p["out_proj"]
    return y, carry


def init_slstm_state(cfg: SLSTMConfig, batch):
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return (z, z, z, z)
