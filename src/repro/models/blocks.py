"""Transformer-family blocks, one per layer kind.

Kinds: dense(_full), moe(_full), cross, xdec, hymba, mlstm, slstm, encoder.
Each kind provides init / specs / train-apply / decode-apply with a shared
signature so model.py can stack same-kind runs and lax.scan over them.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.registry import KernelConfig
from repro.core.moe import (MoEConfig, apply_moe, init_moe_params,
                            moe_param_specs)
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnConfig
from repro.models.layers import (apply_ffn, apply_norm, ffn_specs, init_ffn,
                                 init_norm, norm_specs)


def base_kind(kind: str) -> str:
    return kind[:-5] if kind.endswith("_full") else kind


def attn_config(cfg: ModelConfig, kind: str, cross: bool = False) -> AttnConfig:
    full = kind.endswith("_full")
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope and not full and not cross,
        causal=not cross and cfg.arch_type != "encoder",
        window=None if (full or cross) else cfg.attn_window,
        chunk=None if (full or cross) else cfg.attn_chunk,
        qkv_bias=cfg.qkv_bias and not cross,
        masked_cache_update=cfg.cache_masked_update,
        context_parallel=cfg.context_parallel_decode)


def _has_ffn(kind: str) -> bool:
    return kind not in ("mlstm",)


def _moe_kind(kind: str) -> bool:
    return kind.startswith("moe")


def _moe_cfg(cfg: ModelConfig, kcfg: KernelConfig) -> MoEConfig:
    """MoE config with the model-level kernel pin inherited: the MoE
    config's own (non-default) kernel wins, otherwise the block-level
    choice — incl. the legacy ``use_pallas`` flag — flows through."""
    if cfg.moe.kernel == KernelConfig() and kcfg != cfg.moe.kernel:
        return replace(cfg.moe, kernel=kcfg)
    return cfg.moe


# --- init / specs -------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    p = {"norm1": init_norm(cfg.d_model, cfg.norm_type)}
    base = base_kind(kind)

    if base in ("dense", "moe", "cross", "xdec", "hymba", "encoder"):
        p["attn"] = attn_mod.init_attn(ks[0], attn_config(cfg, kind), dtype)
    if base == "cross" or base == "xdec":
        p["xattn"] = attn_mod.init_attn(
            ks[1], attn_config(cfg, kind, cross=True), dtype)
        p["norm_x"] = init_norm(cfg.d_model, cfg.norm_type)
        if base == "cross":
            p["gate_attn"] = jnp.zeros((), jnp.float32)
            p["gate_ffn"] = jnp.zeros((), jnp.float32)
    if base == "hymba":
        p["mamba"] = ssm_mod.init_mamba(ks[2], _mamba_cfg(cfg), dtype)
        p["norm_a"] = init_norm(cfg.d_model, cfg.norm_type)
        p["norm_s"] = init_norm(cfg.d_model, cfg.norm_type)
    if base == "mlstm":
        p["mlstm"] = ssm_mod.init_mlstm(ks[3], _mlstm_cfg(cfg), dtype)
    if base == "slstm":
        p["slstm"] = ssm_mod.init_slstm(ks[4], _slstm_cfg(cfg), dtype)

    if _moe_kind(kind):
        p["moe"] = init_moe_params(ks[5], cfg.moe, dtype)
        p["norm2"] = init_norm(cfg.d_model, cfg.norm_type)
    elif _has_ffn(base) and cfg.d_ff:
        p["ffn"] = init_ffn(ks[6], cfg.d_model,
                            _ffn_width(cfg, base), glu=cfg.glu,
                            bias=cfg.ffn_bias, dtype=dtype)
        if not cfg.parallel_block:
            p["norm2"] = init_norm(cfg.d_model, cfg.norm_type)
    return p


def _ffn_width(cfg: ModelConfig, base: str) -> int:
    if base == "slstm" and not cfg.d_ff:
        return int(cfg.d_model * 4 / 3)
    return cfg.d_ff


def _mamba_cfg(cfg: ModelConfig) -> ssm_mod.MambaConfig:
    return ssm_mod.MambaConfig(
        d_model=cfg.d_model, d_inner=int(cfg.d_model * cfg.ssm_expand),
        d_state=cfg.ssm_state, d_conv=cfg.ssm_conv)


def _mlstm_cfg(cfg: ModelConfig) -> ssm_mod.MLSTMConfig:
    return ssm_mod.MLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_kv_heads)


def _slstm_cfg(cfg: ModelConfig) -> ssm_mod.SLSTMConfig:
    return ssm_mod.SLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_kv_heads)


def block_specs(cfg: ModelConfig, kind: str, mesh, dims) -> dict:
    mp = dims.mp
    s = {"norm1": norm_specs(cfg.norm_type)}
    base = base_kind(kind)
    if base in ("dense", "moe", "cross", "xdec", "hymba", "encoder"):
        s["attn"] = attn_mod.attn_specs(mesh, mp, attn_config(cfg, kind))
    if base in ("cross", "xdec"):
        s["xattn"] = attn_mod.attn_specs(mesh, mp,
                                         attn_config(cfg, kind, cross=True))
        s["norm_x"] = norm_specs(cfg.norm_type)
        if base == "cross":
            s["gate_attn"] = P()
            s["gate_ffn"] = P()
    if base == "hymba":
        s["mamba"] = ssm_mod.mamba_specs(mesh, mp, _mamba_cfg(cfg))
        s["norm_a"] = norm_specs(cfg.norm_type)
        s["norm_s"] = norm_specs(cfg.norm_type)
    if base == "mlstm":
        s["mlstm"] = ssm_mod.mlstm_specs(mesh, mp, _mlstm_cfg(cfg))
    if base == "slstm":
        s["slstm"] = ssm_mod.slstm_specs(mesh, mp, _slstm_cfg(cfg))
    if _moe_kind(kind):
        s["moe"] = moe_param_specs(cfg.moe, mesh, dims)
        s["norm2"] = norm_specs(cfg.norm_type)
    elif _has_ffn(base) and cfg.d_ff:
        s["ffn"] = ffn_specs(mesh, mp, _ffn_width(cfg, base), glu=cfg.glu,
                             bias=cfg.ffn_bias)
        if not cfg.parallel_block:
            s["norm2"] = norm_specs(cfg.norm_type)
    return s


# --- train/prefill apply --------------------------------------------------------

def apply_block(p, cfg: ModelConfig, kind: str, x, *, mesh, dims,
                ctx=None, positions=None, schedule=None):
    """Full-sequence forward. Returns ``(x, aux)`` where ``aux`` is a dict:
    ``loss`` the scalar router-loss contribution and ``expert_load`` the
    per-expert routed-row counts — (E,) for MoE kinds, (0,) otherwise so
    every kind scans with the same pytree structure."""
    base = base_kind(kind)
    acfg = attn_config(cfg, kind)
    aux = {"loss": jnp.float32(0.0),
           "expert_load": jnp.zeros((0,), jnp.float32)}
    eps = cfg.norm_eps
    kcfg = cfg.kernel_cfg

    def norm(pn, h):
        return apply_norm(pn, h, eps, kcfg)

    if base in ("dense", "moe", "encoder"):
        h = norm(p["norm1"], x)
        a = attn_mod.apply_attn(p["attn"], acfg, h, positions=positions,
                                kernel=kcfg)
        if cfg.parallel_block:
            f = apply_ffn(p["ffn"], h, cfg.ffn_act)
            # sum the two partial (row-parallel) outputs BEFORE they meet
            # the replicated residual: one AllReduce instead of two (§Perf B1)
            return x + (a + f), aux
        x = x + a
        h2 = norm(p["norm2"], x)
        if _moe_kind(kind):
            y, moe_aux = apply_moe(h2, p["moe"], mesh=mesh, dims=dims,
                                   cfg=_moe_cfg(cfg, kcfg), schedule=schedule)
            aux = {"loss": aux["loss"] + moe_aux["aux_loss"]
                   + moe_aux["z_loss"],
                   "expert_load": moe_aux["expert_load"]}
        else:
            y = apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x + y, aux

    if base == "cross":
        # llama3.2-vision style gated cross-attention layer
        h = norm(p["norm1"], x)
        a = attn_mod.apply_attn(p["xattn"], attn_config(cfg, kind, True),
                                h, kv_x=ctx)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h2 = norm(p["norm_x"], x)
        f = apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * f, aux

    if base == "xdec":
        # whisper decoder: self-attn + cross-attn + FFN
        h = norm(p["norm1"], x)
        x = x + attn_mod.apply_attn(p["attn"], acfg, h, positions=positions,
                                    kernel=kcfg)
        h = norm(p["norm_x"], x)
        x = x + attn_mod.apply_attn(p["xattn"],
                                    attn_config(cfg, kind, True), h, kv_x=ctx)
        h = norm(p["norm2"], x)
        return x + apply_ffn(p["ffn"], h, cfg.ffn_act), aux

    if base == "hymba":
        h = norm(p["norm1"], x)
        a = attn_mod.apply_attn(p["attn"], acfg, h, positions=positions,
                                kernel=kcfg)
        s = ssm_mod.apply_mamba(p["mamba"], _mamba_cfg(cfg), h)
        x = x + 0.5 * (norm(p["norm_a"], a)
                       + norm(p["norm_s"], s))
        h2 = norm(p["norm2"], x)
        return x + apply_ffn(p["ffn"], h2, cfg.ffn_act), aux

    if base == "mlstm":
        h = norm(p["norm1"], x)
        return x + ssm_mod.apply_mlstm(p["mlstm"], _mlstm_cfg(cfg), h), aux

    if base == "slstm":
        h = norm(p["norm1"], x)
        x = x + ssm_mod.apply_slstm(p["slstm"], _slstm_cfg(cfg), h)
        if "ffn" in p:
            h2 = norm(p["norm2"], x)
            x = x + apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x, aux

    raise ValueError(f"unknown block kind {kind}")


# --- decode apply ---------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.float32) -> dict:
    base = base_kind(kind)
    c = {}
    acfg = attn_config(cfg, kind)
    if base in ("dense", "moe", "xdec", "hymba", "encoder"):
        c["attn"] = attn_mod.init_cache(acfg, batch, max_len, dtype)
    if base == "hymba":
        c["mamba"] = ssm_mod.init_mamba_state(_mamba_cfg(cfg), batch, dtype)
    if base == "mlstm":
        c["mlstm"] = ssm_mod.init_mlstm_state(_mlstm_cfg(cfg), batch)
    if base == "slstm":
        c["slstm"] = ssm_mod.init_slstm_state(_slstm_cfg(cfg), batch)
    if base == "cross":
        c["dummy"] = jnp.zeros((), dtype)  # static ctx K/V built per request
    return c


def prefill_block(p, cfg: ModelConfig, kind: str, x, cache, lengths, *,
                  mesh, dims, schedule=None):
    """Whole-prompt block forward that also fills the decode cache.

    The serving engine's batched one-shot prefill: identical math to
    ``apply_block`` plus the KV-cache write of ``prefill_attn``.  Only
    attention-backed kinds participate (SSM/cross archs would need
    recurrent-state extraction; the engine rejects them up front).
    Returns (x, new_cache).
    """
    base = base_kind(kind)
    if base not in ("dense", "moe"):
        raise NotImplementedError(
            f"prefill_block: kind {kind!r} has no cache-filling prefill "
            "(serving engine supports dense/moe decoder stacks)")
    acfg = attn_config(cfg, kind)
    eps = cfg.norm_eps
    kcfg = cfg.kernel_cfg

    def norm(pn, h):
        return apply_norm(pn, h, eps, kcfg)

    h = norm(p["norm1"], x)
    a, c2 = attn_mod.prefill_attn(p["attn"], acfg, h, cache["attn"],
                                  lengths, kernel=kcfg)
    new_cache = dict(cache)
    new_cache["attn"] = c2
    if cfg.parallel_block:
        f = apply_ffn(p["ffn"], h, cfg.ffn_act)
        return x + (a + f), new_cache
    x = x + a
    h2 = norm(p["norm2"], x)
    if _moe_kind(kind):
        # prefill pools are training-shaped: the MoE layer takes the
        # *prefill* autosched decision (infer=False), distinct from the
        # decode decision the same layer makes under decode_block
        y, _ = apply_moe(h2, p["moe"], mesh=mesh, dims=dims,
                         cfg=_moe_cfg(cfg, kcfg), schedule=schedule)
    else:
        y = apply_ffn(p["ffn"], h2, cfg.ffn_act)
    return x + y, new_cache


def paged_block(p, cfg: ModelConfig, kind: str, x, cache, table, starts,
                lens, *, mesh, dims, schedule=None, infer=False,
                with_aux=False):
    """Block forward over a paged KV arena: the ONE code path behind the
    serving engine's decode (C=1, ``infer=True``), one-shot prefill and
    chunked prefill (``infer=False`` — prefill pools take the training-
    shaped autosched decision, like ``prefill_block``).  Routing every
    phase through the same primitive is what makes chunked-vs-one-shot
    and prefix-hit-vs-cold runs bitwise comparable.  Returns
    ``(x, new_cache)``, or ``(x, new_cache, expert_load)`` with
    ``with_aux=True`` — the (E,) per-expert routed-row counts ((0,) for
    dense blocks) feeding the serving engine's load EMA; the default
    keeps every existing caller's arity.
    """
    base = base_kind(kind)
    if base not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged_block: kind {kind!r} has no paged-cache path "
            "(serving engine supports dense/moe decoder stacks)")
    acfg = attn_config(cfg, kind)
    eps = cfg.norm_eps
    kcfg = cfg.kernel_cfg

    def norm(pn, h):
        return apply_norm(pn, h, eps, kcfg)

    h = norm(p["norm1"], x)
    a, c2 = attn_mod.paged_chunk_attn(p["attn"], acfg, h, cache["attn"],
                                      table, starts, lens)
    new_cache = dict(cache)
    new_cache["attn"] = c2
    no_load = jnp.zeros((0,), jnp.float32)
    if cfg.parallel_block:
        f = apply_ffn(p["ffn"], h, cfg.ffn_act)
        out = x + (a + f)
        return (out, new_cache, no_load) if with_aux else (out, new_cache)
    x = x + a
    h2 = norm(p["norm2"], x)
    load = no_load
    if _moe_kind(kind):
        y, maux = apply_moe(h2, p["moe"], mesh=mesh, dims=dims,
                            cfg=_moe_cfg(cfg, kcfg), schedule=schedule,
                            infer=infer)
        load = maux["expert_load"]
    else:
        y = apply_ffn(p["ffn"], h2, cfg.ffn_act)
    out = x + y
    return (out, new_cache, load) if with_aux else (out, new_cache)


def decode_block(p, cfg: ModelConfig, kind: str, x, cache, step, *,
                 mesh, dims, ctx_kv=None, schedule=None):
    """One-token decode. Returns (x, new_cache)."""
    base = base_kind(kind)
    acfg = attn_config(cfg, kind)
    eps = cfg.norm_eps
    kcfg = cfg.kernel_cfg
    new_cache = dict(cache)

    def norm(pn, h):
        return apply_norm(pn, h, eps, kcfg)

    def self_attn(h):
        # context-parallel decode: with an idle batch dim (B=1) the cache
        # length is sharded over the batch axes too (§Perf C6).
        ctx_axes = tuple(dims.mp) if x.shape[0] > 1 \
            else tuple(dims.batch_axes) + tuple(dims.mp)
        a, c2 = attn_mod.decode_attn(p["attn"], acfg, h, cache["attn"], step,
                                     mesh=mesh, mp_axes=ctx_axes)
        new_cache["attn"] = c2
        return a

    if base in ("dense", "moe", "encoder"):
        h = norm(p["norm1"], x)
        a = self_attn(h)
        if cfg.parallel_block:
            f = apply_ffn(p["ffn"], h, cfg.ffn_act)
            return x + (a + f), new_cache
        x = x + a
        h2 = norm(p["norm2"], x)
        if _moe_kind(kind):
            # infer=True: decode shape class — own autosched cache line,
            # decode-widened grid (s1d), drop-free capacity
            y, _ = apply_moe(h2, p["moe"], mesh=mesh, dims=dims,
                             cfg=_moe_cfg(cfg, kcfg), schedule=schedule,
                             infer=True)
        else:
            y = apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x + y, new_cache

    if base == "cross":
        h = norm(p["norm1"], x)
        a, _ = attn_mod.decode_attn(p["xattn"], attn_config(cfg, kind, True),
                                    h, None, step, kv_cache_static=ctx_kv)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h2 = norm(p["norm_x"], x)
        f = apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x + jnp.tanh(p["gate_ffn"]).astype(x.dtype) * f, new_cache

    if base == "xdec":
        h = norm(p["norm1"], x)
        x = x + self_attn(h)
        h = norm(p["norm_x"], x)
        a, _ = attn_mod.decode_attn(p["xattn"], attn_config(cfg, kind, True),
                                    h, None, step, kv_cache_static=ctx_kv)
        x = x + a
        h = norm(p["norm2"], x)
        return x + apply_ffn(p["ffn"], h, cfg.ffn_act), new_cache

    if base == "hymba":
        h = norm(p["norm1"], x)
        a = self_attn(h)
        s, st = ssm_mod.apply_mamba(p["mamba"], _mamba_cfg(cfg), h,
                                    state=cache["mamba"])
        new_cache["mamba"] = st
        x = x + 0.5 * (norm(p["norm_a"], a)
                       + norm(p["norm_s"], s))
        h2 = norm(p["norm2"], x)
        return x + apply_ffn(p["ffn"], h2, cfg.ffn_act), new_cache

    if base == "mlstm":
        h = norm(p["norm1"], x)
        y, st = ssm_mod.apply_mlstm(p["mlstm"], _mlstm_cfg(cfg), h,
                                    state=cache["mlstm"])
        new_cache["mlstm"] = st
        return x + y, new_cache

    if base == "slstm":
        h = norm(p["norm1"], x)
        y, st = ssm_mod.apply_slstm(p["slstm"], _slstm_cfg(cfg), h,
                                    state=cache["slstm"])
        new_cache["slstm"] = st
        x = x + y
        if "ffn" in p:
            h2 = norm(p["norm2"], x)
            x = x + apply_ffn(p["ffn"], h2, cfg.ffn_act)
        return x, new_cache

    raise ValueError(f"unknown block kind {kind}")
