"""Buffered streaming JSONL event sink with run-metadata header and
size-based rotation.

Layout under ``metrics_dir``::

    metrics-000.jsonl     # first line: {"event": "meta", ...}, then events
    metrics-001.jsonl     # after rotation (each file re-carries the header)

Every line is one self-contained JSON object with at least ``event``
(name), ``t`` (seconds since sink creation, monotonic) and ``seq``
(global event ordinal — survives rotation, so readers can re-merge a
rotated run in order).  Values must be JSON-serializable; numpy/jax
scalars are coerced via ``float()``/``int()`` fallbacks.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional


def _coerce(v):
    """Best-effort JSON coercion for numpy / jax scalars."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _coerce(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_coerce(x) for x in v]
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return _coerce(item())
        except Exception:
            pass
    tolist = getattr(v, "tolist", None)   # numpy / jax arrays
    if callable(tolist):
        try:
            return _coerce(tolist())
        except Exception:
            pass
    try:
        return float(v)
    except Exception:
        return str(v)


class JsonlSink:
    """Append-only JSONL event writer.

    Parameters
    ----------
    metrics_dir:
        Directory to create/write files under.
    meta:
        Run metadata dict written as the first ``{"event": "meta"}``
        line of every file (config, mesh shape, argv, ...).
    rotate_bytes:
        Rotate to a new file once the current one passes this size.
    buffer_events:
        Events held in memory between writes (1 = unbuffered).
    """

    def __init__(self, metrics_dir: str, meta: Optional[dict] = None,
                 rotate_bytes: int = 64 * 1024 * 1024,
                 buffer_events: int = 64) -> None:
        self.dir = str(metrics_dir)
        os.makedirs(self.dir, exist_ok=True)
        self.meta = dict(meta or {})
        self.rotate_bytes = int(rotate_bytes)
        self.buffer_events = max(1, int(buffer_events))
        self._t0 = time.monotonic()
        self._seq = 0
        self._file_index = -1
        self._bytes = 0
        self._buf: List[str] = []
        self._fh = None
        self._paths: List[str] = []
        self._closed = False
        self._open_next()

    # -- file management ------------------------------------------------

    def _open_next(self) -> None:
        if self._fh is not None:
            self._fh.close()
        self._file_index += 1
        path = os.path.join(self.dir, f"metrics-{self._file_index:03d}.jsonl")
        self._fh = open(path, "w")
        self._paths.append(path)
        header = {"event": "meta", "t": self._now(), "seq": self._seq,
                  "file_index": self._file_index}
        for k, v in self.meta.items():   # reserved keys win on collision
            if k not in header:
                header[k] = _coerce(v)
        line = json.dumps(header) + "\n"
        self._fh.write(line)
        self._bytes = len(line.encode("utf-8"))
        self._seq += 1

    @property
    def paths(self) -> List[str]:
        """All files written so far, in rotation order."""
        return list(self._paths)

    @property
    def path(self) -> str:
        """The file currently being written."""
        return self._paths[-1]

    def _now(self) -> float:
        return round(time.monotonic() - self._t0, 6)

    # -- event API ------------------------------------------------------

    def emit(self, event: str, **fields) -> None:
        if self._closed:
            return
        rec: Dict[str, object] = {"event": event, "t": self._now(),
                                  "seq": self._seq}
        self._seq += 1
        for k, v in fields.items():      # reserved keys win on collision
            if k not in ("event", "t", "seq"):
                rec[k] = _coerce(v)
        self._buf.append(json.dumps(rec) + "\n")
        if len(self._buf) >= self.buffer_events:
            self.flush()

    def flush(self) -> None:
        if self._closed or not self._buf:
            return
        chunk = "".join(self._buf)
        self._buf.clear()
        self._fh.write(chunk)
        self._fh.flush()
        self._bytes += len(chunk.encode("utf-8"))
        if self._bytes >= self.rotate_bytes:
            self._open_next()

    def close(self) -> None:
        if self._closed:
            return
        self.flush()
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        self._closed = True

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(paths) -> List[dict]:
    """Parse one or more JSONL files back into event dicts (in seq
    order across rotated files).  Test/report helper, not a hot path."""
    if isinstance(paths, (str, os.PathLike)):
        paths = [paths]
    events: List[dict] = []
    for p in paths:
        with open(p) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    events.sort(key=lambda e: e.get("seq", 0))
    return events
