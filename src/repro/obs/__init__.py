"""Unified telemetry for the train and serve hot paths.

One small layer, three pieces:

  * :mod:`repro.obs.registry` — process-local metrics primitives:
    counters, gauges, rolling-window histograms, and THE quantile
    codepath (``quantile``) every p50/p95/p99 in the repo goes through
    (``serve.engine.latency_stats``, the guard rails' rolling loss
    median, the sink rollups).
  * :mod:`repro.obs.sink` — a buffered streaming JSONL event sink with
    a run-metadata header and size-based rotation; ``--metrics-dir`` on
    the launchers installs one process-wide, and every emitter below
    writes through it.
  * :mod:`repro.obs.trace` / :mod:`repro.obs.audit` — plan-stage
    tracing: the executor names every plan-IR stage
    (``jax.named_scope``), the timed harness measures per-stage wall
    times (prefix-program differencing — the full-plan program is
    untouched, so outputs stay bitwise-identical), and the audit joins
    them against ``PerfModel.t_plan_stages`` predictions into a
    predicted-vs-measured report (``launch/dryrun.py --audit``).

Emission is opt-in and cheap when off: ``emit(...)`` with no sink
installed is a single attribute test, and nothing here runs inside a
jitted program — runtime events arrive through the same host-side
seams the launchers already owned (per-step logging, engine lifecycle
transitions, ``jax.debug.callback`` for the fp8 monitor).

Two context planes keep events attributable:

  * runtime context (:func:`set_context`) — host-side facts like the
    current train step, merged into every event at emit time;
  * trace context (:func:`trace_tag` / :func:`trace_context`) — facts
    only known while *tracing* (e.g. which MoE layer an fp8 encode
    belongs to), captured into the debug-callback closure so runtime
    events from that trace carry them.
"""

from __future__ import annotations

from contextlib import contextmanager

from repro.obs.registry import (Counter, Gauge, Histogram,  # noqa: F401
                                Registry, quantile)
from repro.obs.sink import JsonlSink  # noqa: F401

_SINK = None            # process-wide JsonlSink (None = telemetry off)
_RUNTIME_CTX: dict = {}  # host-side event context (e.g. step=)
_TRACE_CTX: dict = {}    # trace-time context (e.g. moe_layer=)


def configure(metrics_dir: str, meta=None, **sink_kw) -> JsonlSink:
    """Install a process-wide JSONL sink writing under ``metrics_dir``.
    Returns it (also reachable via :func:`get_sink`)."""
    global _SINK
    if _SINK is not None:
        _SINK.close()
    _SINK = JsonlSink(metrics_dir, meta=meta, **sink_kw)
    return _SINK


def get_sink():
    return _SINK


def enabled() -> bool:
    return _SINK is not None


def emit(event: str, **fields) -> None:
    """Write one event through the installed sink (no-op when none is
    installed).  The runtime context is merged in under the event's own
    fields (explicit fields win)."""
    if _SINK is None:
        return
    if _RUNTIME_CTX:
        merged = dict(_RUNTIME_CTX)
        merged.update(fields)
        fields = merged
    _SINK.emit(event, **fields)


def flush() -> None:
    if _SINK is not None:
        _SINK.flush()


def close() -> None:
    """Flush and close the installed sink (idempotent)."""
    global _SINK
    if _SINK is not None:
        _SINK.close()
        _SINK = None
    _RUNTIME_CTX.clear()
    _TRACE_CTX.clear()


def set_context(**fields) -> None:
    """Merge host-side context (e.g. ``step=12``) into every subsequent
    :func:`emit`.  A value of None removes the key."""
    for k, v in fields.items():
        if v is None:
            _RUNTIME_CTX.pop(k, None)
        else:
            _RUNTIME_CTX[k] = v


def trace_context() -> dict:
    """Snapshot of the trace-time context (copy; safe to close over)."""
    return dict(_TRACE_CTX)


@contextmanager
def trace_tag(**fields):
    """Tag everything traced inside the block (e.g. ``moe_layer=3``) so
    runtime callbacks built there can stamp their events with it."""
    saved = {k: _TRACE_CTX.get(k) for k in fields}
    _TRACE_CTX.update(fields)
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                _TRACE_CTX.pop(k, None)
            else:
                _TRACE_CTX[k] = v
