"""Process-local metrics primitives: counters, gauges, rolling-window
histograms, and the single quantile codepath shared by every
p50/p95/p99 in the repo.

The quantile convention is the one ``serve.engine.latency_stats`` has
used since PR 5 (nearest-rank on the sorted sample,
``xs[min(int(p/100 * n), n - 1)]``): p50 of an odd-length sample is the
middle element — exactly the guards' rolling-median element
``xs[n // 2]`` — so delegating both callers here changes no numbers.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Sequence


def quantile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank quantile of an already **sorted** sample.

    ``p`` is in percent (50.0 = median).  Raises ``ValueError`` on an
    empty sample — callers decide what "no data" means (the engine
    reports zeros, the guards wait for warmup).
    """
    n = len(xs)
    if n == 0:
        raise ValueError("quantile of empty sample")
    if p <= 0.0:
        return float(xs[0])
    return float(xs[min(int(p / 100.0 * n), n - 1)])


@dataclass
class Counter:
    """Monotonic event count."""

    name: str
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


@dataclass
class Gauge:
    """Last-write-wins scalar."""

    name: str
    value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = float(value)


@dataclass
class Histogram:
    """Rolling-window sample with nearest-rank quantiles.

    ``window=None`` keeps every sample (the serve-latency use: bounded
    by request count); a finite window drops the oldest (the guards'
    rolling loss median).
    """

    name: str
    window: Optional[int] = None
    _xs: Deque[float] = field(default_factory=deque, repr=False)

    def __post_init__(self) -> None:
        if self.window is not None:
            self._xs = deque(self._xs, maxlen=self.window)

    def add(self, value: float) -> None:
        self._xs.append(float(value))

    def reset(self) -> None:
        self._xs.clear()

    def __len__(self) -> int:
        return len(self._xs)

    @property
    def count(self) -> int:
        return len(self._xs)

    def sorted_values(self):
        return sorted(self._xs)

    def quantile(self, p: float) -> float:
        return quantile(self.sorted_values(), p)

    def median(self) -> float:
        return self.quantile(50.0)

    def mad(self) -> float:
        """Median absolute deviation (same element convention as
        :meth:`median`); the guards' spike detector scales this by
        1.4826 into a robust sigma."""
        med = self.median()
        return quantile(sorted(abs(x - med) for x in self._xs), 50.0)

    def summary(self) -> Dict[str, float]:
        """``{count, min, max, mean, p50, p95, p99}`` (zeros when
        empty, so rollup emitters never have to special-case)."""
        xs = self.sorted_values()
        n = len(xs)
        if n == 0:
            return {"count": 0, "min": 0.0, "max": 0.0, "mean": 0.0,
                    "p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {
            "count": n,
            "min": xs[0],
            "max": xs[-1],
            "mean": sum(xs) / n,
            "p50": quantile(xs, 50.0),
            "p95": quantile(xs, 95.0),
            "p99": quantile(xs, 99.0),
        }


class Registry:
    """Named metric instruments, created on first touch.

    One instance per subsystem (trainer, engine) — or use the module
    default via :func:`default_registry`.  ``snapshot()`` flattens
    everything into one JSON-ready dict for periodic rollup events.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, window: Optional[int] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, window=window)
        return h

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            if g.value is not None:
                out[name] = g.value
        for name, h in self._histograms.items():
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out


_DEFAULT: Optional[Registry] = None


def default_registry() -> Registry:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Registry()
    return _DEFAULT
