"""Plan-stage wall-time tracing.

The executor names every stage with ``jax.named_scope`` (free, trace
metadata only).  This module adds the *timed* mode: for a plan with
stages ``s_1..s_n`` (topo order) it jits one shard_map program per
prefix ``[s_1..s_k]`` via :func:`executor.execute_prefix` — each
returns a replicated probe scalar folding every stage output, so XLA
cannot dead-code any stage — and attributes

    measured(s_k) = median_t(prefix_k) - median_t(prefix_{k-1})

clamped at 0.  The *full* program (``apply_moe``'s) is never modified,
which is why turning timing on cannot perturb outputs: bitwise parity
is structural, not a tolerance (``tests/test_obs.py`` pins it anyway).

Prefix differencing charges a stage with the marginal cost of
extending the program by it — including overlap effects XLA's
scheduler realizes, which is exactly what ``PerfModel.t_plan_stages``
claims to predict.  Noise makes individual small stages jittery
(hence the clamp and the median-of-iters), but the ranked
predicted-vs-measured join in :mod:`repro.obs.audit` is robust to
that: worst offenders are the big stages.

Outputs also export as Chrome-trace JSON (``chrome://tracing`` /
Perfetto): one ``X`` slice per stage laid end to end.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import executor
from repro.core import plan as planlib
from repro.core.pipeline import UNCHUNKED_OF
from repro.core.plan import validate


@dataclass
class StageTime:
    name: str
    kind: str
    measured_s: float


@dataclass
class StageTrace:
    """Per-stage wall times for one executed plan."""

    plan: str                    # full plan name (chunked variant)
    schedule: str                # base schedule name requested
    total_s: float               # median wall time of the full program
    overhead_s: float            # prefix-0 program (input probe only)
    stages: List[StageTime] = field(default_factory=list)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def by_name(self) -> dict:
        return {s.name: s for s in self.stages}


def _median_time(fn, args, iters: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def time_plan_stages(schedule: str, info, mesh, in_specs, args,
                     iters: int = 5, warmup: int = 2,
                     n_chunks: Optional[int] = None) -> StageTrace:
    """Measure per-stage wall times of one plan on one mesh.

    ``info`` is the layer's ``MoEShardInfo``; ``args`` are the
    shard_map operands ``(xt, wg, w1, w3, w2)`` with matching
    ``in_specs`` — i.e. exactly what ``apply_moe`` feeds its body
    (callers: :func:`repro.obs.audit.run_schedule_audit`, the launcher
    ``--trace`` path, and the parity tests).
    """
    base = UNCHUNKED_OF.get(schedule, schedule)
    plan = planlib.build_plan(base, info, n_chunks=n_chunks)
    order = validate(plan)
    out_spec = jax.sharding.PartitionSpec()

    def prefix_fn(k):
        def body(xt, wg, w1, w3_, w2):
            return executor.execute_prefix(plan, xt, wg, w1, w3_, w2,
                                           info, k)
        return jax.jit(compat.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
            check_vma=False))

    medians = []
    for k in range(len(order) + 1):
        label = "input" if k == 0 else order[k - 1].name
        with jax.profiler.TraceAnnotation(f"obs.prefix.{label}"):
            medians.append(_median_time(prefix_fn(k), args, iters, warmup))
    stages = [StageTime(name=st.name, kind=st.kind,
                        measured_s=max(0.0, medians[i + 1] - medians[i]))
              for i, st in enumerate(order)]
    return StageTrace(plan=plan.name, schedule=schedule,
                      total_s=medians[-1], overhead_s=medians[0],
                      stages=stages)


# --- Chrome trace export -----------------------------------------------------

def chrome_trace_events(trace: StageTrace) -> List[dict]:
    """Chrome-trace ``X`` (complete) events, one per stage, laid end to
    end on a single track.  Times in microseconds per the format."""
    events = [{"name": "process_name", "ph": "M", "pid": 0,
               "args": {"name": f"plan {trace.plan}"}},
              {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
               "args": {"name": trace.schedule}}]
    ts = 0.0
    for s in trace.stages:
        dur = s.measured_s * 1e6
        events.append({"name": s.name, "cat": s.kind, "ph": "X",
                       "ts": round(ts, 3), "dur": round(dur, 3),
                       "pid": 0, "tid": 0,
                       "args": {"kind": s.kind,
                                "measured_s": s.measured_s}})
        ts += dur
    return events


def save_chrome_trace(trace: StageTrace, path: str) -> str:
    with open(path, "w") as fh:
        json.dump({"traceEvents": chrome_trace_events(trace),
                   "displayTimeUnit": "ms"}, fh, indent=1)
    return path


# --- mesh/operand helpers for standalone harness runs ------------------------

def subset_mesh(shape, names):
    """A mesh over the *first* ``prod(shape)`` local devices (unlike
    ``parallel.mesh.make_mesh``, which insists on using all of them) —
    the audit runs under dryrun's fake-device farm where the full
    device count is a topology, not a budget."""
    import numpy as np
    n = 1
    for s in shape:
        n *= int(s)
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices for mesh {shape}, "
                         f"have {len(devs)}")
    arr = np.array(devs[:n]).reshape(shape)
    if compat.AxisType is not None:
        return jax.sharding.Mesh(
            arr, names, axis_types=(compat.AxisType.Auto,) * len(names))
    return jax.sharding.Mesh(arr, names)
