"""Predicted-vs-measured schedule audits.

Parm's pitch is that the alpha-beta model picks schedules *because its
per-stage estimates are right*.  The audit closes that loop: run the
obs stage-timing harness (:mod:`repro.obs.trace`) on real compiled
plans, join each stage's measured wall time against
``PerfModel.t_plan_stages``'s itemized prediction, and rank the worst
offenders by relative error.  Surfaced by ``launch/dryrun.py --audit``
(report saved into the dryrun artifact JSON) and usable to seed
measured calibration: the report's ``calibration.time_scale`` is the
one-number correction that maps the analytic total onto this machine.

Report schema (locked by ``tests/test_obs.py::test_audit_report_schema``):

.. code-block:: python

    {"schedule": "s1", "plan": "s1", "n_stages": 7,
     "total_predicted_s": ..., "total_measured_s": ..., "overhead_s": ...,
     "stages": [{"name", "kind", "predicted_s", "measured_s",
                 "rel_err"},   # rel_err None where predicted == 0
                ...],
     "worst": [...stage names, |rel_err| descending...],
     "calibration": {"time_scale": measured_total / predicted_total}}

Stages the model prices at zero (gate, dispatch, combine, splits — the
"local is free" assumption) keep their measured time but get
``rel_err: None`` and stay out of the ``worst`` ranking; their measured
column is exactly how you falsify that assumption.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import plan as planlib
from repro.core.collectives import CommConfig
from repro.core.moe import (MoEConfig, init_moe_params, moe_param_specs,
                            shard_pool_capacity)
from repro.core.perfmodel import MoELayerShape, PerfModel, tpu_v5e_model
from repro.core.pipeline import UNCHUNKED_OF
from repro.core.schedules import MoEShardInfo
from repro.obs.trace import StageTrace, time_plan_stages
from repro.parallel.mesh import ParallelDims, axis_size

DEFAULT_AUDIT_SCHEDULES = ("s1", "s2", "s1g")


def audit_report(trace: StageTrace, predicted: dict,
                 total_predicted_s: float) -> dict:
    """Pure join of a measured :class:`StageTrace` against per-stage
    predictions (``{stage_name: seconds}``) — no execution, so tests
    can pin the schema without a mesh."""
    stages = []
    for s in trace.stages:
        pred = float(predicted.get(s.name, 0.0))
        rel = ((s.measured_s - pred) / pred) if pred > 0.0 else None
        stages.append({"name": s.name, "kind": s.kind,
                       "predicted_s": pred, "measured_s": s.measured_s,
                       "rel_err": rel})
    worst = [st["name"] for st in
             sorted((st for st in stages if st["rel_err"] is not None),
                    key=lambda st: abs(st["rel_err"]), reverse=True)]
    scale = (trace.total_s / total_predicted_s
             if total_predicted_s > 0.0 else None)
    return {
        "schedule": trace.schedule,
        "plan": trace.plan,
        "n_stages": trace.n_stages,
        "total_predicted_s": float(total_predicted_s),
        "total_measured_s": float(trace.total_s),
        "overhead_s": float(trace.overhead_s),
        "stages": stages,
        "worst": worst,
        "calibration": {"time_scale": scale},
    }


class _LayerHarness:
    """The audited layer's operands and layout, derived exactly the way
    ``apply_moe`` derives them, so the audited plans are the plans
    training would run.  Shared by the multi-schedule audit and the
    launchers' ``--trace`` single-schedule path."""

    def __init__(self, mesh, dims: ParallelDims, cfg: MoEConfig,
                 tokens_global: int, infer: bool = False, seed: int = 0):
        sizes = dims.sizes(mesh)
        self.mesh, self.dims, self.cfg = mesh, dims, cfg
        self.n_ep, self.n_esp, self.n_mp = \
            sizes["ep"], sizes["esp"], sizes["mp"]
        self.gate_cfg = cfg.gate_config()
        batch_ax = dims.batch_axes
        n_token_shard = axis_size(mesh, batch_ax)
        self.s_local, self.cap = shard_pool_capacity(
            tokens_global, n_token_shard, self.n_mp, self.gate_cfg,
            infer=infer)
        self.infer = infer
        wire = cfg.comm.wire_dtype
        self.wire = "f32" if wire == "auto" else wire

        M = cfg.d_model
        kx, kp = jax.random.split(jax.random.PRNGKey(seed))
        params = init_moe_params(kp, cfg)
        xt = jax.random.normal(kx, (tokens_global, M), jnp.float32)
        pspecs = moe_param_specs(cfg, mesh, dims)
        w3 = params.get("w3")
        if w3 is None:
            w3 = jnp.zeros((0,), xt.dtype)
            w3_spec = P(None)
        else:
            w3_spec = pspecs["w3"]
        x_spec = P(tuple(batch_ax) or None, None)
        self.in_specs = (x_spec, pspecs["wg"], pspecs["w1"], w3_spec,
                         pspecs["w2"])
        self.args = (xt, params["wg"], params["w1"], w3, params["w2"])
        self.shape = MoELayerShape(
            B=max(self.s_local, 1), L=1, M=M, H=cfg.d_ff,
            E=cfg.n_experts, k=cfg.top_k, f=cfg.capacity_factor,
            n_mp=self.n_mp, n_esp=self.n_esp, n_ep=self.n_ep,
            infer=infer)

    def info(self, n_chunks: int = 1) -> MoEShardInfo:
        dims, cfg = self.dims, self.cfg
        return MoEShardInfo(
            ep_axes=tuple(dims.ep), esp_axes=tuple(dims.esp),
            mp_axes=tuple(dims.mp), n_ep=self.n_ep, n_esp=self.n_esp,
            n_mp=self.n_mp, tokens=self.s_local, cap=self.cap,
            gate=self.gate_cfg, act=cfg.act, glu=cfg.glu,
            saa_chunks=cfg.saa_chunks, pipeline_chunks=max(n_chunks, 1),
            kernel=cfg.kernel,
            comm=CommConfig(wire_dtype=self.wire,
                            scaling=cfg.comm.scaling))

    def trace(self, schedule: str, n_chunks: int = 1, iters: int = 5,
              warmup: int = 2) -> StageTrace:
        return time_plan_stages(schedule, self.info(n_chunks), self.mesh,
                                self.in_specs, self.args, iters=iters,
                                warmup=warmup, n_chunks=n_chunks)


def trace_schedule(mesh, dims: ParallelDims, cfg: MoEConfig,
                   tokens_global: int, schedule: str, *,
                   infer: bool = False, n_chunks: int = 1,
                   iters: int = 5, warmup: int = 2,
                   seed: int = 0) -> StageTrace:
    """Single-schedule stage trace (the launchers' ``--trace`` path:
    the returned :class:`StageTrace` exports via
    :func:`repro.obs.trace.save_chrome_trace`)."""
    h = _LayerHarness(mesh, dims, cfg, tokens_global, infer=infer,
                      seed=seed)
    return h.trace(schedule, n_chunks=n_chunks, iters=iters,
                   warmup=warmup)


def run_schedule_audit(mesh, dims: ParallelDims, cfg: MoEConfig,
                       tokens_global: int,
                       schedules: Sequence[str] = DEFAULT_AUDIT_SCHEDULES,
                       perf_model: Optional[PerfModel] = None,
                       n_chunks: int = 1, iters: int = 5, warmup: int = 2,
                       seed: int = 0) -> List[dict]:
    """Measure + price the given schedules on ``mesh`` and return one
    audit report per schedule.

    Seqpar schedules are excluded from the default set (their
    token-shard contract changes the operand sharding); pass them
    explicitly if the caller's specs match.
    """
    h = _LayerHarness(mesh, dims, cfg, tokens_global, seed=seed)
    pm = perf_model or tpu_v5e_model(h.n_ep, h.n_esp, h.n_mp)
    reports = []
    for sched in schedules:
        trace = h.trace(sched, n_chunks=n_chunks, iters=iters,
                        warmup=warmup)
        base = UNCHUNKED_OF.get(sched, sched)
        plan = planlib.build_plan(base, h.info(n_chunks),
                                  n_chunks=n_chunks)
        predicted = pm.t_plan_stages(plan, h.shape, wire_dtype=h.wire)
        total_pred = pm.t_plan(plan, h.shape, wire_dtype=h.wire)
        reports.append(audit_report(trace, predicted, total_pred))
    return reports
