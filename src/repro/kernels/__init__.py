"""Hot-path compute kernels behind the unified backend registry.

Per-op Pallas TPU kernels (expert_ffn, moe_dispatch, rmsnorm,
flash_attention) with pure-jnp oracles in ``ref.py``; ``registry.get_op``
is the single entry point the schedules and model layers call.
"""

from repro.kernels.registry import (DEFAULT, BACKENDS, KernelConfig,
                                    available_backends, get_op, list_ops,
                                    register, resolve_backend)

__all__ = ["DEFAULT", "BACKENDS", "KernelConfig", "available_backends",
           "get_op", "list_ops", "register", "resolve_backend"]
