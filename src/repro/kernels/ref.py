"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode kernels are asserted against, shape/dtype-swept)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q,k,v: (B, L, H, hd) (kv already head-repeated). Returns (B, L, H, hd)."""
    B, Lq, H, hd = q.shape
    Lk = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(Lq)[:, None]
    kp = jnp.arange(Lk)[None, :]
    ok = jnp.ones((Lq, Lk), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= qp - kp < window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def expert_ffn_ref(x, w1, w3, w2, *, act="silu"):
    """Grouped expert FFN. x: (E, T, M); w1/w3: (E, M, F); w2: (E, F, M)."""
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    h = jnp.einsum("etm,emf->etf", x, w1)
    if w3 is not None:
        h = actf(h) * jnp.einsum("etm,emf->etf", x, w3)
    else:
        h = actf(h)
    return jnp.einsum("etf,efm->etm", h, w2)


def expert_ffn_ragged_ref(xb, counts, w1, w3, w2, *, act="silu"):
    """Ragged grouped FFN over a (E, G, c, M) pool with per-(expert,
    group) valid-row counts.  Compute runs in f32 (matching the pool
    path's decoded payloads) and rows at index >= counts[e, g] are
    forced to exact zero — the dropless contract: padding rows carry no
    FLOPs semantically and no value numerically.  Output is cast back
    to ``xb.dtype`` (the wire dtype on the fused raw path)."""
    E, G, c, M = xb.shape
    h = expert_ffn_ref(xb.reshape(E, G * c, M).astype(jnp.float32),
                       w1, w3, w2, act=act)
    mask = jnp.arange(c)[None, None, :] < counts[:, :, None]
    h = h.reshape(E, G, c, M) * mask[..., None].astype(h.dtype)
    return h.astype(xb.dtype)


def expert_ffn_grouped_ref(x, flat_idx, weights, w1, w3, w2, *,
                           cap, act="silu", wire="f32"):
    """Single-device fused megakernel oracle: dispatch gather ->
    (wire decode) -> expert FFN -> (wire encode/decode) -> combine
    scatter + weight-dot, one op.  ``wire`` in {"f32", "bf16"} models
    the fused codec as a round-trip at the two pool boundaries,
    matching what dispatch_a2a/combine_a2a do between the unfused ops.

    x: (S, M); flat_idx/weights: (S, k); returns (S, M) in x.dtype."""
    E = w1.shape[0]

    def rt(v):   # fused wire round-trip at a pool boundary
        return v.astype(jnp.bfloat16).astype(v.dtype) if wire == "bf16" \
            else v
    buf = rt(moe_dispatch_ref(x, flat_idx, E * cap))
    h = expert_ffn_ref(buf.reshape(E, cap, -1).astype(jnp.float32),
                       w1, w3, w2, act=act)
    h = rt(h.reshape(E * cap, -1))
    return moe_combine_ref(h, flat_idx, weights).astype(x.dtype)


def moe_dispatch_ref(x, flat_idx, n_slots):
    """Scatter tokens into the flat capacity buffer.

    x: (S, M); flat_idx: (S, k) int32 in [0, n_slots] (n_slots = drop).
    Returns (n_slots, M).
    """
    S, M = x.shape
    k = flat_idx.shape[1]
    buf = jnp.zeros((n_slots + 1, M), x.dtype)
    src = jnp.broadcast_to(x[:, None, :], (S, k, M)).reshape(S * k, M)
    buf = buf.at[flat_idx.reshape(-1)].add(src, mode="drop")
    return buf[:-1]


def moe_combine_ref(buf, flat_idx, weights):
    """Gather expert outputs back to tokens. buf: (n_slots, M);
    flat_idx: (S, k); weights: (S, k). Returns (S, M)."""
    n_slots, M = buf.shape
    idx = jnp.minimum(flat_idx, n_slots - 1)
    vals = buf[idx.reshape(-1)].reshape(*flat_idx.shape, M)
    w = jnp.where(flat_idx < n_slots, weights, 0.0)
    return jnp.einsum("sk,skm->sm", w.astype(buf.dtype), vals)


def rmsnorm_ref(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)
