"""Pallas TPU dropless grouped expert FFN (ragged + fully-fused forms).

Two kernels implement the Parm grouped-GEMM megakernel seam:

``expert_ffn_ragged``
    The pool-path form: the (E, G, c, M) receive buffer from the
    dispatch AlltoAll plus per-(expert, group) routed-row counts.  The
    grid still tiles the padded capacity, but every token tile whose
    rows are entirely beyond the routed count is *predicated off* with
    ``pl.when`` — the MXU never sees it, so compute scales with routed
    tokens, not capacity ("dropless" in FLOPs).  Partially-valid tiles
    mask their tail rows to exact zero, matching the oracle bit-for-bit.
    Compute runs in f32 (the decode half of the fused wire codec when
    the A2A payload arrives raw bf16) and the output is cast back to the
    input dtype (the encode half for the combine A2A).

``expert_ffn_grouped_fused``
    The single-device megakernel: dispatch gather fused into the
    prologue (slot -> token row ids built once in jnp, rows pulled from
    the resident token matrix per capacity tile), the two expert GEMMs
    and activation in the body, and the combine scatter + gate-weight
    dot fused into the epilogue — one kernel launch, no (n_slots, M)
    f32 intermediates in HBM.  ``wire`` in {"f32", "bf16"} applies the
    wire-codec round-trip at the two pool boundaries so the fused op is
    numerically identical to dispatch -> encode/decode -> FFN ->
    encode/decode -> combine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def _ragged_kernel(x_ref, cnt_ref, w1_ref, *refs, act, glu, block_t):
    if glu:
        w3_ref, w2_ref, o_ref = refs
    else:
        w2_ref, o_ref = refs
    it, jf = pl.program_id(2), pl.program_id(3)

    @pl.when(jf == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = cnt_ref[0, 0]

    @pl.when(it * block_t < cnt)          # ragged: skip empty tiles
    def _compute():
        x = x_ref[0, 0].astype(jnp.float32)               # (bt, M)
        w1 = w1_ref[0].astype(jnp.float32)                # (M, bf)
        h = lax.dot_general(x, w1, (((1,), (0,)), ((), ())))
        if glu:
            w3 = w3_ref[0].astype(jnp.float32)
            h = ACT[act](h) * lax.dot_general(
                x, w3, (((1,), (0,)), ((), ())))
        else:
            h = ACT[act](h)
        w2 = w2_ref[0].astype(jnp.float32)                # (bf, M)
        out = lax.dot_general(h, w2, (((1,), (0,)), ((), ())))
        rows = it * block_t + lax.broadcasted_iota(
            jnp.int32, (block_t, 1), 0)
        out = jnp.where(rows < cnt, out, 0.0)  # mask tail of partial tile
        o_ref[...] += out.astype(o_ref.dtype)[None, None]


def expert_ffn_ragged(xb, counts, w1, w3, w2, *, act="silu", block_t=128,
                      block_f=256, interpret=None):
    """xb: (E, G, c, M) pool; counts: (E, G) int32 routed rows per group;
    w1/w3: (E, M, F); w2: (E, F, M) -> (E, G, c, M) in xb.dtype."""
    E, G, c, M = xb.shape
    F = w1.shape[-1]
    glu = w3 is not None
    block_t = min(block_t, c)
    block_f = min(block_f, F)
    c_pad = -(-c // block_t) * block_t
    if c_pad != c:
        xb = jnp.pad(xb, ((0, 0), (0, 0), (0, c_pad - c), (0, 0)))
    while F % block_f:
        block_f //= 2
    n_t, n_f = c_pad // block_t, F // block_f
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_ragged_kernel, act=act, glu=glu,
                               block_t=block_t)
    w_in_spec = pl.BlockSpec((1, M, block_f),
                             lambda e, g, it, jf: (e, 0, jf))
    in_specs = [
        pl.BlockSpec((1, 1, block_t, M), lambda e, g, it, jf: (e, g, it, 0)),
        pl.BlockSpec((1, 1), lambda e, g, it, jf: (e, g)),
        w_in_spec,
        *([w_in_spec] if glu else []),
        pl.BlockSpec((1, block_f, M), lambda e, g, it, jf: (e, jf, 0)),
    ]
    operands = (xb, counts, w1, w3, w2) if glu else (xb, counts, w1, w2)

    out = pl.pallas_call(
        kernel,
        grid=(E, G, n_t, n_f),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_t, M),
                               lambda e, g, it, jf: (e, g, it, 0)),
        out_shape=jax.ShapeDtypeStruct((E, G, c_pad, M), xb.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :, :c] if c_pad != c else out


def slot_metadata(flat_idx, weights, n_tokens, n_experts, cap):
    """Invert the gate's (token -> slot) map into the kernel's
    (slot -> token) form: per-slot source row ids (sentinel =
    ``n_tokens`` for empty slots), per-slot gate weights, and per-expert
    routed-row counts.  Slots are contiguous per expert (GShard slot
    priority), so counts are exactly the ragged group sizes."""
    S, k = flat_idx.shape
    flat = flat_idx.reshape(-1)
    src = (jnp.arange(S * k, dtype=jnp.int32) // k)
    rid = jnp.full((n_experts * cap,), n_tokens, jnp.int32)
    rid = rid.at[flat].set(src, mode="drop")
    ws = jnp.zeros((n_experts * cap,), jnp.float32)
    ws = ws.at[flat].set(weights.reshape(-1).astype(jnp.float32),
                         mode="drop")
    counts = jnp.sum((rid < n_tokens).reshape(n_experts, cap), axis=1,
                     dtype=jnp.int32)
    return (rid.reshape(n_experts, cap), ws.reshape(n_experts, cap),
            counts)


def _fused_kernel(x_ref, rid_ref, ws_ref, cnt_ref, w1_ref, *refs,
                  act, glu, block_t, n_f, wire):
    if glu:
        w3_ref, w2_ref, y_ref, xg_ref, acc_ref = refs
    else:
        w2_ref, y_ref, xg_ref, acc_ref = refs
    e, it, jf = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    S = x_ref.shape[0]

    def rt(v):        # fused wire round-trip at a pool boundary
        return v.astype(jnp.bfloat16).astype(v.dtype) if wire == "bf16" \
            else v

    @pl.when((e == 0) & (it == 0) & (jf == 0))
    def _init_y():
        y_ref[...] = jnp.zeros_like(y_ref)

    cnt = cnt_ref[0, 0]
    active = it * block_t < cnt

    @pl.when(jf == 0)
    def _gather():     # dispatch prologue: pull routed rows into the tile
        xg_ref[...] = jnp.zeros_like(xg_ref)

        @pl.when(active)
        def _rows():
            def row(i, _):
                rid = rid_ref[0, i]

                @pl.when(rid < S)
                def _pull(rid=rid, i=i):
                    xg_ref[0, pl.dslice(i, 1), :] = rt(
                        x_ref[pl.dslice(rid, 1), :].astype(jnp.float32))
                return _

            lax.fori_loop(0, block_t, row, 0)

    @pl.when(jf == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active)
    def _compute():
        x = xg_ref[0]                                     # (bt, M) f32
        w1 = w1_ref[0].astype(jnp.float32)
        h = lax.dot_general(x, w1, (((1,), (0,)), ((), ())))
        if glu:
            w3 = w3_ref[0].astype(jnp.float32)
            h = ACT[act](h) * lax.dot_general(
                x, w3, (((1,), (0,)), ((), ())))
        else:
            h = ACT[act](h)
        w2 = w2_ref[0].astype(jnp.float32)
        acc_ref[...] += lax.dot_general(
            h, w2, (((1,), (0,)), ((), ())))[None]

    @pl.when((jf == n_f - 1) & active)
    def _scatter():    # combine epilogue: weight-dot + scatter-add
        out = rt(acc_ref[0])

        def row(i, _):
            rid = rid_ref[0, i]

            @pl.when(rid < S)
            def _push(rid=rid, i=i):
                w = ws_ref[0, i]
                y_ref[pl.dslice(rid, 1), :] = (
                    y_ref[pl.dslice(rid, 1), :]
                    + w * lax.dynamic_slice_in_dim(out, i, 1, axis=0))
            return _

        lax.fori_loop(0, block_t, row, 0)


def expert_ffn_grouped(x, flat_idx, weights, w1, w3, w2, *, cap,
                       act="silu", wire="f32", block_t=128, block_f=256,
                       interpret=None):
    """Fused dispatch -> ragged FFN -> combine. x: (S, M);
    flat_idx/weights: (S, k); returns (S, M) in x.dtype."""
    S, M = x.shape
    E, _, F = w1.shape
    glu = w3 is not None
    block_t = min(block_t, cap)
    block_f = min(block_f, F)
    c_pad = -(-cap // block_t) * block_t
    while F % block_f:
        block_f //= 2
    n_t, n_f = c_pad // block_t, F // block_f
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    rid, ws, _ = slot_metadata(flat_idx, weights, S, E, cap)
    if c_pad != cap:
        pad = ((0, 0), (0, c_pad - cap))
        rid = jnp.pad(rid, pad, constant_values=S)
        ws = jnp.pad(ws, pad)
    counts = jnp.sum((rid < S), axis=1, dtype=jnp.int32)[:, None]

    kernel = functools.partial(_fused_kernel, act=act, glu=glu,
                               block_t=block_t, n_f=n_f, wire=wire)
    w_in_spec = pl.BlockSpec((1, M, block_f), lambda e, it, jf: (e, 0, jf))
    in_specs = [
        pl.BlockSpec((S, M), lambda e, it, jf: (0, 0)),
        pl.BlockSpec((1, block_t), lambda e, it, jf: (e, it)),
        pl.BlockSpec((1, block_t), lambda e, it, jf: (e, it)),
        pl.BlockSpec((1, 1), lambda e, it, jf: (e, 0)),
        w_in_spec,
        *([w_in_spec] if glu else []),
        pl.BlockSpec((1, block_f, M), lambda e, it, jf: (e, jf, 0)),
    ]
    operands = ((x, rid, ws, counts, w1, w3, w2) if glu
                else (x, rid, ws, counts, w1, w2))

    y, _, _ = pl.pallas_call(
        kernel,
        grid=(E, n_t, n_f),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((S, M), lambda e, it, jf: (0, 0)),
            pl.BlockSpec((1, block_t, M), lambda e, it, jf: (e, it, 0)),
            pl.BlockSpec((1, block_t, M), lambda e, it, jf: (e, it, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, M), jnp.float32),
            jax.ShapeDtypeStruct((E, c_pad, M), jnp.float32),  # gathered
            jax.ShapeDtypeStruct((E, c_pad, M), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(*operands)
    return y.astype(x.dtype)
