"""Pallas TPU MoE dispatch (scatter) and combine (gather) kernels.

Dispatch scatters S tokens into the (n_slots, M) capacity buffer given
flat slot indices (expert * cap + slot, or n_slots for dropped tokens);
combine gathers them back weighted by the gate values.  The buffer lives
whole in VMEM (capacity buffers are per-device and modest); the token
stream is tiled over the grid.  A production kernel would sort tokens by
expert first — this layout keeps the HBM traffic identical and is the
faithful per-slot data movement of the GShard dispatch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _dispatch_kernel(x_ref, idx_ref, o_ref, *, n_slots, block_s, k):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def token(s, _):
        row = x_ref[s, :]
        for j in range(k):
            slot = idx_ref[s, j]

            @pl.when(slot < n_slots)
            def _write(slot=slot, row=row):
                # accumulate (not overwrite): matches the oracle's scatter-add
                # exactly, including adversarial duplicate-slot inputs — the
                # gate never produces collisions, but the op contract does not
                # depend on that.
                o_ref[pl.dslice(slot, 1), :] = (
                    o_ref[pl.dslice(slot, 1), :]
                    + row[None].astype(o_ref.dtype))
        return _

    lax.fori_loop(0, block_s, token, 0)


def moe_dispatch(x, flat_idx, n_slots, *, block_s=256, interpret=None):
    """x: (S, M); flat_idx: (S, k) -> (n_slots, M) capacity buffer."""
    S, M = x.shape
    k = flat_idx.shape[1]
    block_s = min(block_s, S)
    while S % block_s:
        block_s //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_dispatch_kernel, n_slots=n_slots,
                               block_s=block_s, k=k)
    return pl.pallas_call(
        kernel,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, M), lambda i: (i, 0)),
            pl.BlockSpec((block_s, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_slots, M), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_slots, M), x.dtype),
        interpret=interpret,
    )(x, flat_idx)


def _combine_kernel(buf_ref, idx_ref, w_ref, o_ref, *, n_slots, block_s, k):
    def token(s, _):
        acc = jnp.zeros((1, o_ref.shape[1]), jnp.float32)
        for j in range(k):
            slot = idx_ref[s, j]
            ok = slot < n_slots
            safe = jnp.where(ok, slot, 0)
            val = buf_ref[pl.dslice(safe, 1), :].astype(jnp.float32)
            wj = jnp.where(ok, w_ref[s, j], 0.0).astype(jnp.float32)
            acc = acc + wj * val
        o_ref[pl.dslice(s, 1), :] = acc.astype(o_ref.dtype)
        return _

    lax.fori_loop(0, block_s, token, 0)


def moe_combine(buf, flat_idx, weights, *, block_s=256, interpret=None):
    """buf: (n_slots, M); flat_idx/weights: (S, k) -> (S, M)."""
    n_slots, M = buf.shape
    S, k = flat_idx.shape
    block_s = min(block_s, S)
    while S % block_s:
        block_s //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_combine_kernel, n_slots=n_slots,
                               block_s=block_s, k=k)
    return pl.pallas_call(
        kernel,
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((n_slots, M), lambda i: (0, 0)),
            pl.BlockSpec((block_s, k), lambda i: (i, 0)),
            pl.BlockSpec((block_s, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, M), buf.dtype),
        interpret=interpret,
    )(buf, flat_idx, weights)
