"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends every wrapper runs the kernel body in interpret mode
(Python emulation, used by the test suite); on TPU the compiled kernels
run with the documented BlockSpec tiling.  ``use_ref=True`` routes to the
pure-jnp oracles instead (the dry-run path).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.expert_ffn import expert_ffn as _expert_ffn
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_dispatch import moe_combine as _combine
from repro.kernels.moe_dispatch import moe_dispatch as _dispatch
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


@partial(jax.jit, static_argnames=("causal", "window", "scale", "use_ref"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    use_ref=False):
    if use_ref:
        H, K = q.shape[2], k.shape[2]
        if H != K:
            k = jnp.repeat(k, H // K, axis=2)
            v = jnp.repeat(v, H // K, axis=2)
        return ref.flash_attention_ref(q, k, v, causal=causal,
                                       window=window, scale=scale)
    return _flash(q, k, v, causal=causal, window=window, scale=scale)


@partial(jax.jit, static_argnames=("act", "use_ref"))
def expert_ffn(x, w1, w3, w2, *, act="silu", use_ref=False):
    if use_ref:
        return ref.expert_ffn_ref(x, w1, w3, w2, act=act)
    return _expert_ffn(x, w1, w3, w2, act=act)


@partial(jax.jit, static_argnames=("n_slots", "use_ref"))
def moe_dispatch(x, flat_idx, n_slots, *, use_ref=False):
    if use_ref:
        return ref.moe_dispatch_ref(x, flat_idx, n_slots)
    return _dispatch(x, flat_idx, n_slots)


@partial(jax.jit, static_argnames=("use_ref",))
def moe_combine(buf, flat_idx, weights, *, use_ref=False):
    if use_ref:
        return ref.moe_combine_ref(buf, flat_idx, weights)
    return _combine(buf, flat_idx, weights)


@partial(jax.jit, static_argnames=("eps", "use_ref"))
def rmsnorm(x, scale, *, eps=1e-5, use_ref=False):
    if use_ref:
        return ref.rmsnorm_ref(x, scale, eps)
    return _rmsnorm(x, scale, eps=eps)
