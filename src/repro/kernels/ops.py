"""Back-compat public wrappers over the kernel-backend registry.

Historical API: ``use_ref=True`` routes to the pure-jnp oracles, otherwise
the Pallas kernels run (interpret mode off-TPU).  New code should call
``repro.kernels.registry.get_op`` directly — that is the single seam the
schedules and model layers use, and it adds the ``"auto"`` backend plus
per-op block-size configs.  The returned ops are jitted and cached by the
registry, so these wrappers stay cheap to call.
"""

from __future__ import annotations

from repro.kernels.registry import get_op


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    use_ref=False):
    op = get_op("flash_attention", backend="ref" if use_ref else "pallas",
                causal=causal, window=window, scale=scale)
    return op(q, k, v)


def expert_ffn(x, w1, w3, w2, *, act="silu", use_ref=False):
    op = get_op("expert_ffn", backend="ref" if use_ref else "pallas", act=act)
    return op(x, w1, w3, w2)


def moe_dispatch(x, flat_idx, n_slots, *, use_ref=False):
    op = get_op("moe_dispatch", backend="ref" if use_ref else "pallas",
                n_slots=n_slots)
    return op(x, flat_idx)


def moe_combine(buf, flat_idx, weights, *, use_ref=False):
    op = get_op("moe_combine", backend="ref" if use_ref else "pallas")
    return op(buf, flat_idx, weights)


def rmsnorm(x, scale, *, eps=1e-5, use_ref=False):
    op = get_op("rmsnorm", backend="ref" if use_ref else "pallas", eps=eps)
    return op(x, scale)
