"""Pallas TPU grouped expert FFN (the MoE compute hot-spot).

One kernel fuses both expert matmuls and the activation:
    out[e] = (act(x[e] @ w1[e]) [* (x[e] @ w3[e])]) @ w2[e]

Grid (E, nT, nF): expert-major, token tile (block_t) second, hidden tile
(block_f) innermost; the (block_t, M) output accumulator is revisited
across the nF iterations (constant index map on the F axis), so the
second matmul accumulates in VMEM and each w1/w3/w2 hidden slice is read
from HBM exactly once.  Tiles are MXU-aligned (128) on every contraction
dim; M stays unblocked (fits VMEM for M <= ~8k at block_f = 128-512).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def _ffn_kernel(x_ref, w1_ref, *refs, act, glu):
    # the w3 operand only exists in the GLU variant (no dead operand is
    # staged into VMEM for the 2-layer FFN)
    if glu:
        w3_ref, w2_ref, o_ref = refs
    else:
        w2_ref, o_ref = refs
    jf = pl.program_id(2)

    @pl.when(jf == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0].astype(jnp.float32)                      # (bt, M)
    w1 = w1_ref[0].astype(jnp.float32)                    # (M, bf)
    h = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())))
    if glu:
        w3 = w3_ref[0].astype(jnp.float32)
        h = ACT[act](h) * jax.lax.dot_general(
            x, w3, (((1,), (0,)), ((), ())))
    else:
        h = ACT[act](h)
    w2 = w2_ref[0].astype(jnp.float32)                    # (bf, M)
    o_ref[...] += jax.lax.dot_general(
        h, w2, (((1,), (0,)), ((), ()))).astype(o_ref.dtype)[None]


def expert_ffn(x, w1, w3, w2, *, act="silu", block_t=128, block_f=256,
               interpret=None):
    """x: (E, T, M); w1/w3: (E, M, F); w2: (E, F, M) -> (E, T, M)."""
    E, T, M = x.shape
    F = w1.shape[-1]
    glu = w3 is not None
    block_t = min(block_t, T)
    block_f = min(block_f, F)
    # Token dim: pad up to the MXU-aligned tile instead of shrinking the
    # tile to a divisor (non-power-of-two T used to degrade block_t all
    # the way to 1 — scalar-width MXU issue).  The pad rows compute
    # garbage that is sliced off below; they never alias real tokens.
    t_pad = -(-T // block_t) * block_t
    if t_pad != T:
        x = jnp.pad(x, ((0, 0), (0, t_pad - T), (0, 0)))
    while F % block_f:
        block_f //= 2
    n_t, n_f = t_pad // block_t, F // block_f
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_ffn_kernel, act=act, glu=glu)
    w_in_spec = pl.BlockSpec((1, M, block_f), lambda e, it, jf: (e, 0, jf))
    in_specs = [
        pl.BlockSpec((1, block_t, M), lambda e, it, jf: (e, it, 0)),
        w_in_spec,
        *([w_in_spec] if glu else []),
        pl.BlockSpec((1, block_f, M), lambda e, it, jf: (e, jf, 0)),
    ]
    operands = (x, w1, w3, w2) if glu else (x, w1, w2)

    out = pl.pallas_call(
        kernel,
        grid=(E, n_t, n_f),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_t, M), lambda e, it, jf: (e, it, 0)),
        out_shape=jax.ShapeDtypeStruct((E, t_pad, M), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :T] if t_pad != T else out
