"""Pallas TPU fused RMSNorm (single HBM pass, f32 statistics)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps=1e-5, block_r=256, interpret=None):
    """x: (R, D) rows; scale: (D,)."""
    R, D = x.shape
    block_r = min(block_r, R)
    while R % block_r:
        block_r //= 2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_r,),
        in_specs=[pl.BlockSpec((block_r, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_r, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, scale)
