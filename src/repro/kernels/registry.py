"""Unified kernel-backend registry for every hot-path op.

One seam between "what the schedules/models compute" and "how it is
computed": each op (``expert_ffn``, ``moe_dispatch``, ``moe_combine``,
``rmsnorm``, ``flash_attention``) is registered once per backend and
fetched with ``get_op(name, backend=...)``.  Backends:

  * ``"ref"``    — the pure-jnp oracles from ``repro.kernels.ref`` (the
    implementations the schedule bodies used to inline).  Differentiable,
    lowerable anywhere, and the ground truth the Pallas kernels are
    asserted against.
  * ``"pallas"`` — the Pallas TPU kernels.  On non-TPU backends they run
    in interpret mode (Python emulation) unless ``KernelConfig.interpret``
    pins it.  ``pallas_call`` has no autodiff rule, so every pallas op is
    wrapped in a ``custom_vjp``: ``moe_dispatch``/``moe_combine`` use
    their closed-form transposes (a gather / a scatter + weight dot),
    the rest recompute through the ref oracle — grads flow through
    schedule bodies regardless of backend.
  * ``"auto"``   — resolve at call time: ``pallas`` on TPU, ``ref``
    otherwise (overridable with ``REPRO_KERNEL_BACKEND``).  This is the
    default everywhere, so tests/CPU dry-runs stay on jnp while TPU runs
    get the fused kernels with zero config.

Per-op block sizes ride along in ``KernelConfig``; built ops are jitted
and cached by ``(name, backend, config, static-kwargs)``.

Adding a kernel = write the Pallas module, write/point at the jnp oracle
in ``ref.py``, and register both:

    @register("my_op", "ref")
    def _(cfg, static):
        return jax.jit(functools.partial(ref.my_op_ref, **static))

    @register("my_op", "pallas")
    def _(cfg, static):
        fwd = functools.partial(my_op_kernel, block=cfg.block_t, **static)
        return jax.jit(_with_ref_vjp(fwd, functools.partial(
            ref.my_op_ref, **static)))
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import expert_ffn as _expert_ffn_mod
from repro.kernels import expert_ffn_grouped as _grouped_mod
from repro.kernels import flash_attention as _flash_mod
from repro.kernels import moe_dispatch as _dispatch_mod
from repro.kernels import ref
from repro.kernels import rmsnorm as _rmsnorm_mod

BACKENDS = ("ref", "pallas")
_ENV_BACKEND = "REPRO_KERNEL_BACKEND"


@dataclass(frozen=True)
class KernelConfig:
    """Backend choice + per-op tile sizes, threaded from the model configs
    down into shard_map bodies (hashable: lives inside frozen configs and
    keys the built-op cache)."""

    backend: str = "auto"          # "auto" | "pallas" | "ref"
    interpret: Optional[bool] = None  # None = interpret iff not on TPU
    # expert_ffn tiles (token dim, hidden dim; M stays unblocked)
    block_t: int = 128
    block_f: int = 256
    # moe_dispatch / moe_combine token-stream tile
    block_s: int = 256
    # rmsnorm row tile
    block_r: int = 256
    # flash_attention query/key tiles
    block_q: int = 128
    block_k: int = 128


DEFAULT = KernelConfig()

# (op name, backend) -> builder(cfg: KernelConfig, static: dict) -> callable
_REGISTRY: dict = {}


def register(name: str, backend: str):
    """Decorator registering a builder for ``(name, backend)``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, want one of {BACKENDS}")

    def deco(build: Callable):
        _REGISTRY[(name, backend)] = build
        return build

    return deco


def list_ops() -> tuple:
    return tuple(sorted({n for n, _ in _REGISTRY}))


def available_backends(name: str) -> tuple:
    return tuple(b for b in BACKENDS if (name, b) in _REGISTRY)


def resolve_backend(backend: Optional[str] = None,
                    cfg: Optional[KernelConfig] = None) -> str:
    """Concrete backend for a request: explicit arg > config > env > auto.

    ``auto`` picks ``pallas`` on TPU and ``ref`` everywhere else — the ref
    oracles are the same math and XLA already fuses them well on CPU/GPU,
    while interpret-mode Pallas is emulation-speed and only worth running
    when explicitly asked for (tests, kernel debugging).
    """
    b = backend or (cfg or DEFAULT).backend or "auto"
    if b == "auto":
        b = os.environ.get(_ENV_BACKEND, "auto")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r}, want one of {BACKENDS}")
    return b


def get_op(name: str, *, backend: Optional[str] = None,
           cfg: Optional[KernelConfig] = None, **static) -> Callable:
    """Fetch the jitted op ``name`` for the resolved backend.

    ``static`` holds compile-time parameters (``act``, ``n_slots``,
    ``causal``, ``eps``, ...) baked into the returned callable, which then
    takes array arguments only.  Built ops are cached, so calling this in
    a traced function body is free after the first hit.
    """
    cfg = cfg or DEFAULT
    b = resolve_backend(backend, cfg)
    if (name, b) not in _REGISTRY:
        known = ", ".join(f"{n}:{bk}" for n, bk in sorted(_REGISTRY))
        raise KeyError(f"no kernel op {name!r} for backend {b!r} ({known})")
    return _build(name, b, cfg, tuple(sorted(static.items())))


@functools.lru_cache(maxsize=None)
def _build(name, backend, cfg, static_items):
    return _REGISTRY[(name, backend)](cfg, dict(static_items))


def _with_ref_vjp(fwd_fn: Callable, ref_fn: Callable) -> Callable:
    """Differentiate a Pallas op by recompute through its jnp oracle.

    Forward runs the kernel; backward re-traces ``ref_fn`` (numerically
    identical by the parity tests) and applies its VJP.  Residuals are the
    raw inputs, so nothing kernel-internal is saved.
    """

    @jax.custom_vjp
    def op(*args):
        return fwd_fn(*args)

    def fwd(*args):
        return fwd_fn(*args), args

    def bwd(args, g):
        return jax.vjp(ref_fn, *args)[1](g)

    op.defvjp(fwd, bwd)
    return op


# --- expert_ffn --------------------------------------------------------------

@register("expert_ffn", "ref")
def _expert_ffn_ref(cfg, static):
    act = static.get("act", "silu")
    return jax.jit(functools.partial(ref.expert_ffn_ref, act=act))


@register("expert_ffn", "pallas")
def _expert_ffn_pallas(cfg, static):
    act = static.get("act", "silu")
    fwd = functools.partial(
        _expert_ffn_mod.expert_ffn, act=act, block_t=cfg.block_t,
        block_f=cfg.block_f, interpret=cfg.interpret)
    return jax.jit(_with_ref_vjp(
        fwd, functools.partial(ref.expert_ffn_ref, act=act)))


# --- expert_ffn_ragged / expert_ffn_grouped ----------------------------------
# The dropless pair (PR 6).  ``expert_ffn_ragged`` is the pool-path form
# (the executor hands it the A2A receive buffer + routed-row counts);
# ``expert_ffn_grouped`` is the single-device megakernel fusing dispatch
# gather and combine scatter around the ragged FFN.  Both carry analytic
# custom_vjps: the ragged bwd is the hand-written transpose of the two
# GEMMs with the routed-row mask folded into the cotangent (counts are
# integral — cotangent None), and the fused bwd composes the oracle's
# closed-form dispatch/combine transposes via its VJP with ``flat_idx``
# held out as a non-differentiable operand.

def _ragged_analytic_vjp(fwd_fn: Callable, act: str) -> Callable:
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]

    @jax.custom_vjp
    def op(xb, counts, w1, w3, w2):
        return fwd_fn(xb, counts, w1, w3, w2)

    def fwd(xb, counts, w1, w3, w2):
        return fwd_fn(xb, counts, w1, w3, w2), (xb, counts, w1, w3, w2)

    def bwd(res, g):
        xb, counts, w1, w3, w2 = res
        E, G, c, M = xb.shape
        mask = jnp.arange(c)[None, None, :] < counts[:, :, None]
        gm = (g * mask[..., None].astype(g.dtype)).reshape(
            E, G * c, M).astype(jnp.float32)
        xf = xb.reshape(E, G * c, M).astype(jnp.float32)
        w1f = w1.astype(jnp.float32)
        w2f = w2.astype(jnp.float32)
        h1 = jnp.einsum("etm,emf->etf", xf, w1f)
        if w3 is not None:
            w3f = w3.astype(jnp.float32)
            h3 = jnp.einsum("etm,emf->etf", xf, w3f)
            mid, mid_vjp = jax.vjp(lambda a, b: actf(a) * b, h1, h3)
        else:
            mid, mid_vjp = jax.vjp(actf, h1)
        d_w2 = jnp.einsum("etf,etm->efm", mid, gm).astype(w2.dtype)
        d_mid = jnp.einsum("etm,efm->etf", gm, w2f)
        if w3 is not None:
            d_h1, d_h3 = mid_vjp(d_mid)
            d_x = (jnp.einsum("etf,emf->etm", d_h1, w1f)
                   + jnp.einsum("etf,emf->etm", d_h3, w3f))
            d_w3 = jnp.einsum("etm,etf->emf", xf, d_h3).astype(w3.dtype)
        else:
            (d_h1,) = mid_vjp(d_mid)
            d_x = jnp.einsum("etf,emf->etm", d_h1, w1f)
            d_w3 = None
        d_w1 = jnp.einsum("etm,etf->emf", xf, d_h1).astype(w1.dtype)
        d_x = d_x.reshape(E, G, c, M).astype(xb.dtype)
        return d_x, None, d_w1, d_w3, d_w2

    op.defvjp(fwd, bwd)
    return op


def _grouped_fused_vjp(fwd_fn: Callable, ref_fn: Callable) -> Callable:
    @jax.custom_vjp
    def op(x, flat_idx, weights, w1, w3, w2):
        return fwd_fn(x, flat_idx, weights, w1, w3, w2)

    def fwd(x, flat_idx, weights, w1, w3, w2):
        return (fwd_fn(x, flat_idx, weights, w1, w3, w2),
                (x, flat_idx, weights, w1, w3, w2))

    def bwd(res, g):
        x, flat_idx, weights, w1, w3, w2 = res
        d = jax.vjp(
            lambda x_, ws_, w1_, w3_, w2_: ref_fn(
                x_, flat_idx, ws_, w1_, w3_, w2_),
            x, weights, w1, w3, w2)[1](g)
        return d[0], None, d[1], d[2], d[3], d[4]

    op.defvjp(fwd, bwd)
    return op


@register("expert_ffn_ragged", "ref")
def _expert_ffn_ragged_ref(cfg, static):
    act = static.get("act", "silu")
    return jax.jit(_ragged_analytic_vjp(
        functools.partial(ref.expert_ffn_ragged_ref, act=act), act))


@register("expert_ffn_ragged", "pallas")
def _expert_ffn_ragged_pallas(cfg, static):
    act = static.get("act", "silu")
    fwd = functools.partial(
        _grouped_mod.expert_ffn_ragged, act=act, block_t=cfg.block_t,
        block_f=cfg.block_f, interpret=cfg.interpret)
    return jax.jit(_ragged_analytic_vjp(fwd, act))


def _grouped_ref_fn(static):
    return functools.partial(
        ref.expert_ffn_grouped_ref, cap=static["cap"],
        act=static.get("act", "silu"), wire=static.get("wire", "f32"))


@register("expert_ffn_grouped", "ref")
def _expert_ffn_grouped_ref(cfg, static):
    ref_fn = _grouped_ref_fn(static)
    return jax.jit(_grouped_fused_vjp(ref_fn, ref_fn))


@register("expert_ffn_grouped", "pallas")
def _expert_ffn_grouped_pallas(cfg, static):
    fwd = functools.partial(
        _grouped_mod.expert_ffn_grouped, cap=static["cap"],
        act=static.get("act", "silu"), wire=static.get("wire", "f32"),
        block_t=cfg.block_t, block_f=cfg.block_f, interpret=cfg.interpret)
    return jax.jit(_grouped_fused_vjp(fwd, _grouped_ref_fn(static)))


# --- moe_dispatch / moe_combine ----------------------------------------------
# The pallas backends of these two ops do NOT use the ref-recompute VJP:
# both have closed-form transposes that are strictly cheaper than
# re-tracing the oracle.  Dispatch is a scatter-add of each token into
# its flat slots, so its backward w.r.t. the token stream is the gather
# of the output cotangent at the same slots; combine is a weighted
# gather, so its backward is a scatter (w.r.t. the buffer) plus a dot
# (w.r.t. the weights).  ``flat_idx`` is integral — cotangent None.

def _dispatch_analytic_vjp(fwd_fn: Callable, n_slots: int) -> Callable:
    @jax.custom_vjp
    def op(x, flat_idx):
        return fwd_fn(x, flat_idx)

    def fwd(x, flat_idx):
        return fwd_fn(x, flat_idx), flat_idx

    def bwd(flat_idx, g):
        # row n_slots of the padded cotangent is the drop sentinel: zero
        gpad = jnp.concatenate(
            [g, jnp.zeros((1, g.shape[-1]), g.dtype)], axis=0)
        return gpad[flat_idx].sum(axis=1), None   # (S, k, M) -> (S, M)

    op.defvjp(fwd, bwd)
    return op


def _combine_analytic_vjp(fwd_fn: Callable) -> Callable:
    @jax.custom_vjp
    def op(buf, flat_idx, weights):
        return fwd_fn(buf, flat_idx, weights)

    def fwd(buf, flat_idx, weights):
        return fwd_fn(buf, flat_idx, weights), (buf, flat_idx, weights)

    def bwd(res, g):
        buf, flat_idx, weights = res
        n_slots, M = buf.shape
        S, k = flat_idx.shape
        kept = flat_idx < n_slots
        w = jnp.where(kept, weights, 0.0).astype(buf.dtype)
        # d/d buf: scatter-add of w[s,j] * g[s] into the flat slots (the
        # dispatch scatter, drop sentinel row discarded).
        src = (w[:, :, None] * g[:, None, :].astype(buf.dtype))
        cot_buf = jnp.zeros((n_slots + 1, M), buf.dtype).at[
            flat_idx.reshape(-1)].add(src.reshape(S * k, M),
                                      mode="drop")[:-1]
        # d/d weights: the gathered rows dotted with the cotangent.
        vals = buf[jnp.minimum(flat_idx, n_slots - 1).reshape(-1)]
        cot_w = jnp.einsum("sm,skm->sk", g.astype(buf.dtype),
                           vals.reshape(S, k, M))
        cot_w = jnp.where(kept, cot_w, 0.0).astype(weights.dtype)
        return cot_buf, None, cot_w

    op.defvjp(fwd, bwd)
    return op


@register("moe_dispatch", "ref")
def _moe_dispatch_ref(cfg, static):
    n_slots = static["n_slots"]
    return jax.jit(lambda x, flat_idx: ref.moe_dispatch_ref(
        x, flat_idx, n_slots))


@register("moe_dispatch", "pallas")
def _moe_dispatch_pallas(cfg, static):
    n_slots = static["n_slots"]
    fwd = functools.partial(
        _dispatch_mod.moe_dispatch, n_slots=n_slots, block_s=cfg.block_s,
        interpret=cfg.interpret)
    return jax.jit(_dispatch_analytic_vjp(fwd, n_slots))


@register("moe_combine", "ref")
def _moe_combine_ref(cfg, static):
    return jax.jit(ref.moe_combine_ref)


@register("moe_combine", "pallas")
def _moe_combine_pallas(cfg, static):
    fwd = functools.partial(_dispatch_mod.moe_combine, block_s=cfg.block_s,
                            interpret=cfg.interpret)
    return jax.jit(_combine_analytic_vjp(fwd))


# --- rmsnorm -----------------------------------------------------------------

@register("rmsnorm", "ref")
def _rmsnorm_ref(cfg, static):
    eps = static.get("eps", 1e-5)
    return jax.jit(functools.partial(ref.rmsnorm_ref, eps=eps))


@register("rmsnorm", "pallas")
def _rmsnorm_pallas(cfg, static):
    eps = static.get("eps", 1e-5)
    fwd = functools.partial(_rmsnorm_mod.rmsnorm, eps=eps,
                            block_r=cfg.block_r, interpret=cfg.interpret)
    return jax.jit(_with_ref_vjp(
        fwd, functools.partial(ref.rmsnorm_ref, eps=eps)))


# --- flash_attention ---------------------------------------------------------

def _flash_ref_fn(static):
    causal = static.get("causal", True)
    window = static.get("window")
    scale = static.get("scale")

    def f(q, k, v):
        H, K = q.shape[2], k.shape[2]
        if H != K:  # the oracle wants KV pre-repeated; the kernel is GQA-aware
            k = jnp.repeat(k, H // K, axis=2)
            v = jnp.repeat(v, H // K, axis=2)
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)

    return f


@register("flash_attention", "ref")
def _flash_ref(cfg, static):
    return jax.jit(_flash_ref_fn(static))


@register("flash_attention", "pallas")
def _flash_pallas(cfg, static):
    fwd = functools.partial(
        _flash_mod.flash_attention, causal=static.get("causal", True),
        window=static.get("window"), scale=static.get("scale"),
        block_q=cfg.block_q, block_k=cfg.block_k, interpret=cfg.interpret)
    return jax.jit(_with_ref_vjp(fwd, _flash_ref_fn(static)))
