"""Pallas TPU flash attention (GQA-aware, causal + sliding window).

Grid (B, H, nq, nk) with the KV-block index innermost; online-softmax
running stats (m, l) and the output accumulator live in VMEM scratch and
carry across the nk iterations.  KV is consumed in its native
(B, L, K, hd) GQA layout — the index map folds the query-head -> kv-head
mapping, so no head replication ever hits HBM.

Block shapes default to (128, 128): MXU-aligned on the (q, k) tile and
sized so q/k/v tiles + accumulator fit comfortably in ~16 MB VMEM for
head dims up to 256.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, window, block_q, block_k, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * corr
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_k - 1)
    def _flush():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q=128, block_k=128, interpret=None):
    """q: (B, Lq, H, hd); k, v: (B, Lk, K, hd) with H % K == 0."""
    B, Lq, H, hd = q.shape
    _, Lk, K, _ = k.shape
    assert H % K == 0, (H, K)
    rep = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Lq)
    block_k = min(block_k, Lk)
    while Lq % block_q:
        block_q //= 2
    while Lk % block_k:
        block_k //= 2
    n_q, n_k = Lq // block_q, Lk // block_k
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, rep=rep: (b, ik, h // rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, rep=rep: (b, ik, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
