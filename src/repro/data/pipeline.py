"""Synthetic LM data pipeline: deterministic, seekable, shard-aware.

A Zipf-ish unigram mixture with induced bigram structure, so cross-entropy
has real signal (a model can learn it) while remaining fully offline and
reproducible.  Batches are produced as global jax.Arrays laid out to the
mesh's batch sharding (make_array_from_callback) so each host/device only
materializes its own shard — the same code path a real loader uses.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_heavy: int = 64          # heavy bigram successors
    heavy_prob: float = 0.7    # P(next token follows bigram table)


class SyntheticLM:
    """Deterministic synthetic corpus with learnable bigram structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipf unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each token's preferred successor set
        self.bigram = rng.integers(0, v, size=(v, cfg.n_heavy))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, L = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, L + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=B, p=self.unigram)
        follow = rng.random((B, L)) < cfg.heavy_prob
        succ_idx = rng.integers(0, cfg.n_heavy, size=(B, L))
        rand_tok = rng.choice(cfg.vocab_size, size=(B, L), p=self.unigram)
        for t in range(L):
            nxt = np.where(follow[:, t],
                           self.bigram[toks[:, t], succ_idx[:, t]],
                           rand_tok[:, t])
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def sharded_batch(self, step: int, mesh, batch_axes) -> dict:
        """Global jax.Array batch with dim 0 sharded over ``batch_axes``."""
        host = self.batch(step)
        spec = P(tuple(batch_axes) or None, None)
        out = {}
        for k, v in host.items():
            sh = NamedSharding(mesh, spec)
            out[k] = jax.make_array_from_callback(
                v.shape, sh, lambda idx, v=v: v[idx])
        return out


def make_batch_specs(mesh, batch_axes):
    spec = P(tuple(batch_axes) or None, None)
    return {"tokens": NamedSharding(mesh, spec),
            "labels": NamedSharding(mesh, spec)}
