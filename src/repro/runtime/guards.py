"""Training guard rails: non-finite skip-step, LR backoff, loss-spike
detection, and fp8 wire-overflow fallback.

The jitted side lives in ``train.loop.make_guarded_train_step`` (the
update is discarded leaf-wise when loss or grad norm goes non-finite);
this module owns the HOST-side policy around it:

  * :class:`GuardState` — per-run state machine.  Every step's
    ``(loss, nonfinite)`` observation returns an action: ``OK`` (apply),
    ``SKIP`` (the jitted step already kept the old params; back the LR
    off), or ``ROLLBACK`` (the consecutive-skip streak or the loss-spike
    detector fired — re-anchor to the last good checkpoint).
  * Loss-spike detection — rolling median + MAD over the recent finite
    losses; a loss further than ``spike_z`` robust sigmas above the
    median marks the run poisoned even though every value is finite
    (the failure mode a pure NaN check can never see).
  * fp8 wire-overflow fallback — the encode path in
    ``core.collectives`` counts saturating elements into a process-wide
    accumulator (enabled here); when the observed saturation rate
    crosses ``fp8_sat_threshold`` the trainer swaps every fp8 wire
    decision to ``fp8_fallback`` via ``autosched.set_wire_ceiling`` +
    cache invalidation and re-jits — a cheap plan swap, not a restart.

All of it is opt-in: with ``guards=None`` the Trainer runs the exact
pre-existing step function and none of this module is consulted.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.obs.registry import Histogram

OK = "ok"
SKIP = "skip"
ROLLBACK = "rollback"


@dataclass(frozen=True)
class GuardConfig:
    """Knobs for the training guard rails (see module docstring).

    ``max_skips``: consecutive non-finite skip-steps before a rollback
    is requested.  ``lr_backoff`` multiplies the LR scale on every skip;
    ``lr_recover`` multiplies it back up (capped at 1.0) on every clean
    step.  The spike detector needs ``spike_min`` finite losses of
    history and fires at ``spike_z`` robust sigmas (median + MAD) above
    the rolling median.  ``fp8_sat_threshold`` is the fraction of
    saturating fp8 wire elements that triggers the ``fp8_fallback``
    wire-dtype swap.
    """

    max_skips: int = 3
    lr_backoff: float = 0.5
    lr_recover: float = 1.5
    spike_window: int = 32
    spike_min: int = 8
    spike_z: float = 10.0
    fp8_sat_threshold: float = 1e-3
    fp8_fallback: str = "bf16"

    def __post_init__(self):
        if self.max_skips < 1:
            raise ValueError("max_skips must be >= 1")
        if not 0.0 < self.lr_backoff <= 1.0:
            raise ValueError("lr_backoff must be in (0, 1]")


@dataclass
class GuardState:
    """Mutable per-run guard state: streaks, LR scale, counters, and an
    event log (``events`` is what the launchers print and the artifact
    JSONs record)."""

    cfg: GuardConfig = field(default_factory=GuardConfig)
    lr_scale: float = 1.0
    streak: int = 0
    counters: dict = field(default_factory=lambda: {
        "steps": 0, "skipped": 0, "rollbacks": 0, "loss_spikes": 0,
        "fp8_fallbacks": 0, "rollback_unavailable": 0})
    events: list = field(default_factory=list)
    _losses: Histogram = None

    def __post_init__(self):
        # rolling finite-loss window: the obs histogram is the one
        # quantile codepath (median == sorted[n // 2], same as the
        # engine's p50), so the spike detector and the serve latency
        # stats can never drift apart numerically
        self._losses = Histogram("guard_loss",
                                 window=self.cfg.spike_window)

    # --- per-step policy -----------------------------------------------------
    def observe(self, step: int, loss: float, nonfinite: bool) -> str:
        """Fold one step's outcome in; returns OK / SKIP / ROLLBACK."""
        self.counters["steps"] += 1
        if nonfinite or not math.isfinite(loss):
            self.counters["skipped"] += 1
            self.streak += 1
            self.lr_scale = max(self.lr_scale * self.cfg.lr_backoff, 1e-4)
            self.events.append({"step": step, "kind": "skip",
                                "streak": self.streak,
                                "lr_scale": self.lr_scale})
            if self.streak >= self.cfg.max_skips:
                return ROLLBACK
            return SKIP
        if self._is_spike(loss):
            self.counters["loss_spikes"] += 1
            self.events.append({"step": step, "kind": "loss_spike",
                                "loss": loss})
            return ROLLBACK
        self.streak = 0
        self.lr_scale = min(self.lr_scale * self.cfg.lr_recover, 1.0)
        self._losses.add(loss)
        return OK

    def _is_spike(self, loss: float) -> bool:
        """Rolling median + MAD outlier test (spiking losses are never
        folded into the window, so one spike cannot mask the next)."""
        if len(self._losses) < self.cfg.spike_min:
            return False
        sigma = 1.4826 * max(self._losses.mad(), 1e-12)
        return loss > self._losses.median() + self.cfg.spike_z * sigma

    # --- rollback bookkeeping ------------------------------------------------
    def record_rollback(self, step: int, restored_step) -> None:
        """A rollback happened (or was needed but unavailable): reset the
        streak and the spike window — the restored state's losses belong
        to a different trajectory."""
        self.streak = 0
        self._losses.reset()
        if restored_step is None:
            self.counters["rollback_unavailable"] += 1
            self.events.append({"step": step, "kind": "rollback_unavailable"})
        else:
            self.counters["rollbacks"] += 1
            self.events.append({"step": step, "kind": "rollback",
                                "restored_step": restored_step})

    # --- fp8 wire-overflow fallback ------------------------------------------
    def check_fp8(self) -> bool:
        """True exactly once: when the observed fp8 wire saturation rate
        crosses the threshold (and a fallback hasn't already fired)."""
        if self.counters["fp8_fallbacks"]:
            return False
        rate = fp8_sat_rate()
        if rate > self.cfg.fp8_sat_threshold:
            self.counters["fp8_fallbacks"] += 1
            self.events.append({"kind": "fp8_fallback", "sat_rate": rate,
                                "wire": self.cfg.fp8_fallback})
            return True
        return False

    def summary(self) -> str:
        c = self.counters
        return (f"guards: {c['steps']} steps, {c['skipped']} skipped, "
                f"{c['rollbacks']} rollbacks, {c['loss_spikes']} loss "
                f"spikes, {c['fp8_fallbacks']} fp8 fallbacks, "
                f"lr_scale {self.lr_scale:.3g}")


# --- fp8 saturation accumulator ----------------------------------------------
# ``collectives.wire_encode`` (fp8 path) emits (sat_count, n_elements)
# pairs through jax.debug.callback when a monitor is installed; this is
# the process-wide sink.  Rates are read by GuardState.check_fp8.

_SAT = {"sat": 0, "total": 0}


def _sat_cb(sat, total) -> None:
    _SAT["sat"] += int(sat)
    _SAT["total"] += int(total)


def enable_fp8_monitor() -> None:
    """Install the saturation counter into the fp8 wire-encode path.
    Trace-time gated: traces built while enabled carry the counting
    callback; with no monitor installed the encode emits nothing."""
    from repro.core import collectives
    collectives.set_fp8_monitor(_sat_cb)


def disable_fp8_monitor() -> None:
    from repro.core import collectives
    collectives.set_fp8_monitor(None)


def reset_fp8_counter() -> None:
    _SAT["sat"] = _SAT["total"] = 0


def fp8_sat_counts() -> tuple:
    return _SAT["sat"], _SAT["total"]


def fp8_sat_rate() -> float:
    return _SAT["sat"] / _SAT["total"] if _SAT["total"] else 0.0
