"""Rollback policy: re-anchor a poisoned training run to the last good
retained checkpoint.

:class:`RollbackManager` is the thin policy layer between the guard
rails (``runtime.guards``, which decide *when* to roll back) and the
:class:`~repro.checkpoint.ckpt.CheckpointStore` (which knows *what* is
restorable).  It snapshots on clean steps, and on rollback restores the
newest verified checkpoint — falling back across corrupt files — and
reports which step the run re-anchored to.  The Trainer keeps its data
pipeline marching forward deterministically; only params/opt state are
rewound, so a resumed run is bit-identical to one that never faulted
from the restore point onward (tests/test_runtime.py locks this down).
"""

from __future__ import annotations

from repro.checkpoint.ckpt import CheckpointStore


class RollbackManager:
    """Snapshot/restore policy over a :class:`CheckpointStore`.

    ``shardings`` (optional pytrees matching params / opt state) are
    applied on restore so leaves land back on their original device
    layout.
    """

    def __init__(self, store: CheckpointStore, shardings=None):
        self.store = store
        self.shardings = shardings
        self.last_good_step = None
        self.events = []

    def snapshot(self, params, opt_state, step: int) -> str:
        """Persist a clean (guard-approved) step."""
        path = self.store.save({"params": params, "opt_state": opt_state},
                               step)
        self.last_good_step = step
        self.events.append({"kind": "snapshot", "step": step})
        return path

    def rollback(self, step: int):
        """Restore the newest verified checkpoint.

        Returns ``(params, opt_state, restored_step)`` or ``None`` when
        nothing is restorable (the caller decides whether to limp on or
        abort)."""
        try:
            tree, restored_step, path = self.store.restore(self.shardings)
        except FileNotFoundError:
            self.events.append({"kind": "rollback_failed", "step": step})
            return None
        self.events.append({"kind": "rollback", "step": step,
                            "restored_step": restored_step, "path": path})
        return tree["params"], tree["opt_state"], restored_step
