from repro.runtime.faults import FaultPlan, FaultSpec, StarveState  # noqa: F401
from repro.runtime.guards import (OK, ROLLBACK, SKIP,  # noqa: F401
                                  GuardConfig, GuardState,
                                  disable_fp8_monitor, enable_fp8_monitor,
                                  fp8_sat_counts, fp8_sat_rate,
                                  reset_fp8_counter)
from repro.runtime.rollback import RollbackManager  # noqa: F401
