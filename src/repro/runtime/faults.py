"""Deterministic fault injection: one seeded spec drives every failure
path the runtime layer must survive.

The guard rails / rollback / serve-SLO machinery (``repro.runtime`` +
``serve.engine``) would be untestable folklore without a way to *cause*
the failures on demand.  A :class:`FaultPlan` is a parsed, seeded,
read-only description of which faults fire when; the train loop, the
fp8 encode path, the checkpoint store, and the serving engine each ask
it cheap questions (``grad_fault(step)``, ``fp8_sat_factor()``, ...)
and inject accordingly.  With no plan (or an empty one) every hook is a
no-op that costs one ``is None`` check — production paths carry zero
fault-injection overhead.

Spec grammar (``launch/train.py --faults`` / ``launch/serve.py
--faults``): semicolon-separated atoms, each ``kind@key=val,key=val``:

  ``nan_grad@step=5``            poison gradients with NaN at step 5
  ``nan_grad@step=5-8,value=inf``  ... a step range, with +inf instead
  ``fp8_sat@factor=64``          shrink fp8 wire-encode scales by 64x so
                                 payloads saturate (overflow detection)
  ``ckpt_bitflip@save=2``        flip one seeded bit in the 2nd
                                 checkpoint file written by the store
  ``req_delay@rid=1,rounds=6``   serve: request 1's row stops advancing
                                 for 6 decode rounds (watchdog bait)
  ``req_timeout@rid=2,ticks=4``  serve: request 2 is force-expired after
                                 4 engine ticks (deadline path, wall-
                                 clock free so CI is deterministic)
  ``alloc_starve@tick=1,hold=8,rounds=5``  serve: hold up to 8 arena
                                 blocks hostage from tick 1 for 5 ticks

Everything is deterministic under (spec, seed): parsing is order-
preserving, the bit flipped by ``ckpt_bitflip`` comes from a seeded
RNG, and the serve faults key on request ids / tick counts, never wall
time.
"""

from __future__ import annotations

from dataclasses import dataclass, field

KINDS = ("nan_grad", "fp8_sat", "ckpt_bitflip", "req_delay",
         "req_timeout", "alloc_starve")


@dataclass(frozen=True)
class FaultSpec:
    """One parsed fault atom: its kind plus integer/float args."""

    kind: str
    args: tuple = ()            # sorted (key, value) pairs — hashable

    def get(self, key, default=None):
        return dict(self.args).get(key, default)


def _parse_val(key: str, raw: str):
    """``step=5-8`` becomes an inclusive (lo, hi) range; numbers parse
    as int when possible, else float."""
    if "-" in raw and not raw.startswith("-"):
        lo, hi = raw.split("-", 1)
        return (int(lo), int(hi))
    try:
        return int(raw)
    except ValueError:
        return float(raw)


@dataclass(frozen=True)
class FaultPlan:
    """A parsed, immutable set of faults plus the injection seed."""

    specs: tuple = ()
    seed: int = 0

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse the ``--faults`` grammar (empty/None -> empty plan)."""
        specs = []
        for atom in (text or "").split(";"):
            atom = atom.strip()
            if not atom:
                continue
            kind, _, rest = atom.partition("@")
            kind = kind.strip()
            if kind not in KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} (want one of {KINDS})")
            args = []
            for kv in rest.split(","):
                kv = kv.strip()
                if not kv:
                    continue
                if "=" not in kv:
                    raise ValueError(
                        f"bad fault arg {kv!r} in {atom!r} (want key=val)")
                k, v = kv.split("=", 1)
                args.append((k.strip(), _parse_val(k.strip(), v.strip())))
            specs.append(FaultSpec(kind=kind, args=tuple(sorted(args))))
        return cls(specs=tuple(specs), seed=int(seed))

    def __bool__(self) -> bool:
        return bool(self.specs)

    def _of(self, kind: str):
        return [s for s in self.specs if s.kind == kind]

    # --- train-loop hooks ----------------------------------------------------
    def grad_fault(self, step: int) -> float:
        """Multiplier-offset for the guarded train step: grads become
        ``g * (1 + fault)``.  0.0 (exact identity) when no ``nan_grad``
        fault covers ``step``; NaN / +inf when one does."""
        for s in self._of("nan_grad"):
            at = s.get("step", 0)
            lo, hi = at if isinstance(at, tuple) else (at, at)
            if lo <= step <= hi:
                return float("inf") if s.get("value") == "inf" \
                    or s.get("value") == float("inf") else float("nan")
        return 0.0

    # --- wire-encode hook ----------------------------------------------------
    def fp8_sat_factor(self) -> float:
        """Scale-shrink factor for fp8 wire encodes (0.0 = no fault)."""
        for s in self._of("fp8_sat"):
            return float(s.get("factor", 64))
        return 0.0

    # --- checkpoint hook -----------------------------------------------------
    def ckpt_corrupts(self, save_index: int) -> bool:
        """True when the ``save_index``-th (1-based) store save should be
        bit-flipped after writing."""
        return any(s.get("save", 1) == save_index
                   for s in self._of("ckpt_bitflip"))

    def flip_bit(self, path: str) -> int:
        """Flip one seeded bit of the file at ``path`` in place, aimed at
        the middle of the file where the leaf *data* lives (the zip
        headers at the front and the central directory at the tail give
        unreadable-file errors instead; those are a separate restore
        path).  Returns the flipped byte offset."""
        import os
        import random
        size = os.path.getsize(path)
        rng = random.Random(self.seed * 1000003 + size)
        lo = min(512, max(size // 4, 1))
        hi = max(size - 1024, size // 2, lo + 1)
        off = rng.randrange(lo, hi)
        with open(path, "r+b") as f:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ (1 << rng.randrange(8))]))
        return off

    # --- serve hooks ---------------------------------------------------------
    def req_delay_rounds(self, rid) -> int:
        """Decode rounds request ``rid``'s row should refuse to advance
        (0 = no fault).  The watchdog is what should catch this."""
        for s in self._of("req_delay"):
            if s.get("rid") == rid:
                return int(s.get("rounds", 4))
        return 0

    def req_timeout_ticks(self, rid) -> int:
        """Engine ticks after which request ``rid`` is force-expired
        (0 = no fault).  Wall-clock-free stand-in for a blown deadline."""
        for s in self._of("req_timeout"):
            if s.get("rid") == rid:
                return int(s.get("ticks", 4))
        return 0

    def alloc_starve(self):
        """``(start_tick, hold, rounds)`` for the block-allocator
        starvation fault, or None."""
        for s in self._of("alloc_starve"):
            return (int(s.get("tick", 1)), int(s.get("hold", 1 << 30)),
                    int(s.get("rounds", 4)))
        return None

    def summary(self) -> str:
        return "; ".join(
            s.kind + ("@" + ",".join(f"{k}={v}" for k, v in s.args)
                      if s.args else "")
            for s in self.specs) or "(no faults)"


@dataclass
class StarveState:
    """Engine-side countdown for one ``alloc_starve`` fault: blocks are
    reserved (never allocated — the ledger is exactly the mechanism a
    buggy leak would use) at ``start`` and given back ``rounds`` ticks
    later."""

    start: int
    hold: int
    rounds: int
    held: int = 0
    active: bool = False
    done: bool = False
    ticks: int = field(default=0)

    def tick(self, allocator, tick: int) -> None:
        """Advance one engine tick against the live allocator."""
        if self.done:
            return
        if not self.active and tick >= self.start:
            self.held = min(self.hold, allocator.available)
            allocator.reserve(self.held)
            self.active = True
        elif self.active:
            self.ticks += 1
            if self.ticks >= self.rounds:
                allocator.unreserve(self.held)
                self.active, self.done = False, True
