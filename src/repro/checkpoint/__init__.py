from repro.checkpoint.ckpt import (CheckpointCorruptError,  # noqa: F401
                                   CheckpointStore, load_checkpoint,
                                   save_checkpoint)
