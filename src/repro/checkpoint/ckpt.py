"""Tree-structured npz checkpointing with atomic write and step tracking.

Trees are flattened to ``/``-joined key paths.  On restore, arrays are
re-laid-out to the requested shardings (device_put with NamedSharding),
which is the single-host analogue of a sharded restore.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        out[prefix + "__seq__"] = np.asarray(
            [len(tree), int(isinstance(tree, tuple))])
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    # rebuild nested dict first
    root = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__seq__" in node:
            n, is_tuple = int(node["__seq__"][0]), int(node["__seq__"][1])
            seq = [rebuild(node[str(i)]) for i in range(n)]
            return tuple(seq) if is_tuple else seq
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, tree, step: int = 0) -> str:
    """Atomically write ``tree`` (+ step) to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, tree))
    flat["__step__"] = np.asarray(step)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str, shardings=None):
    """Load (tree, step); optionally device_put leaves to ``shardings``."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__", 0))
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else a,
            tree, shardings)
    return tree, step
