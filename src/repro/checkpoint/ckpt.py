"""Tree-structured npz checkpointing with atomic write and step tracking.

Trees are flattened to ``/``-joined key paths.  On restore, arrays are
re-laid-out to the requested shardings (device_put with NamedSharding),
which is the single-host analogue of a sharded restore.
"""

from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        out[prefix + "__seq__"] = np.asarray(
            [len(tree), int(isinstance(tree, tuple))])
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    # rebuild nested dict first
    root = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__seq__" in node:
            n, is_tuple = int(node["__seq__"][0]), int(node["__seq__"][1])
            seq = [rebuild(node[str(i)]) for i in range(n)]
            return tuple(seq) if is_tuple else seq
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, tree, step: int = 0) -> str:
    """Atomically write ``tree`` (+ step) to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, tree))
    flat["__step__"] = np.asarray(step)
    # ml_dtypes leaves (bfloat16 / float8_*): np.savez demotes them to a
    # raw void dtype that np.load hands back as |V2 arrays jax rejects.
    # Ship the raw bits as same-width uints and record the true dtype, so
    # restore is bit-exact (save -> restore -> one-more-step parity,
    # tests/test_checkpoint.py).
    exotic = {}
    for k, a in list(flat.items()):
        if isinstance(a, np.ndarray) and a.dtype.isbuiltin != 1:
            exotic[k] = str(a.dtype)
            flat[k] = a.view(np.dtype(f"u{a.dtype.itemsize}"))
    flat["__dtypes__"] = np.frombuffer(
        json.dumps(exotic).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str, shardings=None):
    """Load (tree, step); optionally device_put leaves to ``shardings``."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__", 0))
    dtypes = flat.pop("__dtypes__", None)
    if dtypes is not None:
        # restore ml_dtypes leaves from their uint bit-carriers (bit-exact)
        for k, name in json.loads(bytes(dtypes.tobytes()).decode()).items():
            flat[k] = flat[k].view(np.dtype(name))
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else a,
            tree, shardings)
    return tree, step
