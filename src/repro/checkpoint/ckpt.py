"""Tree-structured npz checkpointing with atomic write, integrity
manifest, step tracking, and a retained-last-k store.

Trees are flattened to ``/``-joined key paths.  On restore, arrays are
re-laid-out to the requested shardings (device_put with NamedSharding),
which is the single-host analogue of a sharded restore.

Integrity: every save embeds a per-leaf crc32 manifest; ``load_checkpoint``
verifies it (and wraps unreadable/truncated files) into
:class:`CheckpointCorruptError` — a poisoned file produces one clean
diagnostic instead of a numerics mystery three subsystems later.
:class:`CheckpointStore` keeps the last k step-tagged checkpoints and
restores newest-first, falling back across corrupt files, which is what
the training guard rails roll back through (``repro.runtime.rollback``).
"""

from __future__ import annotations

import glob
import json
import os
import re
import tempfile
import zlib

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file failed integrity verification (truncated,
    unreadable, or with leaves whose bytes no longer match the manifest
    recorded at save time)."""


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        out[prefix + "__seq__"] = np.asarray(
            [len(tree), int(isinstance(tree, tuple))])
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    # rebuild nested dict first
    root = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if "__seq__" in node:
            n, is_tuple = int(node["__seq__"][0]), int(node["__seq__"][1])
            seq = [rebuild(node[str(i)]) for i in range(n)]
            return tuple(seq) if is_tuple else seq
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, tree, step: int = 0) -> str:
    """Atomically write ``tree`` (+ step) to ``path`` (.npz)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, tree))
    flat["__step__"] = np.asarray(step)
    # ml_dtypes leaves (bfloat16 / float8_*): np.savez demotes them to a
    # raw void dtype that np.load hands back as |V2 arrays jax rejects.
    # Ship the raw bits as same-width uints and record the true dtype, so
    # restore is bit-exact (save -> restore -> one-more-step parity,
    # tests/test_checkpoint.py).
    exotic = {}
    for k, a in list(flat.items()):
        if isinstance(a, np.ndarray) and a.dtype.isbuiltin != 1:
            exotic[k] = str(a.dtype)
            flat[k] = a.view(np.dtype(f"u{a.dtype.itemsize}"))
    flat["__dtypes__"] = np.frombuffer(
        json.dumps(exotic).encode(), dtype=np.uint8)
    # integrity manifest: crc32 of every leaf's bytes (computed over the
    # uint bit-carrier views, i.e. exactly the bytes that hit disk) so a
    # bit-flipped leaf is caught at restore with its key named
    manifest = {k: zlib.crc32(np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF
                for k, a in flat.items() if k != "__dtypes__"}
    flat["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str, shardings=None, verify: bool = True):
    """Load (tree, step); optionally device_put leaves to ``shardings``.

    ``verify=True`` (default) checks every leaf against the embedded
    crc32 manifest when one is present; mismatches — and truncated or
    otherwise unreadable files — raise :class:`CheckpointCorruptError`
    with the offending keys named, so callers (``CheckpointStore``) can
    fall back to an older retained checkpoint instead of silently
    training on garbage."""
    try:
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
    except Exception as e:  # noqa: BLE001 — zipfile/np errors vary by version
        raise CheckpointCorruptError(
            f"checkpoint {path} is unreadable (truncated or corrupt "
            f"container): {e!r}") from e
    manifest = flat.pop("__manifest__", None)
    if verify and manifest is not None:
        want = json.loads(bytes(manifest.tobytes()).decode())
        bad = [k for k, crc in want.items()
               if k not in flat
               or (zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
                   & 0xFFFFFFFF) != crc]
        bad += [k for k in flat if k != "__dtypes__" and k not in want]
        if bad:
            raise CheckpointCorruptError(
                f"checkpoint {path} failed integrity verification; "
                f"corrupt/missing leaves: {sorted(bad)[:8]}"
                + (" ..." if len(bad) > 8 else ""))
    step = int(flat.pop("__step__", 0))
    dtypes = flat.pop("__dtypes__", None)
    if dtypes is not None:
        # restore ml_dtypes leaves from their uint bit-carriers (bit-exact)
        for k, name in json.loads(bytes(dtypes.tobytes()).decode()).items():
            flat[k] = flat[k].view(np.dtype(name))
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else a,
            tree, shardings)
    return tree, step


class CheckpointStore:
    """Retained-last-k checkpoint directory with corruption fallback.

    Writes step-tagged siblings ``<prefix>.step<N>.npz`` next to (or
    under) ``base``, each via :func:`save_checkpoint` (atomic tmp +
    ``os.replace``, embedded crc manifest), pruning to the newest
    ``retain`` files.  :meth:`restore` walks newest -> oldest, skipping
    files that fail verification — one corrupt newest checkpoint costs
    one retained step of progress, never the run.

    ``base`` may be a directory (files land inside, prefix ``ckpt``) or
    a file path like ``out/run.npz`` (siblings ``out/run.step42.npz``).
    """

    def __init__(self, base: str, retain: int = 3, faults=None):
        if retain < 1:
            raise ValueError(f"retain must be >= 1, got {retain}")
        base = os.path.abspath(base)
        if os.path.isdir(base) or base.endswith(os.sep) or not \
                os.path.splitext(base)[1]:
            self.dir, self.prefix = base, "ckpt"
        else:
            self.dir = os.path.dirname(base)
            self.prefix = os.path.splitext(os.path.basename(base))[0]
        self.retain = int(retain)
        self.faults = faults              # FaultPlan (ckpt_bitflip) or None
        self.n_saves = 0

    def path_of(self, step: int) -> str:
        return os.path.join(self.dir, f"{self.prefix}.step{step:08d}.npz")

    def _step_of(self, path: str):
        m = re.search(r"\.step(\d+)\.npz$", path)
        return int(m.group(1)) if m else None

    def steps(self) -> list:
        """Retained steps on disk, oldest first."""
        pat = os.path.join(glob.escape(self.dir),
                           glob.escape(self.prefix) + ".step*.npz")
        return sorted(s for s in (self._step_of(p) for p in glob.glob(pat))
                      if s is not None)

    def save(self, tree, step: int) -> str:
        """Atomically write ``tree`` at ``step`` and prune beyond
        ``retain``.  The fault hook (``ckpt_bitflip``) corrupts the
        freshly written file in place — exercising exactly the restore
        fallback a real partial write would need."""
        path = save_checkpoint(self.path_of(step), tree, step)
        self.n_saves += 1
        if self.faults is not None and self.faults.ckpt_corrupts(
                self.n_saves):
            off = self.faults.flip_bit(path)
            print(f"[faults] ckpt_bitflip: corrupted byte {off} of "
                  f"{os.path.basename(path)}", flush=True)
        for s in self.steps()[:-self.retain]:
            os.unlink(self.path_of(s))
        return path

    def restore(self, shardings=None):
        """Newest verified checkpoint as ``(tree, step, path)``; corrupt
        files are reported and skipped.  Raises ``FileNotFoundError``
        when nothing is restorable."""
        errors = []
        for s in reversed(self.steps()):
            path = self.path_of(s)
            try:
                tree, step = load_checkpoint(path, shardings)
                return tree, step, path
            except CheckpointCorruptError as e:
                errors.append(str(e))
                print(f"[ckpt] {os.path.basename(path)} corrupt, falling "
                      f"back to previous retained checkpoint: {e}",
                      flush=True)
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.dir} "
            f"(prefix {self.prefix!r})"
            + (f"; {len(errors)} corrupt" if errors else ""))
