"""Collective accounting from lowered/compiled HLO text.

``cost_analysis()`` does not report collective traffic, so we parse the
(stable)HLO and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.  This powers both
the paper's communication-volume validation (Eq. 1/11/14) and the
roofline collective term.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.  %ag = bf16[16,4096,128]{...} all-gather(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\])"   # tuple or single shape
    r"[^=]*?\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    ops: list

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in an HLO dump.

    ``-start``/``-done`` async pairs are counted once (on the start op).
    """
    counts = defaultdict(int)
    nbytes = defaultdict(int)
    ops = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async completion: counted at -start
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            size = sum(_shape_bytes(d, s)
                       for d, s in _SHAPE_RE.findall(tuple_body))
            if "-start(" in line and kind in ("all-gather", "all-reduce",
                                              "reduce-scatter"):
                # start-op tuples carry (input, output); count output only.
                size //= 2
        else:
            size = _shape_bytes(dtype, dims)
        counts[kind] += 1
        nbytes[kind] += size
        ops.append((kind, size))
    return CollectiveStats(dict(counts), dict(nbytes), ops)


def collective_bytes(hlo_text: str) -> int:
    return parse_collectives(hlo_text).total_bytes
