"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * peak FLOP/s)
  memory     = HLO_bytes / (chips * HBM bandwidth)
  collective = collective_bytes / (chips * ICI link bandwidth)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals); collective bytes come from the HLO parse (per-device shapes summed
over ops, i.e. already per-chip traffic).
"""

from __future__ import annotations

from dataclasses import dataclass, field

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


@dataclass
class RooflineTerms:
    flops: float               # whole-program HLO FLOPs
    hbm_bytes: float           # whole-program HLO bytes accessed
    collective_bytes: float    # per-chip collective traffic
    chips: int
    model_flops: float = 0.0   # 6*N*D (dense) or 6*N_active*D (MoE)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # collective_bytes is per-chip already (parsed local shapes)
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "chips": self.chips,
        }


def roofline_terms(cost_analysis: dict, collective_bytes: float, chips: int,
                   model_flops: float = 0.0) -> RooflineTerms:
    ca = cost_analysis or {}
    return RooflineTerms(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        collective_bytes=float(collective_bytes),
        chips=chips,
        model_flops=model_flops,
    )
