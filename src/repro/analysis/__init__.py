from repro.analysis.hlo import collective_bytes, parse_collectives  # noqa: F401
from repro.analysis.roofline import roofline_terms  # noqa: F401
