"""Layer-wise cost accounting for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``lax.scan`` body once, not
times its trip count, so whole-program numbers for scanned-layer models
undercount FLOPs/bytes/collective traffic by ~n_layers.  This module
lowers ONE block per (run kind) with the production shardings, reads its
per-device cost, and sums n_r * cost_r over runs plus the embed/head/loss
cost — giving trip-count-correct roofline terms.

The full-program compile in dryrun.py remains the fits/coherence proof;
this is the accounting layer on top.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import parse_collectives
from repro.models import blocks as blk
from repro.models.layers import apply_norm, unembed
from repro.parallel.mesh import axis_size


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _cost(compiled):
    ca = compiled.cost_analysis()
    ca = ca if isinstance(ca, dict) else (ca[0] if ca else {})
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
            "coll_by_kind": coll.bytes_by_kind}


def _acc(total, cost, n):
    total["flops"] += n * cost["flops"]
    total["bytes"] += n * cost["bytes"]
    total["coll"] += n * cost["coll"]
    for k, v in cost["coll_by_kind"].items():
        total["coll_by_kind"][k] = total["coll_by_kind"].get(k, 0) + n * v


def layerwise_costs(model, cfg, mesh, dims, shape, *, kind: str,
                    schedule=None) -> dict:
    """kind: 'train' | 'prefill' | 'decode'. Returns per-device totals."""
    dtype = jnp.dtype(cfg.dtype)
    B = shape.global_batch
    L = shape.seq_len if kind != "decode" else 1
    M = cfg.d_model
    baxes = tuple(dims.batch_axes)
    nb = axis_size(mesh, baxes) if baxes else 1
    bax = baxes if (baxes and B % nb == 0) else None
    x_sds = jax.ShapeDtypeStruct((B, L, M), dtype)
    x_sh = NamedSharding(mesh, P(bax, None, None))

    ctx_sds = ctx_sh = None
    if model.has_cross:
        Lctx = cfg.n_ctx_tokens or cfg.encoder_seq
        ctx_sds = jax.ShapeDtypeStruct((B, Lctx, M), dtype)
        ctx_sh = NamedSharding(mesh, P(bax, None, None))

    total = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_by_kind": {}}
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def layer_shapes(run_params):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), run_params)

    for r, (kind_r, n_r) in enumerate(model.runs):
        specs = blk.block_specs(cfg, kind_r, mesh, dims)
        p_sh = _named(mesh, specs)
        p_sds = layer_shapes(p_shapes[f"run{r}"])
        needs_ctx = blk.base_kind(kind_r) in ("cross", "xdec")

        if kind == "decode":
            c_one = jax.eval_shape(
                lambda: blk.init_block_cache(cfg, kind_r, B,
                                             shape.seq_len, dtype))

            def c_spec(l):
                sp = [None] * l.ndim
                if l.ndim >= 1 and l.shape and l.shape[0] == B and bax:
                    sp[0] = bax
                return P(*sp)
            c_sh = jax.tree.map(lambda l: NamedSharding(mesh, c_spec(l)),
                                c_one)
            if needs_ctx:
                def fn(p, c, x, ctx):
                    kv = {"k": jnp.zeros(
                        (B, ctx.shape[1], cfg.n_kv_heads, cfg.hd), dtype),
                        "v": jnp.zeros(
                        (B, ctx.shape[1], cfg.n_kv_heads, cfg.hd), dtype)}
                    return blk.decode_block(p, cfg, kind_r, x, c,
                                            jnp.int32(1), mesh=mesh,
                                            dims=dims, ctx_kv=kv,
                                            schedule=schedule)
                lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, x_sh,
                                                    ctx_sh)).lower(
                    p_sds, c_one, x_sds, ctx_sds)
            else:
                def fn(p, c, x):
                    return blk.decode_block(p, cfg, kind_r, x, c,
                                            jnp.int32(1), mesh=mesh,
                                            dims=dims, schedule=schedule)
                lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, x_sh)
                                  ).lower(p_sds, c_one, x_sds)
        else:
            def fwd(p, x, ctx=None):
                y, aux = blk.apply_block(p, cfg, kind_r, x, mesh=mesh,
                                         dims=dims, ctx=ctx,
                                         schedule=schedule)
                return jnp.sum(y.astype(jnp.float32)) + aux["loss"]

            if kind == "train":
                def fn(p, x, ctx=None):
                    if ctx is not None:
                        return jax.grad(fwd, argnums=(0, 1))(p, x, ctx)
                    return jax.grad(lambda p_, x_: fwd(p_, x_),
                                    argnums=(0, 1))(p, x)
            else:
                fn = fwd
            if needs_ctx:
                lowered = jax.jit(fn, in_shardings=(p_sh, x_sh, ctx_sh)
                                  ).lower(p_sds, x_sds, ctx_sds)
            else:
                lowered = jax.jit(fn, in_shardings=(p_sh, x_sh)
                                  ).lower(p_sds, x_sds)

        _acc(total, _cost(lowered.compile()), n_r)

    # whisper encoder (runs once per step, fwd(+bwd in train))
    if cfg.arch_type == "audio" and cfg.encoder_layers:
        specs = blk.block_specs(cfg, "encoder", mesh, dims)
        p_sh = _named(mesh, specs)
        p_sds = layer_shapes(p_shapes["encoder"])
        enc_x = jax.ShapeDtypeStruct((B, cfg.encoder_seq, M), dtype)

        def enc_fwd(p, x):
            y, _ = blk.apply_block(p, cfg, "encoder", x, mesh=mesh,
                                   dims=dims)
            return jnp.sum(y.astype(jnp.float32))
        enc_fn = jax.grad(enc_fwd, argnums=(0, 1)) if kind == "train" \
            else enc_fwd
        lowered = jax.jit(enc_fn, in_shardings=(p_sh, x_sh)).lower(
            p_sds, enc_x)
        _acc(total, _cost(lowered.compile()), cfg.encoder_layers)

    # embed + final norm + head (+ CE/grad in train)
    from repro.models.layers import embed as embed_fn
    emb_specs = model.specs(mesh, dims)
    head_keys = [k for k in ("embed", "final_norm", "lm_head")
                 if k in p_shapes]
    hp_sds = {k: p_shapes[k] for k in head_keys}
    hp_sh = _named(mesh, {k: emb_specs[k] for k in head_keys})
    tok_sds = jax.ShapeDtypeStruct((B, L), jnp.int32)
    tok_sh = NamedSharding(mesh, P(bax, None))

    def head_loss(hp, tokens, labels):
        x = embed_fn(hp["embed"], tokens)
        x = apply_norm(hp["final_norm"], x, cfg.norm_eps)
        logits = (unembed(hp["embed"], x) if cfg.tie_embeddings
                  else x @ hp["lm_head"]["w"])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logp, labels[..., None], -1)
        return -jnp.mean(ll)

    if kind == "train":
        hfn = jax.grad(head_loss)
        lowered = jax.jit(hfn, in_shardings=(hp_sh, tok_sh, tok_sh)).lower(
            hp_sds, tok_sds, tok_sds)
    else:
        lowered = jax.jit(head_loss,
                          in_shardings=(hp_sh, tok_sh, tok_sh)).lower(
            hp_sds, tok_sds, tok_sds)
    _acc(total, _cost(lowered.compile()), 1)
    return total
