"""Train / prefill / serve step factories + the Trainer driver.

These are the functions the multi-pod dry-run lowers and the launchers
execute: ``train_step`` (fwd+bwd+AdamW), ``prefill_fn`` (full-sequence
forward) and ``serve_step`` (one token against a KV cache, with greedy
sampling) — plus the serving engine's two steps
(``make_engine_prefill_step`` / ``make_engine_decode_step``: paged-arena
scatter/gather through page tables, per-row positions, per-row
sampling).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.models.model import Model
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               opt_state_specs)
from repro.parallel.mesh import ParallelDims, axis_size


def named_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(model: Model, mesh, dims: ParallelDims, kind: str) -> dict:
    """PartitionSpecs for a batch dict (dim 0 over batch axes if divisible)."""
    axes = dims.dp + dims.ep if (dims.merged or not dims.esp) \
        else dims.dp + dims.ep + dims.esp

    def bspec(ndim, batch_size=None):
        ax = tuple(axes) if axes and (
            batch_size is None or batch_size % axis_size(mesh, axes) == 0) \
            else None
        return P(*((ax,) + (None,) * (ndim - 1)))
    return bspec


def cache_specs(model: Model, mesh, dims: ParallelDims, batch: int,
                max_len: int, *, seq_shard: bool = False):
    """Specs for the decode cache: batch dim (axis 1, after the layer-stack
    axis) sharded over the batch axes when divisible.

    ``seq_shard=True`` additionally shards attention K/V caches along the
    cache-length dim over the MP axes (context-parallel decode — the
    beyond-paper §Perf lever for collective/memory-bound decode shapes)."""
    axes = tuple(dims.batch_axes)
    n = axis_size(mesh, axes) if axes else 1
    mp = tuple(dims.mp)
    n_mp = axis_size(mesh, mp) if mp else 1
    shapes = jax.eval_shape(lambda: model.init_cache(batch, max_len))

    def rule(leaf):
        spec = [None] * leaf.ndim
        batch_shardable = batch % n == 0 and batch >= n and axes
        if leaf.ndim >= 2 and leaf.shape[1] == batch and batch_shardable:
            spec[1] = axes
        if seq_shard and mp and leaf.ndim == 5:
            # (layers, B, W, K, hd) attention cache: shard W over MP, and
            # when the batch axes are idle (B < their size, e.g. B=1
            # long-context serving) over those too — full context
            # parallelism across the pod (§Perf C6).
            waxes = mp if batch_shardable else tuple(axes) + tuple(mp)
            nw = axis_size(mesh, waxes)
            if leaf.shape[2] % nw == 0 and leaf.shape[2] >= 16 * nw:
                spec[2] = waxes
        return P(*spec)

    return jax.tree.map(rule, shapes)


# --- step factories -----------------------------------------------------------

def make_train_step(model: Model, mesh, dims: ParallelDims,
                    opt_cfg: AdamWConfig, schedule: Optional[str] = None):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, mesh=mesh, dims=dims,
                              schedule=schedule)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt_state2, om = adamw_update(params, grads, opt_state,
                                               opt_cfg)
        return params2, opt_state2, {**metrics, **om, "loss": loss}
    return train_step


def make_guarded_train_step(model: Model, mesh, dims: ParallelDims,
                            opt_cfg: AdamWConfig,
                            schedule: Optional[str] = None):
    """``make_train_step`` wrapped in guard rails, one compilation.

    Signature grows two traced scalars: ``lr_scale`` (the guard rails'
    dynamic LR backoff — multiplies the scheduled LR inside
    ``adamw_update``) and ``grad_fault`` (fault injection: the loss is
    seeded as ``loss * (1 + grad_fault)`` so every gradient comes out
    scaled by ``1 + grad_fault`` through the chain rule — one scalar
    multiply instead of a per-leaf pass; 0.0 is the exact identity and
    NaN/inf poisons every gradient).  The update is computed
    unconditionally and *discarded leaf-wise* when the loss or the
    global grad norm (already computed by AdamW for clipping — no second
    O(N) pass) goes non-finite: the ``where(finite, new, old)`` select
    runs *inside* ``adamw_update``'s per-leaf expression (where XLA
    fuses it with the update writes — a post-hoc tree-select measurably
    does not fuse and costs an extra memory pass), covering params, both
    moments, and the step counter, so a skipped step leaves the
    optimizer bit-identical to never having run.  Metrics gain a
    ``nonfinite`` flag the host-side policy (``runtime.guards``) folds
    into its skip/rollback decision.

    On the clean path (``lr_scale=1.0, grad_fault=0.0, finite=True``)
    every extra op is an IEEE identity, so outputs are bitwise equal to
    the unguarded step (tests/test_runtime.py locks this down).
    """
    def train_step(params, opt_state, batch, lr_scale, grad_fault):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, mesh=mesh, dims=dims,
                                       schedule=schedule)
            return loss * (1.0 + grad_fault), metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt_state2, om = adamw_update(params, grads, opt_state,
                                               opt_cfg, lr_scale=lr_scale,
                                               finite=jnp.isfinite(loss))
        finite = om.pop("finite")
        return params2, opt_state2, {**metrics, **om, "loss": loss,
                                     "nonfinite": ~finite}
    return train_step


def make_prefill_fn(model: Model, mesh, dims: ParallelDims,
                    schedule: Optional[str] = None):
    def prefill(params, batch):
        logits, aux = model.forward(params, batch, mesh=mesh, dims=dims,
                                    schedule=schedule)
        return logits

    return prefill


def make_serve_step(model: Model, mesh, dims: ParallelDims,
                    schedule: Optional[str] = None, greedy: bool = True):
    """Cross-attention archs (VLM/audio) take the per-request precomputed
    context K/V as a fourth argument (built once via model.ctx_kv)."""
    if model.has_cross:
        def serve_step(params, cache, batch, ctx_kv):
            logits, cache2 = model.decode_step(
                params, cache, batch, mesh=mesh, dims=dims,
                schedule=schedule, ctx_kv=ctx_kv)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok[:, None], cache2
        return serve_step

    def serve_step(params, cache, batch):
        logits, cache2 = model.decode_step(params, cache, batch,
                                           mesh=mesh, dims=dims,
                                           schedule=schedule)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache2

    return serve_step


def make_engine_prefill_step(model: Model, mesh, dims: ParallelDims,
                             schedule: Optional[str] = None):
    """The serving engine's prefill step over the PAGED block arena: one
    jitted call per admitted group (or per prefill chunk) — batched
    forward over each row's token span at ``starts``, written into the
    arena through ``tables``, then sampling at each row's own final
    valid position.  (Never a per-token loop: the regression test in
    tests/test_serve.py counts calls per group/chunk.)
    """
    def prefill_step(params, arena, tokens, starts, lens, tables, keys,
                     temps, topks):
        from repro.serve.sampler import sample   # lazy: no train<->serve cycle
        logits, arena2 = model.paged_step(
            params, arena,
            {"tokens": tokens, "starts": starts, "lens": lens,
             "tables": tables},
            mesh=mesh, dims=dims, schedule=schedule, infer=False)
        return sample(logits, keys, temps, topks), arena2

    return prefill_step


def make_engine_decode_step(model: Model, mesh, dims: ParallelDims,
                            schedule: Optional[str] = None,
                            with_aux: bool = False):
    """The serving engine's decode step over the PAGED block arena: one
    token per row at per-row positions (``steps`` is a (B,) vector, so
    requests at different depths batch together), reading/writing
    through fixed-shape ``(B, max_blocks)`` page tables — one
    compilation no matter how requests come and go.  Idle rows carry an
    all-null table: their writes land in the masked null page and their
    outputs are ignored.

    ``with_aux=True`` returns a third output — the (E,) per-expert
    routed-row count for this round ((0,) for dense stacks), feeding the
    engine's load EMA; the default keeps the two-output signature every
    existing caller jits.
    """
    def decode_step(params, arena, tokens, steps, tables, keys, temps,
                    topks):
        from repro.serve.sampler import sample
        out = model.paged_step(
            params, arena,
            {"tokens": tokens, "starts": steps,
             "lens": jnp.ones_like(steps), "tables": tables},
            mesh=mesh, dims=dims, schedule=schedule, infer=True,
            with_aux=with_aux)
        if with_aux:
            logits, arena2, aux = out
            return (sample(logits, keys, temps, topks), arena2,
                    aux["expert_load"])
        logits, arena2 = out
        return sample(logits, keys, temps, topks), arena2

    return decode_step


# --- driver ---------------------------------------------------------------------

@dataclass
class Trainer:
    """End-to-end training driver (used by examples/ and launch/train.py).

    ``guards`` (a :class:`repro.runtime.guards.GuardConfig`) opts into
    the fault-tolerant loop: the guarded step (skip-step + LR backoff),
    retained-checkpoint rollback through ``ckpt_path`` (kept to
    ``ckpt_retain`` files), the fp8 wire-overflow fallback, and the
    ``faults`` injection hooks.  With ``guards=None`` (default) setup
    and run are byte-for-byte the pre-existing paths.

    ``placement="auto"`` + ``rebalance_every=N`` opts into load-adaptive
    expert placement: the per-expert ``expert_load`` metric feeds a
    rolling EMA every step, and every N steps the skew-aware cost model
    scores a replication placement derived from the EMA against uniform
    (``autosched.maybe_rebalance``); on a win the placement is installed
    process-wide and the step re-jitted — the same cheap plan-swap
    mechanism as the fp8 wire fallback (the MoE config must route
    ``placement="auto"`` for the retrace to pick it up, which
    launch/train.py --placement auto arranges).
    """
    model: Model
    mesh: object
    dims: ParallelDims
    opt_cfg: AdamWConfig
    schedule: Optional[str] = None
    ckpt_path: Optional[str] = None
    guards: Optional[object] = None       # runtime.guards.GuardConfig
    faults: Optional[object] = None       # runtime.faults.FaultPlan
    ckpt_retain: int = 3
    placement: Optional[str] = None       # None (uniform) | "auto"
    rebalance_every: int = 0              # steps between rebalance checks
    rebalance_margin: float = 1.05        # modeled win required to swap

    def setup(self, key):
        m, mesh, dims = self.model, self.mesh, self.dims
        pspecs = m.specs(mesh, dims)
        p_sh = named_tree(mesh, pspecs)
        o_sh = named_tree(mesh, opt_state_specs(pspecs))
        params = jax.jit(m.init, out_shardings=p_sh)(key)
        opt_state = jax.jit(adamw_init, out_shardings=o_sh)(params)
        self._p_sh, self._o_sh = p_sh, o_sh
        from repro.core.placement import LoadEMA
        self.load_ema = LoadEMA()
        if self.guards is None:
            self._step_fn = make_train_step(m, mesh, dims, self.opt_cfg,
                                            self.schedule)
            self._step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        else:
            from repro.runtime import guards as guardlib
            self.guard_state = guardlib.GuardState(cfg=self.guards)
            guardlib.reset_fp8_counter()
            # monitor installed BEFORE the jit below traces, so fp8
            # encodes in this step's program carry the saturation counter
            guardlib.enable_fp8_monitor()
            if self.faults:
                factor = self.faults.fp8_sat_factor()
                if factor:
                    from repro.core import collectives
                    collectives.set_fp8_sat_injection(factor)
            self._step_fn = make_guarded_train_step(
                m, mesh, dims, self.opt_cfg, self.schedule)
            self._step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        from repro.core import autosched
        self._sched_keys = set(autosched.cache_info())
        return params, opt_state

    def _log_step0(self, metrics):
        # the first step traced the model: any schedule="auto" MoE
        # layers have made their (schedule, n_chunks) decisions now
        from repro.core import autosched
        summary = autosched.cache_summary(
            exclude=getattr(self, "_sched_keys", ()))
        if summary:
            print(summary, flush=True)
        el = metrics.get("expert_load")
        if el is not None and getattr(el, "ndim", 0) == 1 \
                and el.shape[-1]:
            vals = " ".join(f"{float(c):.0f}"
                            for c in jax.device_get(el))
            print(f"expert load (routed rows/expert, all layers): "
                  f"[{vals}]", flush=True)

    def _track_load(self, metrics):
        """Fold this step's per-expert routed-row counts into the
        rolling load EMA (host-side numpy; a no-op for dense models)."""
        el = metrics.get("expert_load")
        if el is not None and getattr(el, "ndim", 0) == 1 and el.shape[-1]:
            el = jax.device_get(el)
            if float(el.sum()) > 0:      # all-zero = no routing signal
                self.load_ema.update(el)

    def _emit_train_step(self, m):
        """One ``train_step`` event per history row (loss, grad norm,
        LR scale, imbalance, ...), plus the per-expert load vector when
        the EMA is live — the streaming twin of ``history``."""
        if not obs.enabled():
            return
        obs.emit("train_step", **m)
        if self.load_ema.ready:
            obs.emit("expert_load", step=m.get("step"),
                     load=[round(float(v), 3)
                           for v in self.load_ema.value()])

    def _maybe_rebalance(self, step):
        """Every ``rebalance_every`` steps, ask autosched whether a
        placement derived from the load EMA beats uniform under the
        skew-aware cost model; on a win, re-jit the step — the retrace
        resolves ``MoEConfig.placement == "auto"`` to the new placement
        (same cheap plan-swap mechanism as the fp8 wire fallback;
        params/opt state untouched)."""
        if self.placement != "auto" or not self.rebalance_every:
            return
        if step == 0 or step % self.rebalance_every or \
                not self.load_ema.ready:
            return
        from repro.core import autosched
        mcfg = getattr(self.model.cfg, "moe", None)
        if mcfg is None:
            return
        epoch = autosched.maybe_rebalance(
            self.load_ema.value(), margin=self.rebalance_margin,
            capacity_factor=mcfg.capacity_factor, top_k=mcfg.top_k)
        if epoch is None:
            return
        pl = autosched.current_placement()
        desc = pl.summary() if pl is not None else "uniform"
        self._step = jax.jit(self._step_fn, donate_argnums=(0, 1))
        obs.emit("train_rebalance", step=step, epoch=epoch,
                 placement=desc)
        print(f"step {step:5d}  REBALANCE -> placement epoch {epoch}: "
              f"{desc}", flush=True)

    def run(self, params, opt_state, data, n_steps: int, log_every: int = 10,
            ckpt_every: int = 0):
        if self.guards is not None:
            return self._run_guarded(params, opt_state, data, n_steps,
                                     log_every, ckpt_every)
        history = []
        bx = tuple(self.dims.batch_axes)
        t0 = time.perf_counter()
        for step in range(n_steps):
            if obs.enabled():
                obs.set_context(step=step)
            batch = data.sharded_batch(step, self.mesh, bx)
            params, opt_state, metrics = self._step(params, opt_state, batch)
            if step == 0:
                self._log_step0(metrics)
            self._track_load(metrics)
            self._maybe_rebalance(step)
            if step % log_every == 0 or step == n_steps - 1:
                # vector metrics (e.g. expert_load) are step-0 diagnostics,
                # not per-step scalars — keep the history float-only
                m = {k: float(v) for k, v in metrics.items()
                     if getattr(v, "ndim", 0) == 0}
                m["step"] = step
                m["wall_s"] = time.perf_counter() - t0
                if self.load_ema.ready:
                    m["load_imbalance"] = self.load_ema.imbalance()
                history.append(m)
                self._emit_train_step(m)
                print(f"step {step:5d}  loss {m['loss']:.4f}  "
                      f"ce {m['ce']:.4f}  gnorm {m['grad_norm']:.3f}  "
                      f"lr {m['lr']:.2e}", flush=True)
            if ckpt_every and self.ckpt_path and step and \
                    step % ckpt_every == 0:
                from repro.checkpoint import save_checkpoint
                save_checkpoint(self.ckpt_path,
                                {"params": params, "opt": opt_state}, step)
        return params, opt_state, history

    def _run_guarded(self, params, opt_state, data, n_steps: int,
                     log_every: int = 10, ckpt_every: int = 0):
        """The fault-tolerant loop: guarded step -> observe -> (apply |
        skip | rollback), snapshots on clean steps, fp8 fallback swap."""
        from repro.core import autosched
        from repro.runtime import guards as guardlib
        from repro.runtime.rollback import RollbackManager
        from repro.checkpoint.ckpt import CheckpointStore

        state = self.guard_state
        mgr = None
        if self.ckpt_path:
            store = CheckpointStore(self.ckpt_path, retain=self.ckpt_retain,
                                    faults=self.faults)
            mgr = RollbackManager(store, shardings={
                "params": self._p_sh, "opt_state": self._o_sh})
            # anchor before step 0: a streak in the first interval must
            # have somewhere to roll back to
            mgr.snapshot(params, opt_state, 0)

        history = []
        bx = tuple(self.dims.batch_axes)
        t0 = time.perf_counter()
        for step in range(n_steps):
            if obs.enabled():
                obs.set_context(step=step)
            batch = data.sharded_batch(step, self.mesh, bx)
            gf = self.faults.grad_fault(step) if self.faults else 0.0
            # donated-in params/opt_state come back as the OLD values on a
            # skipped step (the jitted where-select), so unconditional
            # reassignment is correct either way
            params, opt_state, metrics = self._step(
                params, opt_state, batch, state.lr_scale, gf)
            loss = float(metrics["loss"])
            action = state.observe(step, loss, bool(metrics["nonfinite"]))
            if step == 0:
                self._log_step0(metrics)
            self._track_load(metrics)
            self._maybe_rebalance(step)
            if action == guardlib.ROLLBACK:
                res = mgr.rollback(step) if mgr is not None else None
                if res is None:
                    # nothing restorable: limp on with the backed-off LR
                    state.record_rollback(step, None)
                    obs.emit("guard_rollback", restored_step=None,
                             loss=loss)
                else:
                    params, opt_state, rstep = res
                    state.record_rollback(step, rstep)
                    obs.emit("guard_rollback", restored_step=rstep,
                             loss=loss)
                    print(f"step {step:5d}  ROLLBACK -> re-anchored to "
                          f"checkpoint step {rstep}", flush=True)
            elif action == guardlib.SKIP:
                obs.emit("guard_skip", streak=state.streak,
                         lr_scale=state.lr_scale)
                print(f"step {step:5d}  SKIPPED (non-finite, streak "
                      f"{state.streak}, lr_scale {state.lr_scale:.3g})",
                      flush=True)
            if state.check_fp8():
                # fp8 wire overflow: clamp every wire decision up to the
                # fallback dtype and re-jit — the retrace re-consults
                # autosched.decide under the new ceiling (cheap plan
                # swap; params/opt state untouched)
                autosched.set_wire_ceiling(state.cfg.fp8_fallback)
                n = autosched.invalidate("fp8 wire overflow fallback")
                self._step = jax.jit(self._step_fn, donate_argnums=(0, 1))
                obs.emit("fp8_fallback",
                         sat_rate=guardlib.fp8_sat_rate(),
                         wire=state.cfg.fp8_fallback, invalidated=n)
                print(f"fp8 wire overflow (sat rate "
                      f"{guardlib.fp8_sat_rate():.2e}): falling back to "
                      f"{state.cfg.fp8_fallback} wire "
                      f"({n} cached decisions invalidated)", flush=True)
            if step % log_every == 0 or step == n_steps - 1:
                m = {k: float(v) for k, v in metrics.items()
                     if getattr(v, "ndim", 0) == 0}
                m["step"] = step
                m["wall_s"] = time.perf_counter() - t0
                m["lr_scale"] = state.lr_scale
                if self.load_ema.ready:
                    m["load_imbalance"] = self.load_ema.imbalance()
                history.append(m)
                self._emit_train_step(m)
                print(f"step {step:5d}  loss {m['loss']:.4f}  "
                      f"ce {m['ce']:.4f}  gnorm {m['grad_norm']:.3f}  "
                      f"lr {m['lr']:.2e}", flush=True)
            if mgr is not None and ckpt_every and step and \
                    step % ckpt_every == 0 and action == guardlib.OK:
                mgr.snapshot(params, opt_state, step)
        print(state.summary(), flush=True)
        return params, opt_state, history
