from repro.train.loop import (  # noqa: F401
    Trainer,
    cache_specs,
    make_engine_decode_step,
    make_engine_prefill_step,
    make_prefill_fn,
    make_serve_step,
    make_train_step,
    named_tree,
)
