"""Slot-based KV-cache pool: the serving engine's memory manager.

``model.init_cache(B, L)`` used to be allocated per monolithic batch and
thrown away with it.  The pool instead allocates it ONCE for
``max_batch`` rows and treats each row as a *slot* — one resident
request's KV state — with a free-list allocator, a request -> slot map,
and eviction on finish.  Slots are recycled without ever touching device
memory: a new occupant's batched prefill rewrites the row's K/V for its
prompt and resets the per-row ``pos`` map, so stale entries from the
previous occupant are unreachable (``pos = -1`` slots are masked out of
every decode-attention read).

This is the single-page special case of paged attention: one page per
request, page size ``max_len``.  The free list hands out the lowest
free slot first, which keeps allocation deterministic — a property the
engine's bitwise parity tests rely on.
"""

from __future__ import annotations

import heapq


class KVCachePool:
    """A ``max_batch``-row KV cache plus slot bookkeeping.

    The jax pytree itself lives in ``self.cache`` (every leaf has the
    layer-stacked layout ``(n_layers, max_batch, ...)``); the engine's
    jitted steps gather/scatter rows by slot index.  This class owns the
    *host-side* lifecycle only: which row belongs to which request.
    """

    def __init__(self, model, max_batch: int, max_len: int, dtype=None):
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.cache = model.init_cache(self.max_batch, self.max_len, dtype)
        import jax
        for leaf in jax.tree.leaves(self.cache):
            if leaf.ndim < 2 or leaf.shape[1] != self.max_batch:
                raise ValueError(
                    "KVCachePool needs every cache leaf shaped "
                    f"(layers, max_batch, ...); got {leaf.shape}")
        self._free = list(range(self.max_batch))   # min-heap of free slots
        heapq.heapify(self._free)
        self._slot_of: dict = {}                   # request id -> slot

    # --- admission control --------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return len(self._slot_of)

    def can_admit(self, n: int = 1) -> bool:
        return len(self._free) >= n

    # --- slot lifecycle -----------------------------------------------------
    def alloc(self, rid) -> int:
        """Assign the lowest free slot to request ``rid``."""
        if rid in self._slot_of:
            raise KeyError(f"request {rid!r} already holds slot "
                           f"{self._slot_of[rid]}")
        if not self._free:
            raise RuntimeError("KV-cache pool exhausted "
                               f"({self.max_batch} slots live)")
        slot = heapq.heappop(self._free)
        self._slot_of[rid] = slot
        return slot

    def release(self, rid) -> int:
        """Evict ``rid``'s slot back to the free list (finish/cancel)."""
        if rid not in self._slot_of:
            raise KeyError(f"request {rid!r} holds no slot")
        slot = self._slot_of.pop(rid)
        heapq.heappush(self._free, slot)
        return slot

    def slot_of(self, rid) -> int:
        return self._slot_of[rid]

    def live(self) -> dict:
        """Snapshot of the request -> slot map."""
        return dict(self._slot_of)
