"""Paged KV cache: block arena, page tables, and shared-prefix reuse.

PR 5's pool allocated one ``max_len`` slab per slot — its docstring
called it "the single-page special case of paged attention".  This is
the general case: ONE fixed arena of ``(n_layers, n_blocks + 1,
block_size, ...)`` KV pages for the engine's lifetime, carved into
``block_size``-token blocks that requests borrow on demand:

  * ``BlockAllocator`` — host-side block accounting.  Lowest-free-first
    allocation (deterministic, the same property the old slab free-list
    relied on), split refcounts (``req_rc`` live request holders vs
    ``cache_rc`` prefix-cache entries), and a reservation ledger so
    admission can promise a request its worst-case growth up front while
    the physical blocks are still handed out lazily.
  * ``PrefixCache`` — hash-keyed shared-prefix index.  After a prompt is
    prefilled, every full-block prefix of it is registered; a later
    request whose prompt starts with the same tokens *shares* those
    blocks (K/V computed once, refcount bumped) instead of re-prefilling
    them.  Sharing is restricted to immutable full blocks, so
    copy-on-write degenerates to share-only: a holder's first private
    position always lands in a fresh block of its own.  Evicting an
    entry whose blocks still have live request holders is refused.
  * ``KVCachePool`` — the arena + row slots + per-request block lists
    (page tables).  Logical position ``p`` of a request lives in its
    table's block ``p // block_size`` at offset ``p % block_size``; the
    jitted steps read the cache through a ``(B, max_blocks)`` gather of
    the table (``repro.models.attention.paged_attn``).

Physical block 0 is the NULL block: never allocated, the write/read
target for idle rows and unallocated table slots.  Its ``pos`` map stays
all ``-1``, so every gather through it is masked out of attention.
"""

from __future__ import annotations

import heapq
from collections import OrderedDict

#: Physical block id reserved as the masked-out null target.
NULL_BLOCK = 0


class BlockAllocator:
    """Host-side accounting for ``n_blocks`` usable KV pages (ids
    ``1..n_blocks``; 0 is the null block and is never handed out).

    Each block carries two refcounts: ``req_rc`` (live requests holding
    it in their page table) and ``cache_rc`` (prefix-cache entries
    covering it).  A block returns to the free heap exactly when both
    hit zero.  ``reserve``/``unreserve`` maintain a ledger of blocks
    promised to admitted requests but not yet physically allocated, so
    ``available`` is the admission-safe headroom.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError(f"need at least 1 block, got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self._free = list(range(1, self.n_blocks + 1))
        heapq.heapify(self._free)
        self._req_rc: dict = {}
        self._cache_rc: dict = {}
        self.reserved = 0
        # freed-page log: the engine drains this before each jitted step
        # and resets those pages' ``pos`` maps to -1 — a reused page must
        # not leak its previous occupant's valid positions into gathers
        self.freed_log: list = []

    # --- queries ------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        """Blocks currently held by at least one request or cache entry."""
        return self.n_blocks - len(self._free)

    @property
    def available(self) -> int:
        """Free blocks not yet promised to an admitted request."""
        return len(self._free) - self.reserved

    def req_rc(self, bid: int) -> int:
        return self._req_rc.get(bid, 0)

    def cache_rc(self, bid: int) -> int:
        return self._cache_rc.get(bid, 0)

    # --- lifecycle ----------------------------------------------------------
    def alloc(self) -> int:
        """Hand out the lowest free block with ``req_rc = 1``."""
        if not self._free:
            raise RuntimeError(
                f"block pool exhausted ({self.n_blocks} blocks live)")
        bid = heapq.heappop(self._free)
        self._req_rc[bid] = 1
        return bid

    def share(self, bid: int) -> None:
        """One more live request holds ``bid`` (prefix hit)."""
        if self._req_rc.get(bid, 0) + self._cache_rc.get(bid, 0) <= 0:
            raise KeyError(f"block {bid} is not live")
        self._req_rc[bid] = self._req_rc.get(bid, 0) + 1

    def release(self, bid: int) -> bool:
        """Drop one request hold; True if the block went back to the
        free heap (no remaining holders of either kind)."""
        rc = self._req_rc.get(bid, 0)
        if rc <= 0:
            raise KeyError(f"double free of block {bid}")
        self._req_rc[bid] = rc - 1
        return self._maybe_free(bid)

    def cache_hold(self, bid: int) -> None:
        if self._req_rc.get(bid, 0) + self._cache_rc.get(bid, 0) <= 0:
            raise KeyError(f"block {bid} is not live")
        self._cache_rc[bid] = self._cache_rc.get(bid, 0) + 1

    def cache_drop(self, bid: int) -> bool:
        rc = self._cache_rc.get(bid, 0)
        if rc <= 0:
            raise KeyError(f"cache double-drop of block {bid}")
        self._cache_rc[bid] = rc - 1
        return self._maybe_free(bid)

    def _maybe_free(self, bid: int) -> bool:
        if self._req_rc.get(bid, 0) == 0 and self._cache_rc.get(bid, 0) == 0:
            self._req_rc.pop(bid, None)
            self._cache_rc.pop(bid, None)
            heapq.heappush(self._free, bid)
            self.freed_log.append(bid)
            return True
        return False

    # --- reservation ledger -------------------------------------------------
    def reserve(self, n: int) -> None:
        if n < 0:
            raise ValueError(f"cannot reserve {n} blocks")
        self.reserved += n

    def unreserve(self, n: int) -> None:
        if n < 0 or n > self.reserved:
            raise ValueError(
                f"unreserve({n}) with only {self.reserved} reserved")
        self.reserved -= n

    def check(self) -> None:
        """Invariant audit (the property tests call this after every op):
        every id is exactly free xor refcounted, counts conserve."""
        free = set(self._free)
        assert len(free) == len(self._free), "free heap holds duplicates"
        for bid in free:
            assert 1 <= bid <= self.n_blocks, f"foreign block {bid} freed"
            assert self._req_rc.get(bid, 0) == 0, f"block {bid} free+held"
            assert self._cache_rc.get(bid, 0) == 0, f"block {bid} free+cached"
        live = {b for b, rc in self._req_rc.items() if rc > 0} | \
               {b for b, rc in self._cache_rc.items() if rc > 0}
        assert not (live & free), "block both live and free"
        assert len(live) + len(free) == self.n_blocks, "blocks leaked"
        assert 0 <= self.reserved, "negative reservation ledger"


class PrefixCache:
    """Hash-keyed index of computed full-block prompt prefixes.

    Keys are token tuples whose length is a multiple of ``block_size``;
    the value is the tuple of physical blocks holding their K/V.  Every
    entry holds a ``cache_rc`` on each of its blocks, so the K/V survive
    the computing request's release.  Entries are kept in LRU order;
    ``evict`` refuses while any of the entry's blocks has a live request
    holder, and ``evict_lru`` (allocation-pressure path) only ever takes
    entries with no live holders.
    """

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self.block_size = alloc.block_size
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self):
        return list(self._entries)

    def lookup(self, prompt, max_blocks: int):
        """Longest cached prefix of ``prompt``, at most ``max_blocks``
        blocks.  Returns the block-id tuple (possibly empty).  Does NOT
        take references — the pool shares the blocks on admission."""
        prompt = tuple(prompt)
        best = ()
        for i in range(min(len(prompt) // self.block_size, max_blocks), 0, -1):
            key = prompt[:i * self.block_size]
            hit = self._entries.get(key)
            if hit is not None:
                self._entries.move_to_end(key)
                best = hit
                break
        if best:
            self.hits += 1
        else:
            self.misses += 1
        return best

    def insert(self, prompt, blocks) -> int:
        """Register every full-block prefix of ``prompt`` backed by
        ``blocks`` (the holder's leading page-table entries).  Returns
        the number of NEW entries."""
        prompt, blocks = tuple(prompt), tuple(blocks)
        n_full = min(len(prompt) // self.block_size, len(blocks))
        added = 0
        for i in range(1, n_full + 1):
            key = prompt[:i * self.block_size]
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            entry = blocks[:i]
            for bid in entry:
                self.alloc.cache_hold(bid)
            self._entries[key] = entry
            added += 1
        return added

    def holders(self, key) -> int:
        """Live request holds on the entry's last (deepest) block — the
        number of requests still reading through this prefix."""
        entry = self._entries[tuple(key)]
        return max(self.alloc.req_rc(b) for b in entry)

    def evict(self, key) -> int:
        """Drop one entry; refused (RuntimeError) while any of its
        blocks is held by a live request.  Returns blocks freed."""
        key = tuple(key)
        entry = self._entries.get(key)
        if entry is None:
            raise KeyError("prefix not cached")
        held = [b for b in entry if self.alloc.req_rc(b) > 0]
        if held:
            raise RuntimeError(
                f"prefix eviction refused: blocks {held} still held by "
                "live requests")
        del self._entries[key]
        return sum(self.alloc.cache_drop(b) for b in entry)

    def evict_lru(self, n_needed: int) -> int:
        """Free >= ``n_needed`` blocks by evicting oldest entries with no
        live holders.  Returns blocks actually freed (may fall short)."""
        freed = 0
        for key in list(self._entries):
            if freed >= n_needed:
                break
            entry = self._entries[key]
            if any(self.alloc.req_rc(b) > 0 for b in entry):
                continue
            freed += self.evict(key)
        return freed

    @property
    def evictable_blocks(self) -> int:
        """Blocks that evicting every holder-free entry would free.

        A block frees only when its ``cache_rc`` hits zero, i.e. every
        entry covering it is gone — and ``evict_lru`` refuses any entry
        with a live-held block ANYWHERE in it.  So a block counts only
        if no covering entry is pinned; counting per-block ``req_rc``
        alone overstates headroom and lets ``can_admit`` admit requests
        that then crash in ``alloc``.
        """
        pinned: set = set()
        for entry in self._entries.values():
            if any(self.alloc.req_rc(b) > 0 for b in entry):
                pinned.update(entry)
        seen, n = set(), 0
        for entry in self._entries.values():
            for b in entry:
                if b in seen or b in pinned:
                    continue
                seen.add(b)
                n += 1
        return n


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class KVCachePool:
    """Paged KV-cache pool: fixed block arena + page-table bookkeeping.

    The jax pytree lives in ``self.cache``; every leaf has the
    layer-stacked paged layout ``(n_layers, n_blocks + 1, block_size,
    ...)`` (slot 0 = null block).  ``max_batch`` decode rows and
    ``max_len`` logical tokens per request are unchanged from the slab
    pool; ``max_len`` must divide into whole blocks (checked HERE, at
    construction — not on first alloc).  The default arena
    (``n_blocks = max_batch * max_len / block_size``) has exactly the
    slab pool's capacity; pass a smaller ``n_blocks`` to overcommit
    (admission then reasons about free *blocks*, not free rows).
    """

    def __init__(self, model, max_batch: int, max_len: int, dtype=None, *,
                 block_size: int = 32, n_blocks=None,
                 prefix_cache: bool = True):
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.block_size = int(min(block_size, self.max_len))
        if self.max_len % self.block_size:
            raise ValueError(
                f"max_len {self.max_len} is not divisible by block_size "
                f"{self.block_size}")
        self.max_blocks = self.max_len // self.block_size
        if n_blocks is None:
            n_blocks = self.max_batch * self.max_blocks
        self.n_blocks = int(n_blocks)
        # +1: physical slot 0 is the never-allocated null block
        self.cache = model.init_cache(self.n_blocks + 1, self.block_size,
                                      dtype)
        self._validate_leaves()
        self.alloc_blocks = BlockAllocator(self.n_blocks, self.block_size)
        self.prefix = PrefixCache(self.alloc_blocks) if prefix_cache else None
        self._row_free = list(range(self.max_batch))
        heapq.heapify(self._row_free)
        self._row_of: dict = {}       # rid -> decode row
        self._table: dict = {}        # rid -> [block ids]
        self._shared: dict = {}       # rid -> leading shared block count
        self._resv: dict = {}         # rid -> blocks still reserved

    def _validate_leaves(self):
        """Leaf-shape audit — runs for EVERY construction, including
        dtype-overridden caches (the old pool only exercised the default
        path in tests)."""
        import jax
        want = self.n_blocks + 1
        for leaf in jax.tree.leaves(self.cache):
            if leaf.ndim < 2 or leaf.shape[1] != want:
                raise ValueError(
                    "KVCachePool needs every cache leaf shaped "
                    f"(layers, n_blocks + 1, ...) = (*, {want}, ...); "
                    f"got {leaf.shape}")
            if leaf.ndim >= 3 and leaf.shape[2] != self.block_size:
                raise ValueError(
                    f"cache leaf {leaf.shape} does not use block_size "
                    f"{self.block_size} pages")

    # --- admission control ---------------------------------------------------
    @property
    def n_free(self) -> int:
        """Free decode rows (the slab pool's admission quantity)."""
        return len(self._row_free)

    @property
    def n_live(self) -> int:
        return len(self._row_of)

    @property
    def n_free_blocks(self) -> int:
        return self.alloc_blocks.n_free

    def occupancy(self) -> float:
        """Fraction of arena blocks currently live."""
        return self.alloc_blocks.n_live / max(self.n_blocks, 1)

    def blocks_needed(self, prompt_len: int, max_new: int) -> int:
        return _ceil_div(min(prompt_len + max_new, self.max_len),
                         self.block_size)

    def can_admit(self, prompt_len: int = 1, max_new: int = 0) -> bool:
        """A free row AND enough unpromised blocks for the request's
        worst case (free + evictable holder-free prefix entries,
        minus what other live requests may still claim)."""
        if not self._row_free:
            return False
        need = self.blocks_needed(prompt_len, max_new)
        head = self.alloc_blocks.available
        if self.prefix is not None:
            head += self.prefix.evictable_blocks
        return head >= need

    # --- request lifecycle ---------------------------------------------------
    def alloc(self, rid, prompt=(), max_new: int = 0):
        """Admit ``rid``: assign the lowest free row, share the longest
        cached prefix of ``prompt`` (full blocks only, capped so at
        least one prompt token is left to prefill), and reserve the
        request's worst-case remaining block growth.

        Returns ``(row, n_shared_tokens)``.
        """
        if rid in self._row_of:
            raise KeyError(f"request {rid!r} already holds row "
                           f"{self._row_of[rid]}")
        if not self._row_free:
            raise RuntimeError(
                f"KV-cache pool exhausted ({self.max_batch} rows live)")
        prompt = tuple(prompt)
        need = self.blocks_needed(max(len(prompt), 1), max_new)
        shared: tuple = ()
        if self.prefix is not None and len(prompt) > 1:
            # cap: the final prompt token is always prefilled, so there
            # is a position to sample the first generated token from
            shared = self.prefix.lookup(prompt,
                                        (len(prompt) - 1) // self.block_size)
        # can_admit's exact headroom bound, measured BEFORE this request
        # pins anything.  Sharing-path success implies it (sharing m
        # blocks removes >= m from the evictable count), so when it
        # fails we can refuse up front without evicting anything.
        evictable0 = 0 if self.prefix is None else \
            self.prefix.evictable_blocks
        if self.alloc_blocks.available + evictable0 < need:
            raise RuntimeError(
                f"KV-cache pool exhausted: request needs {need} blocks, "
                f"{self.alloc_blocks.available} available "
                f"(+{evictable0} evictable)")
        # Hold the matched blocks BEFORE any eviction: evict_lru skips
        # entries with live request holders, so this pins the hit —
        # otherwise pressure-eviction below could free the (holder-free)
        # entry we just matched and share() would KeyError.
        for bid in shared:
            self.alloc_blocks.share(bid)
        private_need = need - len(shared)
        if self.alloc_blocks.available < private_need:
            self.prefix.evict_lru(
                private_need - self.alloc_blocks.available)
            # re-check available alone: freed blocks already returned to
            # the free heap, adding evict_lru's count would double-count
            if self.alloc_blocks.available < private_need:
                # Sharing pinned every entry touching the matched blocks
                # (longer prefixes of the same chain), which may be the
                # only remaining evictable headroom.  Give the hit back
                # and retry share-free — the feasibility bound above
                # guarantees this path succeeds, so alloc admits in
                # exactly the states can_admit approves.
                for bid in shared:
                    self.alloc_blocks.release(bid)
                if shared:
                    self.prefix.hits -= 1
                    self.prefix.misses += 1
                shared = ()
                private_need = need
                self.prefix.evict_lru(
                    need - self.alloc_blocks.available)
                if self.alloc_blocks.available < need:
                    raise RuntimeError(
                        f"KV-cache pool exhausted: request needs "
                        f"{need} blocks, "
                        f"{self.alloc_blocks.available} available")
        self.alloc_blocks.reserve(private_need)
        row = heapq.heappop(self._row_free)
        self._row_of[rid] = row
        self._table[rid] = list(shared)
        self._shared[rid] = len(shared)
        self._resv[rid] = private_need
        return row, len(shared) * self.block_size

    def ensure(self, rid, pos: int) -> None:
        """Grow ``rid``'s page table (on demand, from its reservation)
        until logical position ``pos`` has a physical block."""
        if pos >= self.max_len:
            raise ValueError(f"position {pos} beyond max_len {self.max_len}")
        table = self._table[rid]
        while len(table) * self.block_size <= pos:
            if self._resv[rid] <= 0:
                raise RuntimeError(
                    f"request {rid!r} grew past its reservation")
            if self.alloc_blocks.n_free == 0 and self.prefix is not None:
                self.prefix.evict_lru(1)
            table.append(self.alloc_blocks.alloc())
            self._resv[rid] -= 1
            self.alloc_blocks.unreserve(1)

    def commit_prefix(self, rid, prompt) -> int:
        """Register ``rid``'s freshly prefilled prompt (full blocks
        only) in the prefix cache.  Returns new entries added."""
        if self.prefix is None:
            return 0
        prompt = tuple(prompt)
        n_full = min(len(prompt) // self.block_size,
                     len(self._table[rid]))
        if n_full == 0:
            return 0
        return self.prefix.insert(prompt, self._table[rid][:n_full])

    def release(self, rid) -> int:
        """Finish/cancel: free the row, drop one hold on every block of
        the page table, and return the unused reservation."""
        if rid not in self._row_of:
            raise KeyError(f"request {rid!r} holds no row")
        row = self._row_of.pop(rid)
        heapq.heappush(self._row_free, row)
        for bid in self._table.pop(rid):
            self.alloc_blocks.release(bid)
        self.alloc_blocks.unreserve(self._resv.pop(rid))
        self._shared.pop(rid, None)
        return row

    def drain_freed(self) -> list:
        """Pages freed since the last drain (engine: reset their ``pos``
        maps before the next jitted step touches the arena)."""
        freed, self.alloc_blocks.freed_log = \
            self.alloc_blocks.freed_log, []
        return freed

    # --- views ---------------------------------------------------------------
    def row_of(self, rid) -> int:
        return self._row_of[rid]

    # old slab-pool name, kept for API continuity
    slot_of = row_of

    def table_of(self, rid) -> list:
        return list(self._table[rid])

    def shared_blocks(self, rid) -> int:
        return self._shared.get(rid, 0)

    def live(self) -> dict:
        """Snapshot of the request -> row map."""
        return dict(self._row_of)

    def block_tables(self):
        """The jitted steps' ``(max_batch, max_blocks)`` int32 gather
        table: row r's logical block i -> physical arena slot.  Idle
        rows and unallocated slots point at the null block (0)."""
        import numpy as np
        tables = np.zeros((self.max_batch, self.max_blocks), np.int32)
        for rid, row in self._row_of.items():
            t = self._table[rid]
            tables[row, :len(t)] = t
        return tables
