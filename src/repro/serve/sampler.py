"""Token sampling for the serving engine: greedy / temperature / top-k
behind one batched, jit-friendly interface.

The engine serves heterogeneous requests from ONE jitted step, so the
sampler is vectorized over rows with *per-row* parameters instead of
per-request python branches: ``temperature <= 0`` rows take the greedy
argmax (bitwise the classic ``make_serve_step`` pick), ``top_k == 0``
rows sample the full distribution, and ``top_k > 0`` rows are truncated
to their k best logits before the Gumbel draw.  Keys are per-row raw
``(seed, position)`` uint32 pairs — a request's sample stream depends
only on its own seed and position, never on which batch rows it happens
to share a step with.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

#: Static bound for per-row top-k truncation (keeps ``lax.top_k``'s k a
#: compile-time constant while ``top_k`` itself stays a traced per-row
#: value).  Requests may ask for any ``top_k <= TOPK_MAX``.
TOPK_MAX = 64


@dataclass(frozen=True)
class SamplerConfig:
    """Per-request sampling parameters.

    ``temperature <= 0`` means greedy (argmax; ``top_k``/``seed`` are
    ignored).  ``top_k == 0`` samples the full softmax at the given
    temperature; ``1 <= top_k <= TOPK_MAX`` truncates to the k largest
    logits first.
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.top_k < 0 or self.top_k > TOPK_MAX:
            raise ValueError(f"top_k must be in [0, {TOPK_MAX}], "
                             f"got {self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sample(logits, keys, temperature, top_k):
    """Draw one token per row.  All arguments are batched:

    ``logits`` (B, V) float; ``keys`` (B, 2) uint32 raw PRNG key data
    (the engine packs ``(seed, position)``); ``temperature`` (B,) float;
    ``top_k`` (B,) int32.  Returns (B,) int32 token ids.
    """
    lg = logits.astype(jnp.float32)
    B, V = lg.shape
    greedy = jnp.argmax(lg, axis=-1)

    kmax = min(TOPK_MAX, V)
    topv, _ = jax.lax.top_k(lg, kmax)                       # (B, kmax)
    kth = jnp.take_along_axis(
        topv, jnp.clip(top_k - 1, 0, kmax - 1)[:, None], axis=1)
    truncated = (top_k > 0)[:, None] & (lg < kth)
    scaled = jnp.where(truncated, -jnp.inf,
                       lg / jnp.maximum(temperature, 1e-6)[:, None])
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (V,)))(keys)
    sampled = jnp.argmax(scaled + gumbel, axis=-1)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)
