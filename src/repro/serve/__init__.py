"""Continuous-batching MoE serving: engine, KV-slot pool, sampling.

See docs/serving.md for the architecture walkthrough.
"""

from repro.serve.engine import (  # noqa: F401
    Completion,
    Engine,
    Request,
    latency_stats,
    suggest_max_batch,
)
from repro.serve.kvcache import KVCachePool  # noqa: F401
from repro.serve.sampler import SamplerConfig, sample  # noqa: F401
