"""Continuous-batching MoE serving engine over a PAGED KV cache.

``launch/serve.py`` used to drive one fixed batch token-by-token —
prompt positions included — with every decode step running the
*training*-shaped MoE schedules.  The engine replaces that with a
request lifecycle:

  submit -> queue -> admit (page-table rows + shared-prefix reuse)
         -> prefill (one-shot or fixed-size CHUNKS interleaved with
            decode rounds)
         -> decode rounds (continuous batch over the whole row pool)
         -> finish (EOS / token budget) -> release pages -> detokenize

The KV memory model is paged (PR 7): one fixed block arena for the
engine's lifetime, per-request page tables grown on demand, and a
refcounted shared-prefix cache — a system prompt's full blocks are
computed once and shared across requests (``stats["prefix_hits"]`` /
``stats["prefix_tokens"]``).  Admission reasons about free BLOCKS (worst
case prompt + budget), not free rows, and reserves them up front so a
running request can never deadlock mid-decode.

Scheduling: each ``step()`` either advances prefill (one jitted call
over the waiting group's next chunk — never ``prompt_len`` calls) or
runs one decode round over all ``max_batch`` rows at per-row positions.
With ``prefill_chunk > 0`` a long prompt is split into fixed-size
chunks and ALTERNATES with decode rounds, so one long prompt cannot
stall the pool's decode p99.  Requests join and leave the decode batch
mid-run; idle rows ride along with all-null page tables, which keeps
the decode step's shapes FIXED — one compilation, no matter how
requests come and go.  Prefill chunk shapes are bucketed (power of
two, capped by ``prefill_chunk``), bounding compilations at
log(max_len) x group size.

Every phase — one-shot prefill, chunked prefill, prefix-hit suffix
prefill, decode — runs through ONE paged primitive
(``models.attention.paged_chunk_attn``), whose gather lays position p
at index p (the slab layout).  That is what keeps the PR 5 bitwise
guarantees: paged-vs-slab, chunked-vs-one-shot and hit-vs-cold token
streams are bit-identical (tests/helpers/run_paged_parity.py).

MoE layers keep their decode-DEDICATED schedule decisions: decode
rounds mark ``apply_moe`` ``infer=True`` (own autosched cache class,
decode-widened ``s1d`` grid, n_chunks pinned to 1, drop-free capacity)
while prefill chunks stay ``infer=False`` — a row's output is
independent of its batch mates, which is what makes continuous
batching safe for routed experts.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np

from repro import obs
from repro.models import blocks as blk
from repro.obs.registry import Registry, quantile
from repro.serve.kvcache import KVCachePool
from repro.serve.sampler import SamplerConfig
from repro.train.loop import (make_engine_decode_step,
                              make_engine_prefill_step)


@dataclass(frozen=True)
class Request:
    """One generation request: prompt token ids + budget + sampling.

    ``deadline`` > 0 is a per-request wall-clock budget in seconds
    (measured from submit/arrival); a request still running past it is
    cancelled mid-flight — its KV pages go back to the arena and the
    partial generation comes back with ``status="expired"``.
    """

    rid: int
    prompt: tuple                      # token ids, len >= 1
    max_new_tokens: int = 16
    sampler: SamplerConfig = SamplerConfig()
    arrival: float = 0.0               # seconds after run start
    deadline: float = 0.0              # seconds; 0 = none


@dataclass
class Completion:
    """A finished request: generated ids, text, and latency breakdown.

    ``status``: ``"ok"`` (normal finish), ``"shed"`` (rejected at
    admission — see ``reason``), ``"expired"`` (deadline blown
    mid-flight), or ``"evicted"`` (decode watchdog).  Non-ok completions
    carry whatever tokens were generated before cancellation.
    """

    rid: int
    prompt: tuple
    tokens: list
    text: str
    timing: dict = field(default_factory=dict)   # ttft / latency seconds
    status: str = "ok"
    reason: str = ""


class _State:
    __slots__ = ("req", "slot", "pos", "fill_pos", "last_tok", "generated",
                 "t_submit", "t_admit", "t_first", "t_done", "t_deadline",
                 "stall_rounds", "delay_left", "ticks_active")

    def __init__(self, req, slot, fill_pos, t_submit, t_admit):
        self.req, self.slot = req, slot
        self.pos = len(req.prompt)     # next absolute position to decode
        self.fill_pos = fill_pos       # next prompt position to prefill
        self.last_tok = None
        self.generated = []
        self.t_submit, self.t_admit = t_submit, t_admit
        self.t_first = self.t_done = None
        self.t_deadline = (t_submit + req.deadline) if req.deadline else None
        self.stall_rounds = 0          # decode rounds without advancing
        self.delay_left = 0            # fault: rounds to sit out of decode
        self.ticks_active = 0          # engine ticks since admission


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class Engine:
    """Continuous-batching serving engine over a paged KV-block pool.

    ``max_batch`` is the decode batch (= concurrent rows); ``max_len``
    the per-request KV length (prompt + generation budget must fit).
    ``block_size`` sets the KV page granularity and ``n_blocks`` the
    arena size (default: slab-equivalent ``max_batch * max_len /
    block_size``); ``prefix_cache`` enables shared-prefix reuse and
    ``prefill_chunk`` > 0 splits prompts into chunks of that many
    tokens, alternating with decode rounds.  ``prefill_batch`` caps how
    many admissions share one prefill call (1 = each request prefills
    alone, which makes a request's prefill bitwise independent of its
    queue mates).  ``schedule`` forces one MoE schedule for prefill AND
    decode; None lets each phase's autosched decision stand.

    ``placement="auto"`` + ``rebalance_every=N`` opts into load-adaptive
    expert placement on the serving path: the decode step returns the
    per-expert routed-row counts (fed into a rolling EMA and surfaced as
    ``stats["per_expert_load"]``), and every N decode rounds the
    skew-aware cost model scores a replication placement derived from
    the EMA against uniform — on a win the prefill and decode steps are
    re-jitted, picking up the new placement (the MoE config must route
    ``placement="auto"``, which launch/serve.py --placement auto
    arranges).
    """

    def __init__(self, model, mesh, dims, *, max_batch: int = 8,
                 max_len: int = 256, schedule=None, prefill_batch: int = 1,
                 eos_token=None, detokenize=None, block_size: int = 16,
                 n_blocks=None, prefix_cache: bool = True,
                 prefill_chunk: int = 0, queue_slo: float = 0.0,
                 watchdog_rounds: int = 0, faults=None,
                 placement=None, rebalance_every: int = 0,
                 rebalance_margin: float = 1.05):
        cfg = model.cfg
        bad = [k for k, _ in model.runs
               if blk.base_kind(k) not in ("dense", "moe")]
        if bad:
            raise NotImplementedError(
                f"Engine supports dense/moe decoder stacks; {cfg.name} "
                f"has block kinds {bad}")
        if cfg.attn_window is not None and cfg.attn_window < max_len:
            raise NotImplementedError(
                "Engine needs full-length KV rows (attn_window "
                f"{cfg.attn_window} < max_len {max_len})")
        self.model, self.mesh, self.dims = model, mesh, dims
        self.max_batch, self.max_len = int(max_batch), int(max_len)
        self.prefill_batch = max(int(prefill_batch), 1)
        self.prefill_chunk = max(int(prefill_chunk), 0)
        self.eos_token = eos_token
        self.detokenize = detokenize or (
            lambda ids: " ".join(str(t) for t in ids))
        self.pool = KVCachePool(model, self.max_batch, self.max_len,
                                block_size=block_size, n_blocks=n_blocks,
                                prefix_cache=prefix_cache)
        self.block_size = self.pool.block_size
        self.placement = placement            # None (uniform) | "auto"
        self.rebalance_every = int(rebalance_every)
        self.rebalance_margin = float(rebalance_margin)
        self._track_load = placement == "auto" or self.rebalance_every > 0
        from repro.core.placement import LoadEMA
        self.load_ema = LoadEMA()
        self._schedule = schedule
        # donate the arena: each step's input cache is dead once the
        # updated one lands, so XLA aliases them in place instead of
        # copying the whole block arena every generated token
        self._jit_steps()
        self.queue: deque = deque()
        self._run_t0 = None             # run() wall-clock origin
        self.filling: list = []         # admitted, prefill in progress
        self.active: dict = {}          # slot -> _State (decoding)
        self._fill_turn = True          # chunked prefill <-> decode fairness
        self.stats = {"prefill_calls": 0, "decode_calls": 0,
                      "prefill_tokens": 0, "decode_tokens": 0,
                      "max_active": 0, "admitted": 0,
                      "prefix_hits": 0, "prefix_tokens": 0,
                      "peak_blocks": 0, "shed": 0, "shed_blocks": 0,
                      "shed_queue": 0, "expired": 0, "evicted": 0}
        self._rid = 0
        # request-latency rollup instruments (one quantile codepath:
        # the same obs histogram the guard rails and latency_stats use)
        self.registry = Registry()
        # --- robustness knobs (all off by default) ---
        self.queue_slo = float(queue_slo)        # max queue wait, seconds
        self.watchdog_rounds = int(watchdog_rounds)
        self.faults = faults                     # runtime.faults.FaultPlan
        self._starve = None
        if faults is not None:
            sv = faults.alloc_starve()
            if sv is not None:
                from repro.runtime.faults import StarveState
                self._starve = StarveState(*sv)
        self._tick = 0                           # engine ticks (step calls)
        self._cancelled: list = []               # Completions pending return

    # --- request intake -----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               sampler: SamplerConfig = SamplerConfig(),
               arrival: float = 0.0, rid=None, deadline: float = 0.0) -> int:
        """Queue one request (admission control: prompt + budget must fit
        ``max_len`` logical positions).  Returns the request id."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len {self.max_len}")
        if rid is None:
            rid, self._rid = self._rid, self._rid + 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens), sampler=sampler,
                      arrival=float(arrival), deadline=float(deadline))
        self.queue.append((req, time.perf_counter()))
        obs.emit("req_queued", rid=rid, prompt_len=len(prompt),
                 max_new_tokens=int(max_new_tokens))
        return rid

    # --- load shedding / cancellation ---------------------------------------
    def _shed(self, req, t_submit, reason: str) -> None:
        """Reject a queued request at admission with a reason (surfaced
        in ``stats`` and as a ``status="shed"`` completion)."""
        self.stats["shed"] += 1
        self.stats["shed_blocks" if reason.startswith("blocks")
                   else "shed_queue"] += 1
        t = time.perf_counter()
        self._cancelled.append(Completion(
            rid=req.rid, prompt=req.prompt, tokens=[], text="",
            timing={"queued": t - t_submit}, status="shed", reason=reason))
        obs.emit("req_shed", rid=req.rid, reason=reason,
                 queued_s=t - t_submit)

    def _cancel(self, s, status: str, reason: str = "") -> None:
        """Cancel an in-flight request mid-decode/prefill: its KV pages
        go back to the arena (through the PR 7 allocator) and the
        partial generation is returned with the given status."""
        self.filling = [f for f in self.filling if f is not s]
        self.active.pop(s.slot, None)
        self.pool.release(s.req.rid)
        s.t_done = time.perf_counter()
        self.stats[status] += 1
        timing = {"latency": s.t_done - s.t_submit,
                  "queued": s.t_admit - s.t_submit}
        if s.t_first is not None:
            timing["ttft"] = s.t_first - s.t_submit
        self._cancelled.append(Completion(
            rid=s.req.rid, prompt=s.req.prompt, tokens=list(s.generated),
            text=self.detokenize(s.generated), timing=timing,
            status=status, reason=reason))
        obs.emit("req_cancelled", rid=s.req.rid, status=status,
                 reason=reason, tokens=len(s.generated),
                 latency_s=timing["latency"])

    def _infeasible_blocks(self, req) -> bool:
        """True when the request's worst-case page demand exceeds the
        whole arena — it could never be admitted, even alone (ignoring
        best-case prefix sharing: a shed is deterministic, a maybe-hit
        is not)."""
        need = -(-(len(req.prompt) + req.max_new_tokens)
                 // self.pool.block_size)
        return need > self.pool.n_blocks

    def _enforce_slos(self) -> None:
        """Expire blown deadlines (wall-clock and fault-injected tick
        timeouts) and let the watchdog evict stalled decode rows."""
        t = time.perf_counter()
        for s in list(self.active.values()) + list(self.filling):
            ft = (self.faults.req_timeout_ticks(s.req.rid)
                  if self.faults is not None else 0)
            if ft and s.ticks_active >= ft:
                self._cancel(s, "expired",
                             f"fault req_timeout after {s.ticks_active} "
                             f"ticks")
            elif s.t_deadline is not None and t > s.t_deadline:
                self._cancel(s, "expired",
                             f"deadline {s.req.deadline:.3f}s exceeded")
            elif self.watchdog_rounds and \
                    s.stall_rounds >= self.watchdog_rounds:
                self._cancel(s, "evicted",
                             f"watchdog: no progress in {s.stall_rounds} "
                             f"decode rounds")

    # --- one scheduler tick -------------------------------------------------
    def step(self, params, now=None) -> list:
        """Advance prefill for a waiting group (admitting by BLOCK
        budget) or run one decode round; with chunked prefill the two
        alternate.  Returns the requests that finished this tick."""
        self._tick += 1
        if self._starve is not None:
            # fault: hold arena blocks hostage through the reservation
            # ledger (exactly the accounting a real leak would consume)
            self._starve.tick(self.pool.alloc_blocks, self._tick)
        for s in list(self.active.values()) + list(self.filling):
            s.ticks_active += 1
        self._enforce_slos()
        while (self.queue and len(self.filling) < self.prefill_batch):
            req, t_submit = self.queue[0]
            if now is not None and req.arrival > now:
                break
            if self._infeasible_blocks(req):
                self.queue.popleft()
                self._shed(req, t_submit, "blocks: worst-case "
                           "prompt+budget exceeds the whole arena")
                continue
            if not self.pool.can_admit(len(req.prompt), req.max_new_tokens):
                # backpressure, not rejection — unless the queue-latency
                # SLO says this request has already waited too long
                if self.queue_slo and \
                        time.perf_counter() - t_submit > self.queue_slo:
                    self.queue.popleft()
                    self._shed(req, t_submit,
                               f"queue: waited past SLO {self.queue_slo}s "
                               f"for blocks")
                    continue
                break
            self.queue.popleft()
            row, shared_toks = self.pool.alloc(req.rid, req.prompt,
                                               req.max_new_tokens)
            if shared_toks:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_tokens"] += shared_toks
            if self._run_t0 is not None and req.arrival > 0:
                # latency clock starts at the request's (simulated)
                # arrival, not at the up-front submit() call — otherwise
                # --arrival-rate offsets dominate the percentiles
                t_submit = max(t_submit, self._run_t0 + req.arrival)
            st = _State(req, row, shared_toks, t_submit,
                        time.perf_counter())
            if self.faults is not None:
                st.delay_left = self.faults.req_delay_rounds(req.rid)
            self.filling.append(st)
            self.stats["admitted"] += 1
            obs.emit("req_admitted", rid=req.rid,
                     queued_s=st.t_admit - st.t_submit,
                     prefix_hit_tokens=shared_toks)
        if self.filling and (self._fill_turn or not self.active):
            self._prefill_chunk_round(params)
            self._fill_turn = False
        elif self.active:
            self._decode_round(params)
            self._fill_turn = True
        self.stats["max_active"] = max(self.stats["max_active"],
                                       len(self.active))
        self.stats["peak_blocks"] = max(self.stats["peak_blocks"],
                                        self.pool.alloc_blocks.n_live)
        done = self._collect_finished()
        if self._cancelled:
            done.extend(self._cancelled)
            self._cancelled = []
        return done

    def run(self, params, requests=None, *, progress=False) -> list:
        """Drive until every queued request completes.  ``requests`` is
        an optional iterable of (prompt, max_new_tokens, sampler,
        arrival) tuples / dicts to submit first.  Arrival times are
        honoured against a wall clock started here."""
        for r in (requests or ()):
            if isinstance(r, dict):
                self.submit(**r)
            else:
                self.submit(*r)
        done = []
        t0 = self._run_t0 = time.perf_counter()
        while self.queue or self.filling or self.active:
            now = time.perf_counter() - t0
            finished = self.step(params, now=now)
            done.extend(finished)
            if progress and finished:
                print(f"[serve] {len(done)} done, {len(self.active)} "
                      f"active, {len(self.queue)} queued", flush=True)
            if not finished and not self.active and not self.filling \
                    and self.queue:
                time.sleep(0.001)       # all arrivals in the future
        if obs.enabled():
            self.emit_rollup()
        return sorted(done, key=lambda c: c.rid)

    # --- internals ----------------------------------------------------------
    def _keys(self, states):
        """Per-row raw (seed, position) key data — a request's stream
        never depends on its batch mates.  The position component is the
        absolute position of the token being SAMPLED (prompt length +
        tokens generated so far), which advances between the prefill
        sample and the first decode sample — ``s.pos`` alone would reuse
        the prefill key for the first decode draw."""
        return np.array(
            [[s.req.sampler.seed & 0xFFFFFFFF,
              len(s.req.prompt) + len(s.generated)] for s in states],
            np.uint32)

    def _tables(self, states, n_rows):
        """(n_rows, max_blocks) int32 page tables: listed states get
        their pool tables at their row; every other row stays all-null
        (its writes land in the masked null page)."""
        t = np.zeros((n_rows, self.pool.max_blocks), np.int32)
        for i, s in enumerate(states):
            row = i if n_rows == len(states) else s.slot
            ids = self.pool.table_of(s.req.rid)
            t[row, :len(ids)] = ids
        return t

    def _flush_freed(self):
        """Reset the ``pos`` maps of pages freed since the last jitted
        step: a reused page must not leak its previous occupant's valid
        positions into the next gather."""
        freed = self.pool.drain_freed()
        if not freed:
            return
        idx = np.asarray(freed, np.int32)
        for r in self.pool.cache:
            attn = self.pool.cache[r]["attn"]
            attn["pos"] = attn["pos"].at[:, idx].set(-1)

    def _prefill_chunk_round(self, params):
        """One jitted prefill call over the filling group's next spans:
        the whole remaining prompt when ``prefill_chunk`` is 0 (one-shot,
        exactly PR 5's admission prefill), else at most ``prefill_chunk``
        tokens per row.  Rows whose prompt completes sample their first
        token and join the decode batch."""
        group = self.filling[:self.prefill_batch]
        cap = self.prefill_chunk or self.max_len
        c_lens = [min(len(s.req.prompt) - s.fill_pos, cap) for s in group]
        lb = min(max(_pow2(max(c_lens)), 8), self.max_len)
        G = len(group)
        tokens = np.zeros((G, lb), np.int32)
        starts = np.zeros((G,), np.int32)
        lens = np.array(c_lens, np.int32)
        for i, s in enumerate(group):
            tokens[i, :c_lens[i]] = \
                s.req.prompt[s.fill_pos:s.fill_pos + c_lens[i]]
            starts[i] = s.fill_pos
            self.pool.ensure(s.req.rid, s.fill_pos + c_lens[i] - 1)
        tables = self._tables(group, G)
        temps = np.array([s.req.sampler.temperature for s in group],
                         np.float32)
        topks = np.array([s.req.sampler.top_k for s in group], np.int32)
        self._flush_freed()
        tok, self.pool.cache = self._prefill(
            params, self.pool.cache, tokens, starts, lens, tables,
            self._keys(group), temps, topks)
        tok = np.asarray(tok)
        t = time.perf_counter()
        finished_fill = set()
        for i, s in enumerate(group):
            s.fill_pos += c_lens[i]
            if s.fill_pos < len(s.req.prompt):
                continue                 # more chunks to go
            s.last_tok = int(tok[i])
            s.generated.append(s.last_tok)
            s.t_first = t
            self.pool.commit_prefix(s.req.rid, s.req.prompt)
            self.active[s.slot] = s
            finished_fill.add(id(s))
            obs.emit("req_prefilled", rid=s.req.rid,
                     prompt_len=len(s.req.prompt),
                     ttft_s=s.t_first - s.t_submit)
        self.filling = [s for s in self.filling
                        if id(s) not in finished_fill]
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += int(sum(c_lens))

    def _jit_steps(self):
        """(Re-)jit the prefill and decode steps — called at construction
        and again after a placement rebalance (the retrace resolves
        ``MoEConfig.placement == "auto"`` to the new placement)."""
        self._prefill = jax.jit(make_engine_prefill_step(
            self.model, self.mesh, self.dims, self._schedule),
            donate_argnums=(1,))
        self._decode = jax.jit(make_engine_decode_step(
            self.model, self.mesh, self.dims, self._schedule,
            with_aux=self._track_load), donate_argnums=(1,))

    def _maybe_rebalance(self):
        """Every ``rebalance_every`` decode rounds, score a placement
        derived from the load EMA against uniform over the cached decode
        decisions; on a win, install it and re-jit both steps."""
        if self.placement != "auto" or not self.rebalance_every:
            return
        if self.stats["decode_calls"] % self.rebalance_every:
            return
        if not self.load_ema.ready:
            return
        mcfg = getattr(self.model.cfg, "moe", None)
        if mcfg is None:
            return
        from repro.core import autosched
        epoch = autosched.maybe_rebalance(
            self.load_ema.value(), margin=self.rebalance_margin,
            capacity_factor=mcfg.capacity_factor, top_k=mcfg.top_k,
            infer=True)
        if epoch is None:
            return
        pl = autosched.current_placement()
        desc = pl.summary() if pl is not None else "uniform"
        self._jit_steps()
        obs.emit("serve_rebalance", epoch=epoch, placement=desc,
                 tick=self._tick)
        print(f"serve REBALANCE -> placement epoch {epoch}: {desc}",
              flush=True)

    def _decode_round(self, params):
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        steps = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)      # idle rows: greedy, ignored
        topks = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        states = []
        for s in sorted(self.active.values(), key=lambda s: s.slot):
            if s.delay_left > 0:
                # fault: this row sits the round out (its slot rides along
                # with an all-null table, so batch mates are bit-exactly
                # unaffected); the watchdog counts the stall
                s.delay_left -= 1
                s.stall_rounds += 1
                continue
            states.append(s)
        if not states:
            return
        for s in states:
            tokens[s.slot, 0] = s.last_tok
            steps[s.slot] = s.pos
            temps[s.slot] = s.req.sampler.temperature
            topks[s.slot] = s.req.sampler.top_k
            self.pool.ensure(s.req.rid, s.pos)
        keys[[s.slot for s in states]] = self._keys(states)
        tables = self._tables(states, B)
        self._flush_freed()
        if self._track_load:
            tok, self.pool.cache, load = self._decode(
                params, self.pool.cache, tokens, steps, tables, keys,
                temps, topks)
        else:
            tok, self.pool.cache = self._decode(
                params, self.pool.cache, tokens, steps, tables, keys,
                temps, topks)
            load = None
        tok = np.asarray(tok)
        if load is not None and load.shape[-1]:
            load = np.asarray(load)
            # the dense decode fallback body has no capacity buffer and
            # reports zero routed counts — no routing signal, don't let
            # it drag the EMA toward "perfectly balanced"
            if float(load.sum()) > 0:
                self.load_ema.update(load)
                self.stats["per_expert_load"] = [
                    round(float(v), 3) for v in self.load_ema.value()]
        for s in states:
            s.last_tok = int(tok[s.slot])
            s.generated.append(s.last_tok)
            s.pos += 1
            s.stall_rounds = 0
        self.stats["decode_calls"] += 1
        self.stats["decode_tokens"] += len(states)
        if obs.enabled():
            obs.emit("decode_round", tick=self._tick, rows=len(states),
                     active=len(self.active),
                     block_occupancy=self.pool.alloc_blocks.n_live
                     / max(self.pool.n_blocks, 1))
        self._maybe_rebalance()

    def _collect_finished(self) -> list:
        done = []
        for slot, s in list(self.active.items()):
            full = len(s.generated) >= s.req.max_new_tokens
            eos = (self.eos_token is not None
                   and s.generated and s.generated[-1] == self.eos_token)
            capped = s.pos >= self.max_len
            if not (full or eos or capped):
                continue
            s.t_done = time.perf_counter()
            del self.active[slot]
            self.pool.release(s.req.rid)            # pages back to the arena
            timing = {"ttft": s.t_first - s.t_submit,
                      "latency": s.t_done - s.t_submit,
                      "queued": s.t_admit - s.t_submit}
            self.registry.histogram("latency_s").add(timing["latency"])
            self.registry.histogram("ttft_s").add(timing["ttft"])
            obs.emit("req_finished", rid=s.req.rid,
                     tokens=len(s.generated), ttft_s=timing["ttft"],
                     latency_s=timing["latency"])
            done.append(Completion(
                rid=s.req.rid, prompt=s.req.prompt,
                tokens=list(s.generated),
                text=self.detokenize(s.generated), timing=timing))
        return done

    def emit_rollup(self) -> dict:
        """Snapshot the engine's rolling latency instruments + counters
        into one ``serve_rollup`` event (emitted when a sink is active)
        and return the snapshot."""
        admitted = max(self.stats["admitted"], 1)
        snap = self.registry.snapshot()
        snap.update(self.stats)
        snap["prefix_hit_rate"] = self.stats["prefix_hits"] / admitted
        snap["block_occupancy"] = (self.pool.alloc_blocks.n_live
                                   / max(self.pool.n_blocks, 1))
        snap.pop("per_expert_load", None)   # vector: too wide for rollup
        obs.emit("serve_rollup", **snap)
        return snap


def latency_stats(completions) -> dict:
    """Throughput + p50/p95/p99 latency summary for a finished run.

    Total on any input: empty runs, single samples, and mixed-status
    completion lists all produce the full key set (zeros where there is
    nothing to measure), so callers can index unconditionally.
    Percentiles are computed over the ``status == "ok"`` completions;
    shed/expired/evicted requests are counted (``n_shed`` /
    ``n_cancelled``) but never pollute the latency distribution.
    """
    completions = list(completions)
    ok = [c for c in completions
          if getattr(c, "status", "ok") == "ok" and "latency" in c.timing]
    out = {
        "n_requests": len(ok), "n_tokens": 0, "tok_per_s": 0.0,
        "p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
        "ttft_p50_ms": 0.0, "ttft_p99_ms": 0.0,
        "n_shed": sum(1 for c in completions
                      if getattr(c, "status", "ok") == "shed"),
        "n_cancelled": sum(1 for c in completions
                           if getattr(c, "status", "ok")
                           in ("expired", "evicted")),
    }
    if not ok:
        return out
    lat = sorted(c.timing["latency"] for c in ok)
    ttft = sorted(c.timing["ttft"] for c in ok if "ttft" in c.timing)

    def pct(xs, p):
        # the obs quantile (nearest-rank, single-sample safe: the index
        # clamps into [0, len-1]); empty reads as 0.0 here — "nothing
        # to measure", matching the zero-filled default key set
        return quantile(xs, p) if xs else 0.0

    n_tok = sum(len(c.tokens) for c in ok)
    span = max(max(lat), 1e-9)
    out.update({
        "n_tokens": n_tok, "tok_per_s": n_tok / span,
        "p50_ms": 1e3 * pct(lat, 50), "p95_ms": 1e3 * pct(lat, 95),
        "p99_ms": 1e3 * pct(lat, 99),
        "ttft_p50_ms": 1e3 * pct(ttft, 50),
        "ttft_p99_ms": 1e3 * pct(ttft, 99),
    })
    return out


def suggest_max_batch(cfg, *, n_ep: int = 1, n_esp: int = 1, n_mp: int = 1,
                      candidates=(1, 2, 4, 8, 16, 32), perf_model=None,
                      n_blocks=None, block_size: int = 16,
                      mean_len=None):
    """Decode batch-bucket sizing from the perf model (``t_decode``).

    Picks the candidate maximizing predicted decode throughput
    ``B / t_decode(B)``: decode steps are alpha-dominated, so per-token
    latency falls with batch until the bandwidth/compute terms take
    over.  The paged-KV budget enters twice: ``t_decode`` charges each
    row's KV read at HBM bandwidth (``kv_bytes``), and a finite arena
    (``n_blocks`` pages of ``block_size`` tokens) caps the batch at the
    rows it can actually hold at ``mean_len`` tokens each — the budget
    is BLOCKS, not slots.  Dense archs (no MoE layer to model) just
    take the largest block-feasible candidate.
    """
    from repro.core.perfmodel import MoELayerShape, tpu_v5e_model

    def blocks_ok(b):
        if n_blocks is None or not mean_len:
            return True
        per_row = -(-int(mean_len) // int(block_size))   # ceil
        return b * per_row <= int(n_blocks)

    feasible = [b for b in candidates if blocks_ok(b)] or [min(candidates)]
    if cfg.moe is None:
        return max(feasible)
    pm = perf_model or tpu_v5e_model(n_ep, n_esp, n_mp)
    kv_row_bytes = 0.0
    if mean_len:
        # per-row paged-KV read per decode step: every layer's K+V pages
        # up to the row's length (bf16)
        kv_row_bytes = (2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
                        * float(mean_len) * 2.0)

    def throughput(b):
        shape = MoELayerShape(
            B=b, L=1, M=cfg.moe.d_model, H=cfg.moe.d_ff,
            E=cfg.moe.n_experts, k=cfg.moe.top_k,
            f=cfg.moe.capacity_factor, n_mp=n_mp, n_esp=n_esp,
            n_ep=n_ep, infer=True)
        return b / pm.t_decode(shape, kv_bytes=b * kv_row_bytes)

    return max(feasible, key=throughput)
