"""Continuous-batching MoE serving engine.

``launch/serve.py`` used to drive one fixed batch token-by-token —
prompt positions included — with every decode step running the
*training*-shaped MoE schedules.  The engine replaces that with a
request lifecycle:

  submit -> queue -> admit (KV slot + batched ONE-SHOT prefill)
         -> decode rounds (continuous batch over the whole slot pool)
         -> finish (EOS / token budget) -> evict slot -> detokenize

Scheduling interleaves the two phases prefill-first: each ``step()``
either admits waiting requests (one jitted prefill over the whole
group's padded prompts — never ``prompt_len`` calls) or runs one decode
round over all ``max_batch`` pool rows at per-row positions.  Requests
join and leave the decode batch mid-run; idle rows ride along as
padding, which keeps the decode step's shapes FIXED — one compilation,
no matter how requests come and go.  Prefill shapes are bucketed
(prompt length rounded up to a power of two, group size capped by
``prefill_batch``), bounding compilations at log(max_len) x
prefill_batch.

MoE layers run decode-DEDICATED schedule decisions: ``decode_block``
marks its ``apply_moe`` calls ``infer=True``, giving decode pools their
own autosched cache class (never evicting the training/prefill
decision), the decode-widened plan grid (``s1d``), n_chunks pinned to
1, and drop-free capacity — a row's output is independent of its batch
mates, which is what makes continuous batching safe for routed experts
(and what the bitwise parity test in tests/test_serve.py pins down).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.models import blocks as blk
from repro.serve.kvcache import KVCachePool
from repro.serve.sampler import SamplerConfig
from repro.train.loop import (make_engine_decode_step,
                              make_engine_prefill_step)


@dataclass(frozen=True)
class Request:
    """One generation request: prompt token ids + budget + sampling."""

    rid: int
    prompt: tuple                      # token ids, len >= 1
    max_new_tokens: int = 16
    sampler: SamplerConfig = SamplerConfig()
    arrival: float = 0.0               # seconds after run start


@dataclass
class Completion:
    """A finished request: generated ids, text, and latency breakdown."""

    rid: int
    prompt: tuple
    tokens: list
    text: str
    timing: dict = field(default_factory=dict)   # ttft / latency seconds


class _State:
    __slots__ = ("req", "slot", "pos", "last_tok", "generated",
                 "t_submit", "t_admit", "t_first", "t_done")

    def __init__(self, req, slot, t_submit, t_admit):
        self.req, self.slot = req, slot
        self.pos = len(req.prompt)     # next absolute position to decode
        self.last_tok = None
        self.generated = []
        self.t_submit, self.t_admit = t_submit, t_admit
        self.t_first = self.t_done = None


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class Engine:
    """Continuous-batching serving engine over a KV-slot pool.

    ``max_batch`` is the decode batch (= KV pool slots); ``max_len`` the
    per-slot KV length (prompt + generation budget must fit).
    ``prefill_batch`` caps how many admissions share one prefill call
    (1 = each request prefills alone, which makes a request's prefill
    bitwise independent of its queue mates).  ``schedule`` forces one
    MoE schedule for prefill AND decode; None lets each phase's
    autosched decision stand.
    """

    def __init__(self, model, mesh, dims, *, max_batch: int = 8,
                 max_len: int = 256, schedule=None, prefill_batch: int = 1,
                 eos_token=None, detokenize=None):
        cfg = model.cfg
        bad = [k for k, _ in model.runs
               if blk.base_kind(k) not in ("dense", "moe")]
        if bad:
            raise NotImplementedError(
                f"Engine supports dense/moe decoder stacks; {cfg.name} "
                f"has block kinds {bad}")
        if cfg.attn_window is not None and cfg.attn_window < max_len:
            raise NotImplementedError(
                "Engine needs full-length KV rows (attn_window "
                f"{cfg.attn_window} < max_len {max_len})")
        self.model, self.mesh, self.dims = model, mesh, dims
        self.max_batch, self.max_len = int(max_batch), int(max_len)
        self.prefill_batch = max(int(prefill_batch), 1)
        self.eos_token = eos_token
        self.detokenize = detokenize or (
            lambda ids: " ".join(str(t) for t in ids))
        self.pool = KVCachePool(model, self.max_batch, self.max_len)
        # donate the pool: each step's input cache is dead once the
        # updated one lands, so XLA aliases them in place instead of
        # copying the whole KV pool every generated token
        self._prefill = jax.jit(make_engine_prefill_step(
            model, mesh, dims, schedule), donate_argnums=(1,))
        self._decode = jax.jit(make_engine_decode_step(
            model, mesh, dims, schedule), donate_argnums=(1,))
        self.queue: deque = deque()
        self._run_t0 = None             # run() wall-clock origin
        self.active: dict = {}          # slot -> _State
        self.stats = {"prefill_calls": 0, "decode_calls": 0,
                      "prefill_tokens": 0, "decode_tokens": 0,
                      "max_active": 0, "admitted": 0}
        self._rid = 0

    # --- request intake -----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               sampler: SamplerConfig = SamplerConfig(),
               arrival: float = 0.0, rid=None) -> int:
        """Queue one request (admission control: prompt + budget must fit
        a KV slot).  Returns the request id."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len {self.max_len}")
        if rid is None:
            rid, self._rid = self._rid, self._rid + 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens), sampler=sampler,
                      arrival=float(arrival))
        self.queue.append((req, time.perf_counter()))
        return rid

    # --- one scheduler tick -------------------------------------------------
    def step(self, params, now=None) -> list:
        """Admit+prefill a waiting group if possible, else run one decode
        round.  Returns the requests that finished this tick."""
        group = []
        while (self.queue and len(group) < self.prefill_batch
               and self.pool.can_admit()):
            req, t_submit = self.queue[0]
            if now is not None and req.arrival > now:
                break
            self.queue.popleft()
            slot = self.pool.alloc(req.rid)
            if self._run_t0 is not None and req.arrival > 0:
                # latency clock starts at the request's (simulated)
                # arrival, not at the up-front submit() call — otherwise
                # --arrival-rate offsets dominate the percentiles
                t_submit = max(t_submit, self._run_t0 + req.arrival)
            group.append(_State(req, slot, t_submit, time.perf_counter()))
        if group:
            self._prefill_group(params, group)
        elif self.active:
            self._decode_round(params)
        self.stats["max_active"] = max(self.stats["max_active"],
                                       len(self.active))
        return self._collect_finished()

    def run(self, params, requests=None, *, progress=False) -> list:
        """Drive until every queued request completes.  ``requests`` is
        an optional iterable of (prompt, max_new_tokens, sampler,
        arrival) tuples / dicts to submit first.  Arrival times are
        honoured against a wall clock started here."""
        for r in (requests or ()):
            if isinstance(r, dict):
                self.submit(**r)
            else:
                self.submit(*r)
        done = []
        t0 = self._run_t0 = time.perf_counter()
        while self.queue or self.active:
            now = time.perf_counter() - t0
            finished = self.step(params, now=now)
            done.extend(finished)
            if progress and finished:
                print(f"[serve] {len(done)} done, {len(self.active)} "
                      f"active, {len(self.queue)} queued", flush=True)
            if not finished and not self.active and self.queue:
                time.sleep(0.001)       # all arrivals in the future
        return sorted(done, key=lambda c: c.rid)

    # --- internals ----------------------------------------------------------
    def _keys(self, states):
        """Per-row raw (seed, position) key data — a request's stream
        never depends on its batch mates.  The position component is the
        absolute position of the token being SAMPLED (prompt length +
        tokens generated so far), which advances between the prefill
        sample and the first decode sample — ``s.pos`` alone would reuse
        the prefill key for the first decode draw."""
        return np.array(
            [[s.req.sampler.seed & 0xFFFFFFFF,
              len(s.req.prompt) + len(s.generated)] for s in states],
            np.uint32)

    def _prefill_group(self, params, group):
        lens = [len(s.req.prompt) for s in group]
        lb = min(max(_pow2(max(lens)), 8), self.max_len)
        tokens = np.zeros((len(group), lb), np.int32)
        for i, s in enumerate(group):
            tokens[i, :lens[i]] = s.req.prompt
        temps = np.array([s.req.sampler.temperature for s in group],
                         np.float32)
        topks = np.array([s.req.sampler.top_k for s in group], np.int32)
        slots = np.array([s.slot for s in group], np.int32)
        tok, self.pool.cache = self._prefill(
            params, self.pool.cache, tokens,
            np.array(lens, np.int32), slots, self._keys(group), temps,
            topks)
        tok = np.asarray(tok)
        t = time.perf_counter()
        for i, s in enumerate(group):
            s.last_tok = int(tok[i])
            s.generated.append(s.last_tok)
            s.t_first = t
            self.active[s.slot] = s
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += sum(lens)
        self.stats["admitted"] += len(group)

    def _decode_round(self, params):
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        steps = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)      # idle rows: greedy, ignored
        topks = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        states = sorted(self.active.values(), key=lambda s: s.slot)
        for s in states:
            tokens[s.slot, 0] = s.last_tok
            steps[s.slot] = s.pos
            temps[s.slot] = s.req.sampler.temperature
            topks[s.slot] = s.req.sampler.top_k
        keys[[s.slot for s in states]] = self._keys(states)
        tok, self.pool.cache = self._decode(
            params, self.pool.cache, tokens, steps, keys, temps, topks)
        tok = np.asarray(tok)
        for s in states:
            s.last_tok = int(tok[s.slot])
            s.generated.append(s.last_tok)
            s.pos += 1
        self.stats["decode_calls"] += 1
        self.stats["decode_tokens"] += len(states)

    def _collect_finished(self) -> list:
        done = []
        for slot, s in list(self.active.items()):
            full = len(s.generated) >= s.req.max_new_tokens
            eos = (self.eos_token is not None
                   and s.generated and s.generated[-1] == self.eos_token)
            capped = s.pos >= self.max_len
            if not (full or eos or capped):
                continue
            s.t_done = time.perf_counter()
            del self.active[slot]
            self.pool.release(s.req.rid)            # eviction on finish
            done.append(Completion(
                rid=s.req.rid, prompt=s.req.prompt,
                tokens=list(s.generated),
                text=self.detokenize(s.generated),
                timing={"ttft": s.t_first - s.t_submit,
                        "latency": s.t_done - s.t_submit,
                        "queued": s.t_admit - s.t_submit}))
        return done


def latency_stats(completions) -> dict:
    """Throughput + p50/p95/p99 latency summary for a finished run."""
    if not completions:
        return {}
    lat = sorted(c.timing["latency"] for c in completions)
    ttft = sorted(c.timing["ttft"] for c in completions)

    def pct(xs, p):
        return xs[min(int(p / 100.0 * len(xs)), len(xs) - 1)]

    n_tok = sum(len(c.tokens) for c in completions)
    span = max(max(lat), 1e-9)
    return {
        "n_requests": len(completions), "n_tokens": n_tok,
        "tok_per_s": n_tok / span,
        "p50_ms": 1e3 * pct(lat, 50), "p95_ms": 1e3 * pct(lat, 95),
        "p99_ms": 1e3 * pct(lat, 99),
        "ttft_p50_ms": 1e3 * pct(ttft, 50),
        "ttft_p99_ms": 1e3 * pct(ttft, 99),
    }


def suggest_max_batch(cfg, *, n_ep: int = 1, n_esp: int = 1, n_mp: int = 1,
                      candidates=(1, 2, 4, 8, 16, 32), perf_model=None):
    """Decode batch-bucket sizing from the perf model (``t_decode``).

    Picks the candidate maximizing predicted decode throughput
    ``B / t_decode(B)``: decode steps are alpha-dominated, so per-token
    latency falls with batch until the bandwidth/compute terms take
    over.  Dense archs (no MoE layer to model) just take the largest
    candidate.
    """
    from repro.core.perfmodel import MoELayerShape, tpu_v5e_model
    if cfg.moe is None:
        return max(candidates)
    pm = perf_model or tpu_v5e_model(n_ep, n_esp, n_mp)

    def throughput(b):
        shape = MoELayerShape(
            B=b, L=1, M=cfg.moe.d_model, H=cfg.moe.d_ff,
            E=cfg.moe.n_experts, k=cfg.moe.top_k,
            f=cfg.moe.capacity_factor, n_mp=n_mp, n_esp=n_esp,
            n_ep=n_ep, infer=True)
        return b / pm.t_decode(shape)

    return max(candidates, key=throughput)
