import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  REPRO_DRYRUN_DEVICES overrides for scaled-down CI.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
# The dry-run needs the *SPMD-partitioned program* (shardings, collectives,
# memory), not fast host code: turning LLVM codegen effort down makes the
# 512-device CPU-emulated compiles tractable without changing the HLO-level
# analyses this harness records.  Disable with REPRO_DRYRUN_FULL_OPT=1.
if not os.environ.get("REPRO_DRYRUN_FULL_OPT"):
    os.environ["XLA_FLAGS"] += " --xla_backend_optimization_level=0"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination, lower + compile
the appropriate step function (train_step / prefill / serve_step) against
ShapeDtypeStruct stand-ins (no allocation), then record:

  * memory_analysis()  — proves the program fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the compiled HLO per §Roofline.

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__<sched>].json.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.analysis.hlo import parse_collectives
from repro.analysis.layerwise import layerwise_costs
from repro.analysis.roofline import roofline_terms
from repro.configs import INPUT_SHAPES, get_config, input_specs
from repro.configs.registry import ASSIGNED
from repro.core import autosched
from repro.core.perfmodel import MoELayerShape
from repro.launch.mesh import dims_for, make_production_mesh, make_test_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, opt_state_specs
from repro.parallel.mesh import axis_size
from repro.train.loop import (cache_specs, make_guarded_train_step,
                              make_prefill_fn, make_serve_step,
                              make_train_step, named_tree)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _moe_pool_cap(cfg, shape, sizes, nb, sched_name):
    """Per-device token pool and capacity exactly as apply_moe computes
    them: the token-shard group is the batch axes plus — under the
    seqpar contract — the MP axes (moe.shard_pool_capacity).  Decode
    shapes mirror the inference class (drop-free capacity)."""
    from repro.core.moe import shard_pool_capacity
    from repro.core.pipeline import UNCHUNKED_OF
    tokens_global = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    seqpar = UNCHUNKED_OF.get(sched_name, sched_name) == "s1_seqpar"
    n_shard = max(nb, 1) * (max(sizes["mp"], 1) if seqpar else 1)
    s_local, cap = shard_pool_capacity(tokens_global, n_shard,
                                       sizes["mp"], cfg.moe.gate_config(),
                                       infer=shape.kind == "decode")
    return max(s_local, 1), cap


def _placement_summary(cfg):
    """JSON-ready expert-placement record for the artifact: None for
    dense/uniform configs, the resolved placement summary otherwise."""
    if cfg.moe is None or cfg.moe.placement is None:
        return None
    pl = cfg.moe.placement
    if pl == "auto":
        from repro.core import autosched
        live = autosched.current_placement()
        return {"mode": "auto", "epoch": autosched.placement_epoch(),
                "current": live.summary() if live is not None else None}
    return {"mode": "forced", "current": pl.summary()}


def count_params(shapes) -> int:
    import math
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes))


def active_param_count(cfg, shapes) -> float:
    """Active params per token: full count minus inactive expert fraction."""
    total = count_params(shapes)
    if cfg.moe is None:
        return float(total)
    moe = cfg.moe
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k.startswith("moe"))
    per_expert = moe.d_model * moe.d_ff * (3 if moe.glu else 2)
    inactive = n_moe_layers * per_expert * (moe.n_experts - moe.top_k)
    return float(total - inactive)


def variant_config(cfg, shape_name: str):
    """Apply the SWA variant for long_500k on full-attention archs."""
    shape = INPUT_SHAPES[shape_name]
    if shape.name != "long_500k" or cfg.sub_quadratic:
        return cfg, ""
    if cfg.arch_type == "audio":
        return None, "skip: enc-dec audio arch, 500k decode not meaningful"
    return replace(cfg, attn_window=8192), "swa"


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              schedule: str = None, dtype: str = "bfloat16",
              save_hlo: bool = False, cache_seq_shard: bool = False,
              saa_chunks: int = None, seq_parallel: bool = False,
              pipeline_chunks: int = None, run_step: bool = False,
              reduced: bool = False, seq: int = None,
              batch_size: int = None, wire_dtype: str = None,
              dump_plan: bool = False, guards: bool = False,
              audit: bool = False) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg, variant = variant_config(cfg, shape_name)
    if cfg is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": variant}
    cfg = replace(cfg, dtype=dtype)
    if cache_seq_shard:
        cfg = replace(cfg, context_parallel_decode=True)
    if seq_parallel:
        cfg = replace(cfg, seq_parallel=True)
    if saa_chunks is not None and cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe, saa_chunks=saa_chunks))
    if pipeline_chunks is not None and cfg.moe is not None:
        cfg = replace(cfg, moe=replace(cfg.moe,
                                       pipeline_chunks=pipeline_chunks))
    if wire_dtype is not None and cfg.moe is not None:
        from repro.core.collectives import CommConfig
        cfg = replace(cfg, moe=replace(
            cfg.moe, comm=replace(cfg.moe.comm or CommConfig(),
                                  wire_dtype=wire_dtype)))
    shape = INPUT_SHAPES[shape_name]
    if seq or batch_size:
        shape = dataclasses.replace(
            shape, seq_len=seq or shape.seq_len,
            global_batch=batch_size or shape.global_batch)
    n_dev = int(os.environ.get("REPRO_DRYRUN_DEVICES", "512"))
    mesh = (make_production_mesh(multi_pod=multi_pod) if n_dev >= 512
            else make_test_mesh(multi_pod=multi_pod))
    dims = dims_for(cfg, multi_pod)
    model = build_model(cfg)

    pspecs = model.specs(mesh, dims)
    p_sh = named_tree(mesh, pspecs)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    batch = input_specs(cfg, shape)
    baxes = tuple(dims.batch_axes)
    nb = axis_size(mesh, baxes) if baxes else 1

    def bshard(leaf):
        if leaf.ndim >= 1 and leaf.shape and leaf.shape[0] == shape.global_batch \
                and shape.global_batch % nb == 0 and baxes:
            return named_tree(mesh, jax.sharding.PartitionSpec(
                baxes, *([None] * (leaf.ndim - 1))))
        return named_tree(mesh, jax.sharding.PartitionSpec(
            *([None] * leaf.ndim)))
    b_sh = jax.tree.map(bshard, batch)

    sched = schedule
    chunks_pick = cfg.moe.pipeline_chunks if cfg.moe is not None else 0
    wire_pick = (cfg.moe.comm.wire_dtype if cfg.moe is not None
                 else "n/a")
    sched_auto = (cfg.moe is not None and not sched
                  and cfg.moe.schedule == "auto")
    if cfg.moe is not None and (sched_auto or wire_pick == "auto"):
        from repro.core.pipeline import UNCHUNKED_OF, clamp_chunks

        sizes = dims.sizes(mesh)
        # mirror apply_moe's pool/capacity + chunk-candidate clamping so
        # the recorded decision matches what the trace will compile
        # (shard_pool_capacity is the same helper apply_moe calls)
        s_local, cap = _moe_pool_cap(cfg, shape, sizes, nb,
                                     sched or cfg.moe.schedule)
        infer = shape.kind == "decode"
        # decode pools never chunk (mirrors apply_moe's infer grid)
        cands = ((1,) if infer else
                 tuple(sorted({clamp_chunks(cap // max(sizes["mp"], 1), n)
                               for n in autosched.DEFAULT_CHUNKS})))
        forced = None
        if not sched_auto:
            # forced schedule + wire="auto": wire-only decision, exactly
            # as apply_moe will make it
            base = sched or cfg.moe.schedule
            forced = (UNCHUNKED_OF.get(base, base),)
            cands = (clamp_chunks(cap // max(sizes["mp"], 1),
                                  cfg.moe.pipeline_chunks),)
        wire_cands = (autosched.AUTO_WIRE if wire_pick == "auto"
                      else (wire_pick,))
        decision = autosched.decide(MoELayerShape(
            B=1, L=s_local, M=cfg.d_model, H=cfg.moe.d_ff,
            E=cfg.moe.n_experts, k=cfg.moe.top_k,
            f=cfg.moe.capacity_factor, n_mp=sizes["mp"],
            n_esp=sizes["esp"], n_ep=sizes["ep"], infer=infer),
            chunk_candidates=cands, wire_candidates=wire_cands,
            schedules=forced)
        if sched_auto:
            sched_pick, chunks_pick = decision.schedule, decision.n_chunks
        else:
            sched_pick = sched or cfg.moe.schedule
        if wire_pick == "auto":
            wire_pick = decision.wire_dtype
    else:
        sched_pick = sched or (cfg.moe.schedule if cfg.moe is not None
                               else "n/a")

    plan_dump = None
    if dump_plan and cfg.moe is not None and sched_pick != "n/a":
        # serialize the chosen schedule's stage graph exactly as the MoE
        # layers will build it: same capacity, chunk clamp and wire dtype
        from repro.core.collectives import CommConfig
        from repro.core.pipeline import UNCHUNKED_OF
        from repro.core.plan import build_plan, format_plan, plan_summary
        from repro.core.schedules import MoEShardInfo
        sizes = dims.sizes(mesh)
        s_local, cap = _moe_pool_cap(cfg, shape, sizes, nb, sched_pick)
        winfo = MoEShardInfo(
            ep_axes=tuple(dims.ep), esp_axes=tuple(dims.esp),
            mp_axes=tuple(dims.mp), n_ep=sizes["ep"], n_esp=sizes["esp"],
            n_mp=sizes["mp"], tokens=s_local, cap=cap,
            gate=cfg.moe.gate_config(), glu=cfg.moe.glu,
            saa_chunks=cfg.moe.saa_chunks,
            pipeline_chunks=max(chunks_pick, 1),
            comm=CommConfig(
                wire_dtype=wire_pick if wire_pick != "auto" else "f32",
                scaling=(cfg.moe.comm or CommConfig()).scaling))
        p = build_plan(UNCHUNKED_OF.get(sched_pick, sched_pick), winfo)
        plan_dump = plan_summary(p)
        print(format_plan(p), flush=True)

    audit_reports = None
    if audit and cfg.moe is not None:
        # predicted-vs-measured schedule audit on a small subset of the
        # fake-device farm: compile + run the obs prefix-timing harness
        # and join against PerfModel.t_plan_stages.  Host-emulated
        # timings are noisy — the point is the joined REPORT (schema,
        # stage coverage, calibration scale), not CPU milliseconds.
        from repro.obs.audit import DEFAULT_AUDIT_SCHEDULES, \
            run_schedule_audit
        from repro.obs.trace import subset_mesh
        from repro.parallel.mesh import ParallelDims
        a_mesh = subset_mesh((4, 2), ("data", "model"))
        a_dims = ParallelDims(ep=("data",), esp=("model",),
                              mp=("model",))
        audit_reports = run_schedule_audit(
            a_mesh, a_dims, cfg.moe, tokens_global=256,
            schedules=DEFAULT_AUDIT_SCHEDULES, iters=3, warmup=1)
        for rep in audit_reports:
            worst = rep["worst"][:3]
            print(f"[audit] {rep['schedule']}: "
                  f"measured {rep['total_measured_s'] * 1e3:.3f} ms, "
                  f"predicted {rep['total_predicted_s'] * 1e3:.3f} ms, "
                  f"time_scale "
                  f"{rep['calibration']['time_scale']:.3g}, "
                  f"worst {worst}", flush=True)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        # ZeRO-1 (production default): shard optimizer moments' leading dim
        # over the pure-DP axes. For dense archs that's `data` (+`pod`);
        # for MoE archs `data` serves EP, so only `pod` remains multi-pod.
        zero_axes = tuple(dims.dp) + (
            () if cfg.moe is not None else tuple(dims.ep))
        if not zero_axes and cfg.moe is None and not multi_pod:
            zero_axes = ("data",)
        o_sh = named_tree(mesh, opt_state_specs(
            pspecs, mesh=mesh, dp_axes=zero_axes, zero1=bool(zero_axes),
            params_shape=p_shapes))
        if guards:
            # the fault-tolerant step (skip-step where-select + LR
            # backoff): proves the GUARDED program lowers/compiles/fits
            # on the production mesh, not just the plain one
            fn = make_guarded_train_step(model, mesh, dims, opt_cfg,
                                         schedule)
            scalar = jax.ShapeDtypeStruct((), jnp.float32)
            jitted = jax.jit(fn,
                             in_shardings=(p_sh, o_sh, b_sh, None, None),
                             out_shardings=(p_sh, o_sh, None))
            lowered = jitted.lower(p_shapes, o_shapes, batch, scalar,
                                   scalar)
        else:
            fn = make_train_step(model, mesh, dims, opt_cfg, schedule)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None))
            lowered = jitted.lower(p_shapes, o_shapes, batch)
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 3.0   # fwd + bwd
    elif shape.kind == "prefill":
        fn = make_prefill_fn(model, mesh, dims, schedule)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(p_shapes, batch)
        tokens = shape.global_batch * shape.seq_len
        flops_mult = 1.0
    else:  # decode: one token against a seq_len cache
        c_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     jnp.dtype(cfg.dtype)))
        c_specs = cache_specs(model, mesh, dims, shape.global_batch,
                              shape.seq_len, seq_shard=cache_seq_shard)
        c_sh = named_tree(mesh, c_specs)
        fn = make_serve_step(model, mesh, dims, schedule)
        if model.has_cross:
            # per-request precomputed cross-attention K/V (image/audio ctx)
            kv_shapes = jax.eval_shape(
                lambda p, b: model.ctx_kv(p, b, mesh=mesh, dims=dims),
                p_shapes, batch)
            kv_specs = jax.tree.map(
                lambda l: jax.sharding.PartitionSpec(
                    None, baxes if (l.ndim >= 2 and baxes and
                                    l.shape[1] % nb == 0) else None,
                    *([None] * (l.ndim - 2))),
                kv_shapes)
            kv_sh = named_tree(mesh, kv_specs)
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh, kv_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_shapes, c_shapes, batch, kv_shapes)
        else:
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_shapes, c_shapes, batch)
        tokens = shape.global_batch
        flops_mult = 1.0
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    step_metrics = None
    if run_step and shape.kind == "train":
        # prove the program end-to-end: init real (sharded) params and
        # optimizer state, run ONE optimizer step on synthetic tokens.
        params = jax.jit(model.init, out_shardings=p_sh)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(adamw_init, out_shardings=o_sh)(params)
        concrete = jax.tree.map(
            lambda l, s: jax.device_put(jnp.zeros(l.shape, l.dtype), s),
            batch, b_sh)
        if guards:
            one, zero = jnp.float32(1.0), jnp.float32(0.0)
            _, _, metrics = compiled(params, opt_state, concrete, one,
                                     zero)
        else:
            _, _, metrics = compiled(params, opt_state, concrete)
        step_metrics = {k: float(v) for k, v in metrics.items()
                        if getattr(v, "ndim", 0) == 0}
        el = metrics.get("expert_load")
        if el is not None and getattr(el, "ndim", 0) == 1 and el.shape[-1]:
            # per-expert routed-row counts (summed over layers): the
            # dropless grouped kernel's actual group sizes
            step_metrics["expert_load"] = [
                float(c) for c in jax.device_get(el)]
        print(f"[step] {arch} x {shape_name} sched={sched_pick} "
              f"wire={wire_pick} "
              f"loss={step_metrics.get('loss', float('nan')):.4f}",
              flush=True)

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[f] = getattr(mem, f, None)
    ca_list = compiled.cost_analysis()
    ca = ca_list if isinstance(ca_list, dict) else (
        ca_list[0] if ca_list else {})
    hlo = compiled.as_text()
    stats = parse_collectives(hlo)

    chips = mesh.devices.size
    n_params = count_params(p_shapes)
    n_active = active_param_count(cfg, p_shapes)
    model_flops = flops_mult * 2.0 * n_active * tokens  # 6ND = 3 * 2ND

    # Trip-count-correct accounting: XLA cost_analysis counts scan bodies
    # once, so roofline terms come from the layer-wise sums (x n_layers),
    # while the full-program compile above remains the fits/coherence proof.
    # The roofline table is single-pod only (§Roofline), so multi-pod combos
    # skip the extra per-block compiles and report raw program costs.
    if not multi_pod:
        lw = layerwise_costs(model, cfg, mesh, dims, shape, kind=shape.kind,
                             schedule=schedule)
        # lw is per-device; model_flops is whole-program -> per-chip ratio
        # uses chips inside roofline_terms, so scale up to whole-program.
        rl = roofline_terms({"flops": lw["flops"] * chips,
                             "bytes accessed": lw["bytes"] * chips},
                            lw["coll"], chips, model_flops)
    else:
        rl = roofline_terms(ca, stats.total_bytes, chips, model_flops)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": (variant + ("+reduced" if reduced else "")).lstrip("+"),
        "schedule": sched_pick, "pipeline_chunks": chunks_pick,
        "wire_dtype": wire_pick,
        # the expert placement the MoE layers would trace under: the
        # config's own (None | "auto" | concrete) resolved against the
        # process-wide autosched registry, as a JSON-ready summary
        "placement": _placement_summary(cfg),
        "plan": plan_dump,
        "audit": audit_reports,
        "step_metrics": step_metrics,
        # guarded combos record the guard-rail outcome: step_metrics
        # carries the jitted "nonfinite" flag (0.0 = the update applied)
        "robustness": {"guards": True,
                       "nonfinite": (step_metrics or {}).get("nonfinite"),
                       "lr_scale": 1.0} if guards else None,
        "chips": chips, "dtype": dtype,
        "n_params": n_params, "n_active_params": n_active,
        "tokens_per_step": tokens,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory_analysis": mem_d,
        "cost_flops": float(ca.get("flops", 0.0)),
        "cost_bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": {"counts": stats.counts,
                        "bytes": stats.bytes_by_kind,
                        "total_bytes": stats.total_bytes},
        "roofline": rl.as_dict(),
        "hlo_lines": hlo.count("\n"),
    }
    if save_hlo:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(os.path.join(
                ART_DIR, f"{arch}__{shape_name}__"
                f"{'multi' if multi_pod else 'single'}.hlo"), "w") as f:
            f.write(hlo)
    return rec


def save(rec: dict, suffix: str = ""):
    os.makedirs(ART_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(ART_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--schedule", default=None,
                    help="force a Parm schedule (baseline/s1/s2/s1_seqpar/"
                         "s2h or a pipelined *_pipe variant)")
    ap.add_argument("--dump-plan", action="store_true",
                    help="print the chosen schedule's plan-IR stage graph "
                         "and record it (stages, deps, wire dtypes, chunk "
                         "count) in the artifact JSON")
    ap.add_argument("--audit", action="store_true",
                    help="run the predicted-vs-measured schedule audit "
                         "(s1/s2/s1g stage timings vs the perf model) on "
                         "a 4x2 subset mesh and record the reports in "
                         "the artifact JSON (pair with --reduced)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="micro-chunk count for the pipelined bodies")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["f32", "bf16", "fp8_e4m3", "auto"],
                    help="wire format for the MoE collectives (auto = "
                         "joint autosched decision per layer shape)")
    ap.add_argument("--run-step", action="store_true",
                    help="after compiling a train combo, init real params "
                         "and execute one optimizer step (use with "
                         "--reduced/--seq/--batch on CPU)")
    ap.add_argument("--guards", action="store_true",
                    help="lower the GUARDED train step (non-finite "
                         "skip-step + LR backoff) and record the guard "
                         "outcome in the artifact")
    ap.add_argument("--reduced", action="store_true",
                    help="lower the smoke-scale config variant")
    ap.add_argument("--seq", type=int, default=None,
                    help="override the input shape's sequence length")
    ap.add_argument("--batch", type=int, default=None,
                    help="override the input shape's global batch")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip combos whose artifact JSON already exists")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-SP residual stream (§Perf B2)")
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="shard attention KV caches along the length dim "
                         "over MP (context-parallel decode; §Perf lever)")
    ap.add_argument("--saa-chunks", type=int, default=None,
                    help="override SAA pipeline depth (1 = AAS, no overlap)")
    ap.add_argument("--tag", default="",
                    help="artifact filename suffix for perf iterations")
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                if args.skip_existing:
                    sfx = f"__{args.schedule}" if args.schedule else ""
                    fname = os.path.join(
                        ART_DIR, f"{arch}__{shape}__"
                        f"{'multi' if mp else 'single'}{sfx}.json")
                    if os.path.exists(fname):
                        print(f"[have] {tag}", flush=True)
                        continue
                try:
                    rec = lower_one(arch, shape, mp, args.schedule,
                                    args.dtype, args.save_hlo,
                                    cache_seq_shard=args.cache_seq_shard,
                                    saa_chunks=args.saa_chunks,
                                    seq_parallel=args.seq_parallel,
                                    pipeline_chunks=args.pipeline_chunks,
                                    run_step=args.run_step,
                                    reduced=args.reduced, seq=args.seq,
                                    batch_size=args.batch,
                                    wire_dtype=args.wire_dtype,
                                    dump_plan=args.dump_plan,
                                    guards=args.guards,
                                    audit=args.audit)
                    sfx = f"__{args.schedule}" if args.schedule else ""
                    if args.tag:
                        sfx += f"__{args.tag}"
                    save(rec, sfx)
                    if rec.get("skipped"):
                        print(f"[skip] {tag}: {rec['skipped']}", flush=True)
                        continue
                    rl = rec["roofline"]
                    print(f"[ok]   {tag} sched={rec['schedule']} "
                          f"compile={rec['compile_s']:.1f}s "
                          f"flops={rec['cost_flops']:.3g} "
                          f"coll={rec['collectives']['total_bytes']:.3g}B "
                          f"bound={rl['bottleneck']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: "
                         + "; ".join(t for t, _ in failures))
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
