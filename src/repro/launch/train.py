"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gpt2-moe --reduced \
      --steps 200 --seq 256 --batch 8 --schedule auto

Full-size configs target the production mesh (real TPU pods); --reduced
runs the smoke-scale variant on whatever devices are present.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import replace

import jax

from repro import obs
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import dims_for, make_production_mesh
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default=None,
                    help="Parm schedule override (baseline/s1/s2/s1_seqpar/"
                         "s2h, their *_pipe pipelined variants, or auto; "
                         "any schedule registered in repro.core.plan works)")
    ap.add_argument("--pipeline-chunks", type=int, default=None,
                    help="micro-chunk count for the pipelined bodies "
                         "(1 = unchunked)")
    ap.add_argument("--autosched", default=None,
                    choices=["analytic", "measured"],
                    help="schedule=auto decision mode: score the perf model "
                         "or calibrate each candidate on the live mesh")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["f32", "bf16", "fp8_e4m3", "auto"],
                    help="wire format for the MoE collectives: ship "
                         "AlltoAll/AllGather payloads at this width "
                         "(auto = let the autoscheduler pick f32 vs bf16 "
                         "per layer shape; decisions print after step 0)")
    ap.add_argument("--placement", default="uniform",
                    choices=["uniform", "auto"],
                    help="expert placement: uniform (one expert per slot, "
                         "the default) or auto (load-adaptive replication "
                         "of hot experts, rebalanced from the live load "
                         "EMA every --rebalance-every steps)")
    ap.add_argument("--rebalance-every", type=int, default=50,
                    help="steps between placement rebalance checks "
                         "(--placement auto; 0 disables rebalancing)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint period in steps (default: steps/2 "
                         "when --ckpt is set)")
    ap.add_argument("--retain", type=int, default=3,
                    help="retained checkpoints under --guards (last k)")
    ap.add_argument("--guards", action="store_true",
                    help="fault-tolerant loop: non-finite skip-step + LR "
                         "backoff, loss-spike detection, checkpoint "
                         "rollback (needs --ckpt), fp8 overflow fallback")
    ap.add_argument("--max-skips", type=int, default=3,
                    help="consecutive skipped steps before rollback")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec, e.g. 'nan_grad@step=5-8;"
                         "fp8_sat@factor=64;ckpt_bitflip@save=2' "
                         "(see repro.runtime.faults; implies --guards)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--metrics-dir", default=None,
                    help="stream run telemetry (train_step / guard / "
                         "autosched / fp8 events) as JSONL into this "
                         "directory; emitted file paths are mirrored "
                         "into --log-json")
    ap.add_argument("--trace", action="store_true",
                    help="after training, time the resolved MoE "
                         "schedule's plan stages and save a Chrome "
                         "trace JSON into --metrics-dir")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    if args.trace and not args.metrics_dir:
        ap.error("--trace requires --metrics-dir")

    cfg = get_config(args.arch)
    if cfg.moe is not None and (args.pipeline_chunks is not None
                                or args.autosched or args.wire_dtype
                                or args.placement == "auto"):
        moe_kw = {}
        if args.pipeline_chunks is not None:
            moe_kw["pipeline_chunks"] = args.pipeline_chunks
        if args.autosched:
            moe_kw["autosched"] = args.autosched
        if args.wire_dtype:
            from repro.core.collectives import CommConfig
            moe_kw["comm"] = replace(cfg.moe.comm,
                                     wire_dtype=args.wire_dtype) \
                if cfg.moe.comm else CommConfig(wire_dtype=args.wire_dtype)
        if args.placement == "auto":
            # MoE layers read the live placement from the autosched
            # registry at trace time; the Trainer drives the rebalances
            moe_kw["placement"] = "auto"
        cfg = replace(cfg, moe=replace(cfg.moe, **moe_kw))
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers or 2,
                          d_model=args.d_model or 256)
    elif args.layers or args.d_model:
        cfg = replace(cfg, n_layers=args.layers or cfg.n_layers,
                      d_model=args.d_model or cfg.d_model)

    n_dev = jax.device_count()
    if n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        dims = dims_for(cfg, args.multi_pod)
    else:
        # fold whatever devices exist into (data, model)
        d = max(1, n_dev // 2) if n_dev > 1 else 1
        mesh = make_mesh((d, n_dev // d), ("data", "model"))
        dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
                if cfg.moe is not None
                else ParallelDims(dp=("data",), mp=("model",)))

    if args.metrics_dir:
        obs.configure(args.metrics_dir, meta={
            "kind": "train", "arch": args.arch, "steps": args.steps,
            "seq_len": args.seq, "batch": args.batch,
            "schedule": args.schedule, "n_devices": n_dev,
            "argv": sys.argv[1:]})

    model = build_model(cfg)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    guards = faults = None
    if args.faults:
        from repro.runtime import FaultPlan
        faults = FaultPlan.parse(args.faults, seed=args.fault_seed)
        print(f"fault plan: {faults.summary()}", flush=True)
    if args.guards or faults is not None:
        from repro.runtime import GuardConfig
        guards = GuardConfig(max_skips=args.max_skips)
    placement = args.placement if cfg.moe is not None else "uniform"
    tr = Trainer(model, mesh, dims, opt, schedule=args.schedule,
                 ckpt_path=args.ckpt, guards=guards, faults=faults,
                 ckpt_retain=args.retain,
                 placement="auto" if placement == "auto" else None,
                 rebalance_every=args.rebalance_every)
    params, opt_state = tr.setup(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    ckpt_every = args.ckpt_every or (args.steps // 2 if args.ckpt else 0)
    params, opt_state, hist = tr.run(params, opt_state, data, args.steps,
                                     ckpt_every=ckpt_every if args.ckpt
                                     else 0)

    trace_file = None
    if args.trace:
        if cfg.moe is None:
            print("--trace: dense arch has no MoE plan stages; skipping",
                  flush=True)
        else:
            from repro.obs.audit import trace_schedule
            from repro.obs.trace import save_chrome_trace
            sched = args.schedule
            if sched in (None, "auto") or sched.endswith("_seqpar"):
                sched = "s1"   # concrete, trace-compatible default
            st = trace_schedule(mesh, dims, cfg.moe,
                                args.batch * args.seq, sched,
                                n_chunks=args.pipeline_chunks or 1)
            trace_file = os.path.join(args.metrics_dir,
                                      f"trace_{sched}.json")
            save_chrome_trace(st, trace_file)
            obs.emit("stage_trace", schedule=sched, path=trace_file,
                     total_s=st.total_s, n_stages=st.n_stages)
            print(f"stage trace ({sched}, {st.n_stages} stages, "
                  f"{st.total_s * 1e3:.3f} ms) -> {trace_file}",
                  flush=True)

    metrics_files = None
    if args.metrics_dir:
        metrics_files = list(obs.get_sink().paths)
        obs.close()

    if args.log_json:
        os.makedirs(os.path.dirname(os.path.abspath(args.log_json)),
                    exist_ok=True)
        rec = hist if (guards is None and placement != "auto"
                       and not args.metrics_dir) else {"history": hist}
        if isinstance(rec, dict) and args.metrics_dir:
            rec["obs"] = {"metrics_dir": args.metrics_dir,
                          "metrics_files": metrics_files,
                          "trace_file": trace_file}
        if isinstance(rec, dict) and guards is not None:
            rec.update({"guards": dict(tr.guard_state.counters),
                        "guard_events": tr.guard_state.events,
                        "lr_scale": tr.guard_state.lr_scale})
        if isinstance(rec, dict) and placement == "auto":
            from repro.core import autosched
            pl = autosched.current_placement()
            rec["placement"] = {
                "mode": "auto",
                "rebalance_every": args.rebalance_every,
                "epoch": autosched.placement_epoch(),
                "current": pl.summary() if pl is not None else None,
                "load_ema": [round(float(v), 3)
                             for v in tr.load_ema.value()]}
        with open(args.log_json, "w") as f:
            json.dump(rec, f, indent=1)
    import math
    if guards is not None:
        gs = tr.guard_state
        # the chaos contract: an injected-fault run must still END finite
        assert math.isfinite(hist[-1]["loss"]), \
            f"guarded run ended non-finite: {hist[-1]['loss']}"
        if faults is not None and any(
                s.kind == "nan_grad" for s in faults.specs):
            assert gs.counters["skipped"] > 0, \
                "nan_grad fault injected but no step was skipped"
        print(f"CHAOS TRAIN OK  final loss {hist[-1]['loss']:.4f}  "
              f"({gs.counters['skipped']} skipped, "
              f"{gs.counters['rollbacks']} rollbacks)", flush=True)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
