"""Serving launcher: continuous-batching engine over synthetic traffic.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 16 --arrival-rate 8 --max-batch 8 --gen 32 --schedule auto

Thin CLI over ``repro.serve.Engine``: synthesizes ``--requests`` random
prompts (lengths uniform in [4, --prompt-len]), optionally spreads their
arrivals at ``--arrival-rate`` req/s, serves them with continuous
batching + decode-dedicated MoE schedules, and prints throughput and
latency percentiles.  ``--smoke`` caps everything for CI and exits 0 on
a clean run.
"""

from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.serve import Engine, SamplerConfig, latency_stats


def build_engine(args, cfg, model):
    n_dev = jax.device_count()
    d = max(1, n_dev // 2) if n_dev > 1 else 1
    mesh = make_mesh((d, max(n_dev // d, 1)), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))
    schedule = None if args.schedule in (None, "auto") else args.schedule
    max_batch = args.max_batch
    if max_batch <= 0:               # perf-model bucket sizing (t_decode)
        from repro.serve import suggest_max_batch
        sizes = dims.sizes(mesh)
        # mean live context per row: half the prompt spread + the budget
        mean_len = min((4 + args.prompt_len) / 2 + args.gen, args.max_len)
        max_batch = suggest_max_batch(
            cfg, n_ep=sizes["ep"], n_esp=sizes["esp"], n_mp=sizes["mp"],
            candidates=(1, 2, 4, 8, 16, 32),
            n_blocks=args.n_blocks or None, block_size=args.block_size,
            mean_len=mean_len)
        print(f"auto max-batch (t_decode, block budget): {max_batch}")
    faults = None
    if getattr(args, "faults", None):
        from repro.runtime import FaultPlan
        faults = FaultPlan.parse(args.faults, seed=args.fault_seed)
        print(f"fault plan: {faults.summary()}", flush=True)
    placement = getattr(args, "placement", "uniform")
    if placement == "auto" and cfg.moe is None:
        placement = "uniform"
    return Engine(model, mesh, dims, max_batch=max_batch,
                  max_len=args.max_len, schedule=schedule,
                  prefill_batch=args.prefill_batch,
                  block_size=args.block_size,
                  n_blocks=args.n_blocks or None,
                  prefix_cache=args.prefix_cache,
                  prefill_chunk=args.prefill_chunk,
                  queue_slo=getattr(args, "queue_slo", 0.0),
                  watchdog_rounds=getattr(args, "watchdog_rounds", 0),
                  faults=faults,
                  placement="auto" if placement == "auto" else None,
                  rebalance_every=getattr(args, "rebalance_every", 0)), \
        mesh, dims


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests/s (0 = all arrive at t=0)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="decode batch / KV slots (0 = auto via t_decode)")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max synthetic prompt length")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--prefill-batch", type=int, default=1)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV page size in tokens (must divide --max-len)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="KV arena pages (0 = slab-equivalent "
                         "max_batch * max_len / block_size)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shared-prefix reuse (--no-prefix-cache disables)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size in tokens (0 = one-shot); "
                         "chunks alternate with decode rounds")
    ap.add_argument("--schedule", default=None,
                    help="force one MoE schedule (default: auto decisions)")
    ap.add_argument("--placement", default="uniform",
                    choices=["uniform", "auto"],
                    help="expert placement: uniform (default) or auto "
                         "(load-adaptive replication from the decode load "
                         "EMA, rebalanced every --rebalance-every rounds)")
    ap.add_argument("--rebalance-every", type=int, default=64,
                    help="decode rounds between placement rebalance "
                         "checks (--placement auto; 0 disables)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request wall-clock deadline in seconds "
                         "(0 = none); blown deadlines cancel mid-flight "
                         "and free their KV pages")
    ap.add_argument("--queue-slo", type=float, default=0.0,
                    help="max seconds a request may wait in queue for "
                         "blocks before being shed (0 = backpressure only)")
    ap.add_argument("--watchdog-rounds", type=int, default=0,
                    help="evict a decode row after this many rounds "
                         "without progress (0 = off)")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec, e.g. 'req_timeout@rid=1,"
                         "ticks=4;req_delay@rid=2,rounds=99;alloc_starve@"
                         "tick=1,hold=999,rounds=8' (repro.runtime.faults)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--log-json", default=None,
                    help="write latency + robustness stats to this file")
    ap.add_argument("--metrics-dir", default=None,
                    help="stream request-lifecycle telemetry (queued/"
                         "admitted/prefilled/finished, decode rounds, "
                         "rollups) as JSONL into this directory; file "
                         "paths are mirrored into --log-json")
    ap.add_argument("--trace", action="store_true",
                    help="after serving, time the decode MoE schedule's "
                         "plan stages and save a Chrome trace JSON into "
                         "--metrics-dir")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny run, assert clean completion")
    args = ap.parse_args()
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.trace and not args.metrics_dir:
        ap.error("--trace requires --metrics-dir")
    if args.smoke:
        args.requests = min(args.requests, 8)
        args.gen = min(args.gen, 8)
        args.max_len = min(args.max_len, 64)
        args.prompt_len = min(args.prompt_len, 12)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.placement == "auto" and cfg.moe is not None:
        from dataclasses import replace as _replace
        # MoE layers read the live placement from the autosched registry
        # at trace time; the engine drives the rebalances
        cfg = _replace(cfg, moe=_replace(cfg.moe, placement="auto"))
    if args.metrics_dir:
        obs.configure(args.metrics_dir, meta={
            "kind": "serve", "arch": args.arch,
            "requests": args.requests, "max_batch": args.max_batch,
            "gen": args.gen, "schedule": args.schedule,
            "n_devices": jax.device_count(), "argv": sys.argv[1:]})
    model = build_model(cfg)
    engine, mesh, dims = build_engine(args, cfg, model)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.RandomState(args.seed)
    sampler = SamplerConfig(temperature=args.temperature,
                            top_k=args.top_k, seed=args.seed)
    for i in range(args.requests):
        plen = int(rng.randint(4, max(args.prompt_len, 5)))
        engine.submit(rng.randint(0, cfg.vocab_size, plen), args.gen,
                      sampler=sampler,
                      arrival=(i / args.arrival_rate
                               if args.arrival_rate > 0 else 0.0),
                      deadline=args.deadline)
    done = engine.run(params, progress=not args.smoke)

    stats = latency_stats(done)
    s = engine.stats
    print(f"served {stats['n_requests']} requests / "
          f"{stats['n_tokens']} tokens: {stats['tok_per_s']:.1f} tok/s  "
          f"p50 {stats['p50_ms']:.0f}ms  p95 {stats['p95_ms']:.0f}ms  "
          f"p99 {stats['p99_ms']:.0f}ms  "
          f"ttft_p50 {stats['ttft_p50_ms']:.0f}ms")
    print(f"engine: {s['prefill_calls']} prefill calls "
          f"({s['prefill_tokens']} tokens), {s['decode_calls']} decode "
          f"rounds ({s['decode_tokens']} tokens), max_active "
          f"{s['max_active']}/{engine.max_batch}")
    print(f"paged kv: {s['prefix_hits']} prefix hits "
          f"({s['prefix_tokens']} tokens reused), peak pages "
          f"{s['peak_blocks']}/{engine.pool.n_blocks} "
          f"(block size {engine.block_size})")
    if s["shed"] or s["expired"] or s["evicted"] or args.faults \
            or args.deadline or args.queue_slo or args.watchdog_rounds:
        print(f"robustness: {s['shed']} shed "
              f"({s['shed_blocks']} blocks, {s['shed_queue']} queue SLO), "
              f"{s['expired']} expired, {s['evicted']} evicted")
    from repro.core import autosched
    summary = autosched.cache_summary()
    if summary:
        print(summary)

    trace_file = None
    if args.trace:
        if cfg.moe is None:
            print("--trace: dense arch has no MoE plan stages; skipping",
                  flush=True)
        else:
            import os as _os
            from repro.obs.audit import trace_schedule
            from repro.obs.trace import save_chrome_trace
            sched = args.schedule
            if sched in (None, "auto") or sched.endswith("_seqpar"):
                sched = "s1d"   # the decode-dedicated plan
            try:
                st = trace_schedule(mesh, dims, cfg.moe,
                                    engine.max_batch, sched, infer=True)
            except Exception as e:   # tiny decode pools can be untraceable
                print(f"--trace: {type(e).__name__}: {e}; skipping",
                      flush=True)
            else:
                trace_file = _os.path.join(args.metrics_dir,
                                           f"trace_{sched}.json")
                save_chrome_trace(st, trace_file)
                obs.emit("stage_trace", schedule=sched, path=trace_file,
                         total_s=st.total_s, n_stages=st.n_stages)
                print(f"stage trace ({sched}, {st.n_stages} stages, "
                      f"{st.total_s * 1e3:.3f} ms) -> {trace_file}",
                      flush=True)

    metrics_files = None
    if args.metrics_dir:
        metrics_files = list(obs.get_sink().paths)
        obs.close()

    if args.log_json:
        import json as _json
        import os as _os
        _os.makedirs(_os.path.dirname(_os.path.abspath(args.log_json)),
                     exist_ok=True)
        rec = {"latency": stats, "engine": s,
               "statuses": {c.rid: c.status for c in done}}
        if args.metrics_dir:
            rec["obs"] = {"metrics_dir": args.metrics_dir,
                          "metrics_files": metrics_files,
                          "trace_file": trace_file}
        if args.placement == "auto":
            pl = autosched.current_placement()
            rec["placement"] = {
                "mode": "auto",
                "rebalance_every": args.rebalance_every,
                "epoch": autosched.placement_epoch(),
                "current": pl.summary() if pl is not None else None,
                "per_expert_load": s.get("per_expert_load")}
        with open(args.log_json, "w") as f:
            _json.dump(rec, f, indent=1)
    ok = [c for c in done if c.status == "ok"]
    if ok:
        print("sample:", ok[0].tokens[:16])
    if args.smoke:
        # every submitted request must come back — finished, shed,
        # expired, or evicted; nothing may hang or vanish
        assert len(done) == args.requests, "smoke: not all requests done"
        assert all(len(c.tokens) > 0 for c in ok)
        if args.faults:
            assert ok, "chaos smoke: every request was cancelled"
            print("SERVE CHAOS OK")
        print("SERVE SMOKE OK")


if __name__ == "__main__":
    main()
