"""Serving launcher: batched greedy decoding against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.mesh import ParallelDims, make_mesh
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--schedule", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = jax.device_count()
    d = max(1, n_dev // 2) if n_dev > 1 else 1
    mesh = make_mesh((d, max(n_dev // d, 1)), ("data", "model"))
    dims = (ParallelDims(ep=("data",), esp=("model",), mp=("model",))
            if cfg.moe is not None
            else ParallelDims(dp=("data",), mp=("model",)))

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(B, max_len)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (B, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.arch_type == "vlm":
        batch["ctx_embeds"] = jnp.zeros((B, cfg.n_ctx_tokens, cfg.d_model))
    if cfg.arch_type == "audio":
        batch["ctx_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
    ctx_kv = model.ctx_kv(params, batch, mesh=mesh, dims=dims) \
        if model.has_cross else None

    serve = jax.jit(make_serve_step(model, mesh, dims, args.schedule))

    # prefill by stepping the prompt (simple serving loop)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    out_tokens = []
    for t in range(max_len - 1):
        b = {"tokens": (prompt[:, t:t + 1] if t < args.prompt_len - 1
                        else tok), "step": jnp.int32(t)}
        if ctx_kv is not None:
            tok, cache = serve(params, cache, b, ctx_kv)
        else:
            tok, cache = serve(params, cache, b)
        if t >= args.prompt_len - 1:
            out_tokens.append(int(tok[0, 0]))
    dt = time.perf_counter() - t0
    print(f"generated {len(out_tokens)} tokens x batch {B} "
          f"in {dt:.2f}s ({B * len(out_tokens) / dt:.1f} tok/s)")
    print("sample:", out_tokens[:16])


if __name__ == "__main__":
    main()
