"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* any jax
initialization.
"""

from __future__ import annotations

import jax

from repro.parallel.mesh import ParallelDims, make_mesh, production_dims


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Scaled-down mesh with the same axis structure (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dims_for(cfg, multi_pod: bool = False) -> ParallelDims:
    """Logical parallel dims for an architecture on the production mesh."""
    return production_dims(multi_pod=multi_pod, moe=cfg.moe is not None)
