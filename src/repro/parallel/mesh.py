"""Mesh construction and logical parallel-dimension bookkeeping.

Parm's schedules are expressed over four *logical* parallel dimensions —
DP (pure data parallel), EP (expert parallel), ESP (expert-sharding
parallel) and MP (tensor/model parallel) — each mapped onto one or more
physical mesh axes.  The production mesh maps EP onto ``data`` and both
MP and ESP onto ``model`` (the DeepSpeed-TED setting, N_MP == N_ESP);
unit tests build dedicated ``(dp, ep, esp, mp)`` meshes to exercise
N_MP != N_ESP, which the paper's Table III explores.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
from jax.sharding import Mesh

from repro import compat


def make_mesh(shape, names) -> Mesh:
    """``jax.make_mesh`` pinned to Auto axis types (GSPMD + shard_map mix).

    Routed through ``repro.compat`` so the same call works on jax 0.4.x
    (no ``AxisType`` / ``axis_types=``) and 0.5+.
    """
    return compat.make_mesh(shape, names)


def axis_size(mesh: Mesh, axes) -> int:
    """Product of sizes of ``axes`` (a name or tuple of names) in ``mesh``."""
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


@dataclass(frozen=True)
class ParallelDims:
    """Mapping of logical parallel dims to physical mesh axis names.

    ``esp == mp`` (and non-empty) is the *merged* mode used on the
    production mesh: the ESP group coincides with the MP group, so the
    baseline schedule's ESP-AllGather materializes N_MP identical copies
    of the dispatch buffer — exactly the redundancy Parm eliminates.
    """

    dp: tuple = ()   # pure data-parallel axes (gradient all-reduce)
    ep: tuple = ()   # expert-parallel axes (AlltoAll dispatch/combine)
    esp: tuple = ()  # expert-sharding axes (expert FFN hidden dim)
    mp: tuple = ()   # tensor/model-parallel axes (dense Megatron sharding)

    def __post_init__(self):
        for f in ("dp", "ep", "esp", "mp"):
            v = getattr(self, f)
            if isinstance(v, str):
                object.__setattr__(self, f, (v,))
            else:
                object.__setattr__(self, f, tuple(v))

    @property
    def merged(self) -> bool:
        """True when the ESP group is the MP group (DeepSpeed-TED setting)."""
        return len(self.mp) > 0 and self.esp == self.mp

    @property
    def batch_axes(self) -> tuple:
        """Axes over which tokens are distinct at the MoE-layer boundary.

        In merged mode MP(==ESP) ranks hold replicated activations; in the
        distinct-axes mode, ESP ranks double as extra data parallelism
        (they hold different tokens), which is what gives the baseline's
        ESP-AllGather its B*L*M*N_ESP cost in the paper's Eq. (1).
        """
        if self.merged:
            return self.dp + self.ep
        return self.dp + self.ep + self.esp

    def sizes(self, mesh: Mesh) -> dict:
        return {
            "dp": axis_size(mesh, self.dp),
            "ep": axis_size(mesh, self.ep),
            "esp": axis_size(mesh, self.esp),
            "mp": axis_size(mesh, self.mp),
        }

    def validate(self, mesh: Mesh, n_experts: int) -> None:
        for a in self.dp + self.ep + self.esp + self.mp:
            if a not in mesh.shape:
                raise ValueError(f"axis {a!r} not in mesh {mesh.shape}")
        n_ep = axis_size(mesh, self.ep)
        if n_experts % max(n_ep, 1) != 0:
            raise ValueError(
                f"E={n_experts} must be divisible by EP degree {n_ep}")


# Canonical logical->physical mappings ---------------------------------------

def production_dims(multi_pod: bool = False, moe: bool = True) -> ParallelDims:
    """Logical dims for the (16,16) / (2,16,16) production meshes.

    MoE archs: EP over ``data`` (DeepSpeed-MoE style "EP inside DP"),
    ESP == MP over ``model``; the ``pod`` axis is pure DP.
    Dense archs: MP over ``model``, everything else DP.
    """
    dp = ("pod",) if multi_pod else ()
    if moe:
        return ParallelDims(dp=dp, ep=("data",), esp=("model",), mp=("model",))
    return ParallelDims(dp=dp + ("data",), ep=(), esp=(), mp=("model",))
