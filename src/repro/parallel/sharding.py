"""PartitionSpec rules for parameters and activations.

Dense (non-MoE) parts of every model are parallelized GSPMD-style:
attention heads and FFN hidden dims over the MP axes (Megatron), batch
over DP(+EP) axes.  MoE expert parameters are sharded E-over-EP and
hidden-over-ESP and consumed inside the explicit shard_map region.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.mesh import ParallelDims, axis_size


def maybe(axes):
    """Return axes tuple for a PartitionSpec entry, or None if empty."""
    axes = tuple(axes)
    return axes if axes else None


def divisible(n: int, mesh, axes) -> bool:
    return n % max(axis_size(mesh, axes), 1) == 0


class ShardingRules:
    """Derive PartitionSpecs for a model family given mesh + ParallelDims.

    Falls back to replication whenever a dim is not divisible by the axis
    size (e.g. GQA kv_heads=4 on a 16-way model axis).
    """

    def __init__(self, mesh, dims: ParallelDims):
        self.mesh = mesh
        self.dims = dims

    def _mp(self, dim_size: int):
        mp = self.dims.mp
        if mp and dim_size % axis_size(self.mesh, mp) == 0:
            return maybe(mp)
        return None

    # --- activations ---------------------------------------------------
    def act_tokens(self):
        """(B, L, M) activations: batch over DP+EP, replicated over MP."""
        return P(maybe(self.dims.batch_axes), None, None)

    def act_kv_cache(self, n_kv: int):
        """(B, n_kv, L, hd) decode cache."""
        return P(maybe(self.dims.batch_axes), self._mp(n_kv), None, None)

    # --- dense params ----------------------------------------------------
    def dense(self, shape, mp_dim: int | None):
        """Generic dense weight; shard dim ``mp_dim`` over MP if divisible."""
        spec = [None] * len(shape)
        if mp_dim is not None:
            ax = self._mp(shape[mp_dim])
            spec[mp_dim] = ax
        return P(*spec)

    # --- expert params --------------------------------------------------
    def expert(self, shape_e_first, esp_dim: int):
        """Stacked expert weight (E, ...): E over EP, ``esp_dim`` over ESP."""
        spec = [None] * len(shape_e_first)
        ep = self.dims.ep
        if ep and shape_e_first[0] % axis_size(self.mesh, ep) == 0:
            spec[0] = maybe(ep)
        esp = self.dims.esp
        if esp and shape_e_first[esp_dim] % axis_size(self.mesh, esp) == 0:
            spec[esp_dim] = maybe(esp)
        return P(*spec)


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(x, mesh, spec: P):
    """Sharding constraint helper (no-op outside jit on a 1-device mesh)."""
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
