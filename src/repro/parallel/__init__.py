from repro.parallel.mesh import (  # noqa: F401
    ParallelDims,
    axis_size,
    make_mesh,
)
from repro.parallel import sharding  # noqa: F401
