"""Docs link-check: every relative markdown link must resolve on disk.

    python tools/check_links.py [files...]        # default: README + docs/*.md

No dependencies, no network: external (http/https/mailto) links are only
syntax-checked; relative links (with optional #anchors) are resolved
against the containing file and must point at an existing file or
directory.  Exits 1 listing every broken link.  Run by the CI docs job
and by tests/test_docs.py.
"""

from __future__ import annotations

import glob
import os
import re
import sys

# [text](target) — ignores images' leading "!" (same resolution rules)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def check_file(path: str) -> list:
    """Return [(lineno, target, reason), ...] for broken links in one file."""
    broken = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        in_code = False
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(EXTERNAL) or target.startswith("#"):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.join(base, rel)):
                    broken.append((lineno, target, "missing file"))
    return broken


def main(argv) -> int:
    files = argv or (["README.md"] + sorted(glob.glob("docs/*.md")))
    n_links = 0
    failures = []
    for path in files:
        if not os.path.exists(path):
            failures.append((path, 0, path, "file not found"))
            continue
        with open(path, encoding="utf-8") as f:
            n_links += len(LINK_RE.findall(f.read()))
        for lineno, target, reason in check_file(path):
            failures.append((path, lineno, target, reason))
    for path, lineno, target, reason in failures:
        print(f"BROKEN {path}:{lineno}: ({target}) {reason}")
    print(f"checked {len(files)} files, {n_links} links, "
          f"{len(failures)} broken")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
